"""Serving throughput: jitted wave loop vs wavefront (PR 1) vs seed router,
plus the continuous-batching front-end under a steady-state arrival process.

Sweeps batch sizes on an oracle pool and reports queries/sec plus realized-
vs-planned cost for three engines:

  * ``jit``       — ``ThriftRouter.route_batch`` (PR 2): the whole wave loop
                    as one on-device ``lax.scan`` behind the plan cache;
  * ``wavefront`` — ``ThriftRouter.route_batch_reference`` (PR 1): the
                    compacting host-side wavefront;
  * ``seed``      — a faithful reproduction of the seed implementation
                    (per-query Python belief updates in the wave loop AND a
                    per-query Python loop inside the oracle arm).

Then drives the same pool through the :class:`BatchScheduler` front-end
(``steady_state`` in the report): a saturated run measuring end-to-end
capacity at batch-256 admission (submit -> admission queue -> pipelined
budget-group waves -> futures), and a Poisson arrival run at a fraction of
that capacity recording per-request p50/p99 completion latency.

The ``replica_scaling`` section measures the R-replica serving plane
(``ReplicaSet``): aggregate qps and p99 completion tails for R in {1, 2, 4}
at a fixed per-replica admission batch on one saturated stream — sharded
affinity admission plus single-device fused same-budget wave dispatch —
with the R=1 row bit-checked against the plain ``BatchScheduler`` steady
path (the committed full-size report carries the >= 2x aggregate qps at
R=4 acceptance bar). Its ``cross_device`` subsection adds the multi-device
placement curve (run under ``XLA_FLAGS=--xla_force_host_platform_device_
count=4``): overlapped per-device wave dispatch vs fused single-device
dispatch at the serving level AND at the raw wave-program level, with an
explicit ``parallel_capable`` flag — forced host devices multiplex the
host's physical cores, so the >= 1.5x overlapped-vs-fused bar is only
asserted where the host can actually run device programs concurrently.

The ``selection`` section measures the batched planner (PR 5): serial vs
batched replan latency when G in {1, 8, 64} drifted clusters re-select at
once, with bit-identical plans asserted across the two paths (the
committed full-size report carries the >= 3x speedup acceptance bar at
G = 64).

The ``raw_speed`` section is the PR 10 pass: the fully on-device planner
(greedy-on-gamma, l* and candidate scoring fused into the scan program,
``sur_greedy_many``) against the retained PR 9 host-gamma plane at G in
{1, 8, 64} with bit-identical plans asserted (the committed report carries
the >= 1.3x bar at G = 64); donated vs non-donated wave dispatch with the
routes bit-checked; and cold-*process* first-plan latency twice against a
shared ``REPRO_COMPILE_CACHE_DIR`` (second process deserializes instead of
compiling), with honesty fields when the backend lacks cache support.

Finally the ``feedback`` section measures the online estimation loop on
synthetic *drifted* traffic: the arms the served plans rely on degrade
mid-stream, and three pipelines route the same post-drift request stream —
frozen plans (no feedback), the feedback-enabled front-end (ground-truth
labels recorded per chunk, folded at admission boundaries, drift-gated
replans), and an oracle replan (re-estimated from post-drift truth). The
acceptance bar: online recovers >= 90% of the oracle's drifted-cluster
tail accuracy while frozen does not; ``overhead_vs_frozen`` reports the
wall-time cost of carrying the loop.

Writes ``BENCH_serving.json``; if the output file already holds an earlier
report, its summary is appended to ``history`` so the perf trajectory
(seed -> wavefront -> jitted -> continuous) stays in one file.

Run:  PYTHONPATH=src python -m benchmarks.serving_throughput [--out BENCH_serving.json]
CI smoke:  python -m benchmarks.serving_throughput --smoke --out /tmp/bench.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import List

import jax
import numpy as np

from repro.analysis import CompileSentinel, compile_cache_size
from repro.core import selection as selection_mod
from repro.core.belief import empty_log_belief, log_weight
from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.core.mc import bucket_size
from repro.core.types import clip_probs
from repro.data import OracleWorkload
from repro.distributed.fault import FaultPolicy
from repro.serving import BatchScheduler, OracleArm, PoolEngine, ThriftRouter
from repro.serving import router as router_mod

BATCH_SIZES = [32, 64, 128, 256, 512, 1024]


@dataclasses.dataclass
class _SeedOracleArm:
    """Seed-commit oracle arm: one workload.invoke per query (Python loop)."""

    name: str
    workload: OracleWorkload
    arm_index: int
    seed: int = 0

    def __post_init__(self):
        self.cost = float(self.workload.costs[self.arm_index])
        self._rng = np.random.default_rng(self.seed + 7919 * self.arm_index)

    def classify_batch(self, queries) -> np.ndarray:
        out = np.empty(len(queries), np.int64)
        for i, (cid, label) in enumerate(queries):
            out[i] = self.workload.invoke(self.arm_index, cid, label, self._rng)
        return out

    def latency_s(self, batch: int) -> float:
        return 0.0


def _seed_lookup_batch(est: SuccessProbEstimator, embeddings: np.ndarray) -> np.ndarray:
    """Seed-commit lookup_batch: full (B, C, d) difference tensor."""
    d = ((embeddings[:, None, :] - est._centroids[None, :, :]) ** 2).sum(-1)
    return est._cids[np.argmin(d, axis=1)]


def seed_route_batch(router: ThriftRouter, engine: PoolEngine, queries, embeddings, budget):
    """The seed ``ThriftRouter.route_batch``, verbatim modulo imports: per-
    cluster groups routed serially, per-query Python loops updating beliefs."""
    B = len(queries)
    K = router.num_classes
    cluster_ids = _seed_lookup_batch(router.estimator, embeddings)

    predictions = np.zeros(B, np.int64)
    costs = np.zeros(B, np.float64)
    planned = np.zeros(B, np.float64)
    arms_used: List[List[int]] = [[] for _ in range(B)]

    for cid in np.unique(cluster_ids):
        q_idx = np.flatnonzero(cluster_ids == cid)
        stats = router.estimator.clusters[int(cid)]
        p = stats.p_hat
        sel = router.selector.select(p, K, budget)
        order = sorted(sel.chosen, key=lambda i: -p[i])
        w = log_weight(clip_probs(p), K)
        empty = empty_log_belief(p)

        nb = q_idx.size
        beliefs = np.full((nb, K), empty, np.float64)
        counts = np.zeros((nb, K), np.int64)
        active = np.ones(nb, bool)
        planned[q_idx] = float(engine.costs[order].sum()) if order else 0.0

        for wave, arm in enumerate(order):
            log_f = float(np.sum(w[order[wave:]]))
            srt = np.sort(beliefs, axis=1)
            h1, h2 = srt[:, -1], srt[:, -2]
            still = active & (log_f + h2 > h1 - 1e-9)
            if not still.any():
                break
            full_active = np.zeros(B, bool)
            full_active[q_idx[still]] = True
            resp = engine.invoke_arm(arm, queries, full_active)[q_idx]
            hit = np.flatnonzero(still)
            for j in hit:
                r = int(resp[j])
                if counts[j, r] == 0:
                    beliefs[j, r] = w[arm]
                else:
                    beliefs[j, r] += w[arm]
                counts[j, r] += 1
                costs[q_idx[j]] += engine.costs[arm]
                arms_used[q_idx[j]].append(arm)
            active = still

        predictions[q_idx] = np.argmax(beliefs, axis=1)
    return predictions, costs, planned


def steady_state(router, wl, budget: float, batch: int, n_queries: int,
                 load: float, seed: int = 23, repeats: int = 5) -> dict:
    """Drive the continuous-batching front-end and measure it end to end.

    Two runs over the same request stream:

    * **saturated** — every request submitted at t0 (offered load far above
      capacity): measures the front-end's sustainable throughput at
      ``batch``-sized admission, i.e. the one-shot jitted engine plus all
      scheduler overhead (admission, budget grouping, pipelined dispatch,
      future resolution). Best-of-``repeats``, like the one-shot engine
      rows, since this is the number the acceptance bar compares against
      the raw jitted engine.
    * **steady** — Poisson arrivals at ``load``x the measured capacity:
      below saturation, so the p50/p99 completion latencies reflect
      queueing + batching delay rather than unbounded backlog.
    """
    from repro.serving.router import _bucket

    rng = np.random.default_rng(seed)
    cid, qemb, lab = wl.sample_queries(n_queries, rng)
    payloads = np.column_stack([cid, lab])

    coalesce = 4

    def make_sched():
        return BatchScheduler(
            router, max_batch=batch, max_wait_s=0.0005, max_inflight=2,
            coalesce=coalesce,
        )

    # warm-up: fill plan caches and compile the wave program for every
    # (B,) bucket an admission could land in — partial bursts from the
    # arrival run up through saturation-coalesced batches
    warm = make_sched()
    for b in sorted({
        _bucket(n, base=8) for n in range(1, coalesce * batch + 1)
    }):
        b = min(b, n_queries)
        warm.submit_many(payloads[:b], qemb[:b], budget)
        warm.drain()

    # saturated capacity, paired with a bare-engine measurement of the SAME
    # stream in `batch`-sized one-shot calls, interleaved (best-of each) so
    # shared-host load spikes penalize both sides equally — this ratio is
    # the "front-end overhead vs the PR 2 jitted engine" acceptance number
    dt = dt_oneshot = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        for s in range(0, n_queries, batch):
            router.route_batch(
                payloads[s:s + batch], qemb[s:s + batch], budget
            )
        dt_oneshot = min(dt_oneshot, time.perf_counter() - t0)
        sched = make_sched()
        t0 = time.perf_counter()
        blk = sched.submit_many(payloads, qemb, budget)
        sched.drain()
        dt = min(dt, time.perf_counter() - t0)
    saturated_qps = n_queries / dt
    oneshot_qps = n_queries / dt_oneshot
    accuracy = float((blk.predictions == lab).mean())

    # steady arrival process at `load` x capacity
    offered_qps = load * saturated_qps
    sched2 = make_sched()
    start = time.monotonic()
    arrivals = start + np.cumsum(rng.exponential(1.0 / offered_qps, n_queries))
    sent = 0
    while sent < n_queries:
        now = time.monotonic()
        due = int(np.searchsorted(arrivals, now, side="right"))
        if due > sent:
            sched2.submit_many(
                payloads[sent:due], qemb[sent:due], budget,
                arrival_s=arrivals[sent:due],
            )
            sent = due
        sched2.pump()
    sched2.drain()
    steady_dt = time.monotonic() - start
    lat = sched2.latency_stats()

    return {
        "max_batch": batch,
        "queries": n_queries,
        "saturated_qps": saturated_qps,
        "oneshot_qps": oneshot_qps,
        "vs_jit_engine": saturated_qps / oneshot_qps,
        "offered_qps": offered_qps,
        "steady_qps": n_queries / steady_dt,
        "p50_ms": 1e3 * lat.get("p50_s", 0.0),
        "p99_ms": 1e3 * lat.get("p99_s", 0.0),
        "mean_ms": 1e3 * lat.get("mean_s", 0.0),
        "accuracy": accuracy,
        # scheduler counters of the Poisson run the latencies describe
        "flushes": int(sched2.stats["flushes"]),
        "groups": int(sched2.stats["batches"]),
        "spec_jit": int(sched2.stats["spec_jit"]),
        "spec_reference": int(sched2.stats["spec_reference"]),
        "inflight_peak": int(sched2.stats["inflight_peak"]),
        # and of the saturated-capacity run (coalesced admissions)
        "saturated_flushes": int(sched.stats["flushes"]),
        "saturated_groups": int(sched.stats["batches"]),
        "saturated_spec_jit": int(sched.stats["spec_jit"]),
        "saturated_spec_reference": int(sched.stats["spec_reference"]),
    }


def replica_scaling(router, wl, budget: float, per_batch: int, make_router,
                    replicas=(1, 2, 4), n_queries: int = 0, seed: int = 41,
                    repeats: int = 3) -> dict:
    """Aggregate throughput and completion tails of the R-replica plane.

    The SAME saturated request stream is served at fixed *per-replica*
    admission size by R in ``replicas``: sharded affinity admission, one
    fused same-budget wave dispatch per drive cycle on a single device
    (the multi-replica tentpole). Because every run serves an identical
    workload, higher R finishing sooner shows up as BOTH higher qps and an
    equal-or-better p99 — the acceptance bar is R=4 >= 2x the R=1 qps.

    The R=1 row is additionally bit-checked against the plain
    ``BatchScheduler`` steady path on the same stream
    (``r1_bitmatch_steady``): the replica front-end at R=1 must not cost
    or change anything. Oracle arms draw responses from a per-arm rng that
    advances with every invocation, so the check runs each side on its own
    freshly-seeded ``make_router()`` pool — the streams stay bit-equal
    exactly when the two front-ends invoke the same cells in the same
    order, which is the contract. All timed passes run after a warm-up pass plus
    ``prewarm_compile`` (per-replica and fused buckets), and a
    CompileSentinel asserts the timed section never compiles.

    Measurement notes: the per-replica admission size is deliberately
    small (latency-bound regime — that is where cross-replica fusion
    amortizes the per-dispatch host cost; at large per-replica batches a
    single scheduler is already amortized), ``spill_factor=1.0`` pins the
    shards to exact fair share so every drive cycle fuses all R workers,
    and the repeats are INTERLEAVED across R so machine noise hits every
    row under the same conditions before best-of is taken.
    """
    from repro.serving import ReplicaSet

    n = n_queries or per_batch * 128
    rng = np.random.default_rng(seed)
    cid, qemb, lab = wl.sample_queries(n, rng)
    payloads = np.column_stack([cid, lab])

    def make_set(R):
        # pinned to the fused placement: this sweep is the PR-8 historical
        # metric (admission-plane scaling with single-device fused waves);
        # the overlapped-vs-fused placement comparison lives in the
        # cross_device subsection
        return ReplicaSet(
            router, replicas=R, max_batch=per_batch, max_wait_s=0.0005,
            max_inflight=12, coalesce=1, spill_factor=1.0,
            placement="fused",
        )

    # warm every bucket the sweep can hit (per-replica + fused), then pin
    # the timed section to zero recompiles
    for R in replicas:
        rset = make_set(R)
        rset.prewarm(budgets=[budget])
        rset.prewarm_compile()
        rset.submit_many(payloads, qemb, budget)
        rset.drain()
    sentinel = CompileSentinel({"wave": router_mod._wave_scan})
    sentinel.snapshot()

    best = {}
    for _ in range(repeats):
        for R in replicas:
            rset = make_set(R)
            t0 = time.perf_counter()
            blk = rset.submit_many(payloads, qemb, budget)
            rset.drain()
            dt = time.perf_counter() - t0
            if R not in best or dt < best[R][0]:
                best[R] = (dt, rset, blk)

    rows = []
    r1_qps = None
    for R in replicas:
        best_dt, rset, blk = best[R]
        lat = rset.latency_stats()
        st = rset.stats
        qps = n / best_dt
        if R == replicas[0]:
            r1_qps = qps
        rows.append({
            "replicas": int(R),
            "per_replica_batch": per_batch,
            "qps": qps,
            "p50_ms": 1e3 * lat.get("p50_s", 0.0),
            "p99_ms": 1e3 * lat.get("p99_s", 0.0),
            "speedup_vs_r1": qps / r1_qps,
            "placement": rset.placement,
            "devices": int(st["replica_devices"]),
            "fused_dispatches": int(st["replica_fused"]),
            "fused_rows": int(st["replica_fused_rows"]),
            "overlapped_dispatches": int(st["replica_overlapped"]),
            "spills": int(st["replica_spills"]),
            "accuracy": float((blk.predictions == lab).mean()),
        })
        print(
            f"replica scaling R={R}: {qps:9.0f} qps "
            f"({rows[-1]['speedup_vs_r1']:4.2f}x R=1) | p99 "
            f"{rows[-1]['p99_ms']:7.2f}ms | fused {st['replica_fused']} "
            f"({st['replica_fused_rows']} rows) spills {st['replica_spills']}"
        )
    timed_recompiles = sentinel.total()

    # R=1 contract: bit-identical to the plain BatchScheduler steady path
    # (twin freshly-seeded pools: see the docstring)
    rset1 = ReplicaSet(make_router(), replicas=1, max_batch=per_batch,
                       max_wait_s=0.0005, max_inflight=12, coalesce=1)
    r1_blk = rset1.submit_many(payloads, qemb, budget)
    rset1.drain()
    base = BatchScheduler(make_router(), max_batch=per_batch,
                          max_wait_s=0.0005, max_inflight=12, coalesce=1)
    ref = base.submit_many(payloads, qemb, budget)
    base.drain()
    r1_bitmatch = bool(
        np.array_equal(r1_blk.predictions, ref.predictions)
        and np.array_equal(r1_blk.costs, ref.costs)
        and np.array_equal(r1_blk.stop_waves, ref.stop_waves)
    )
    by_r = {r["replicas"]: r for r in rows}
    top = max(by_r)
    return {
        "per_replica_batch": per_batch,
        "queries": n,
        "rows": rows,
        "r1_bitmatch_steady": r1_bitmatch,
        "speedup_at_max": by_r[top]["speedup_vs_r1"],
        "replicas_max": int(top),
        "timed_recompiles": int(timed_recompiles),
    }


def cross_device(router, wl, budget: float, per_batch: int, make_router,
                 replicas=(1, 2, 4), seed: int = 43, repeats: int = 3,
                 wave_rows_per_device: int = 4096) -> dict:
    """Cross-device scaling curve: overlapped-R-devices vs fused-1-device.

    Two layers, both at R in ``replicas`` on however many host devices the
    process was forced to (CI: ``--xla_force_host_platform_device_count=4``):

    * **serving rows** — the full ReplicaSet stream (admission, planning,
      speculative gather, dispatch, retirement) under
      ``placement="overlapped"`` vs ``placement="fused"``. End-to-end qps
      here is dominated by the single-threaded host front-end, so this
      layer mostly prices the placement's per-dispatch overhead.
    * **wave_plane rows** — the device-program curve the placement
      actually owns: identical pre-staged padded wave tables, R per-device
      ``_wave_scan`` programs in flight concurrently vs one fused
      ``R x rows`` program on a single device. No host work in the timed
      section beyond R dispatches.

    ``parallel_capable`` records whether the host can physically overlap
    device programs (``host_cores >= devices``). Forced host devices
    multiplex the same cores, so on a 1-core container the overlapped
    ratios sit below 1 — CI asserts the >= 1.5x acceptance bar only when
    ``parallel_capable`` is true, and always asserts well-formedness,
    the R=1 bit-match and the zero-recompile contract.

    Returns ``{"devices": 1, "skipped": true}`` on a single-device
    process (nothing to place across).
    """
    import os

    import jax
    from jax.experimental import enable_x64

    from repro.serving import ReplicaSet

    devs = jax.devices()
    if len(devs) <= 1:
        return {"devices": 1, "skipped": True}

    n = per_batch * 64
    rng = np.random.default_rng(seed)
    cid, qemb, lab = wl.sample_queries(n, rng)
    payloads = np.column_stack([cid, lab])

    def make_set(R, placement):
        return ReplicaSet(
            router, replicas=R, max_batch=per_batch, max_wait_s=0.0005,
            max_inflight=12, coalesce=1, spill_factor=1.0,
            placement=placement,
        )

    # ---- warm every (bucket, device) the timed sections can hit --------
    for R in replicas:
        for placement in ("overlapped", "fused"):
            rset = make_set(R, placement)
            rset.prewarm(budgets=[budget])
            rset.prewarm_compile()
            rset.submit_many(payloads, qemb, budget)
            rset.drain()

    Tp = bucket_size(len(router.engine.arms), 4)
    Bp = int(wave_rows_per_device)
    wrng = np.random.default_rng(seed + 1)
    L = len(router.engine.arms)
    K = router.num_classes

    def wave_args(rows):
        sched = wrng.integers(0, L, size=(Tp, rows)).astype(np.int32)
        resp = wrng.integers(0, K, size=(Tp, rows)).astype(np.int32)
        w = wrng.random((Tp, rows))
        res = np.log(np.maximum(wrng.random((Tp, rows)), 1e-3))
        src = np.broadcast_to(
            np.arange(Tp, dtype=np.int32)[:, None], (Tp, rows)
        ).copy()
        valid = np.ones((Tp, rows), bool)
        empty = np.zeros(rows, np.float64)
        return (sched, resp, w, res, src, valid, empty)

    def run_wave(args_list):
        with router_mod._quiet_donation():
            outs = [
                router_mod._wave_scan(
                    *a, router_mod.STOP_MARGIN,
                    num_classes=K, use_kernel=router.use_kernel,
                )
                for a in args_list
            ]
        for o in outs:
            jax.block_until_ready(o)

    wave_staged = {}
    with enable_x64():
        for R in replicas:
            shards = [
                jax.device_put(wave_args(Bp), devs[i % len(devs)])
                for i in range(R)
            ]
            fused = jax.device_put(wave_args(R * Bp), devs[0])
            wave_staged[R] = (shards, fused)
            run_wave(shards)      # warm the per-device shard buckets
            run_wave([fused])     # warm the fused bucket

    sentinel = CompileSentinel({"wave": router_mod._wave_scan})
    sentinel.snapshot()

    # ---- serving rows --------------------------------------------------
    best = {}
    for _ in range(repeats):
        for R in replicas:
            for placement in ("overlapped", "fused"):
                rset = make_set(R, placement)
                t0 = time.perf_counter()
                rset.submit_many(payloads, qemb, budget)
                rset.drain()
                dt = time.perf_counter() - t0
                key = (R, placement)
                if key not in best or dt < best[key][0]:
                    best[key] = (dt, rset)

    rows = []
    for R in replicas:
        dt_o, rset_o = best[(R, "overlapped")]
        dt_f, _ = best[(R, "fused")]
        st = rset_o.stats
        rows.append({
            "replicas": int(R),
            "devices_used": int(st["replica_devices"]),
            "qps_overlapped": n / dt_o,
            "qps_fused": n / dt_f,
            "overlapped_vs_fused": dt_f / dt_o,
            "overlapped_dispatches": int(st["replica_overlapped"]),
        })
        print(
            f"cross-device serving R={R}: overlapped "
            f"{rows[-1]['qps_overlapped']:9.0f} qps vs fused "
            f"{rows[-1]['qps_fused']:9.0f} "
            f"({rows[-1]['overlapped_vs_fused']:4.2f}x) on "
            f"{rows[-1]['devices_used']} device(s)"
        )

    # ---- wave-plane rows -----------------------------------------------
    wave_rows = []
    with enable_x64():
        for R in replicas:
            shards, fused = wave_staged[R]
            t_o = t_f = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                run_wave([fused])
                t_f = min(t_f, time.perf_counter() - t0)
                t0 = time.perf_counter()
                run_wave(shards)
                t_o = min(t_o, time.perf_counter() - t0)
            total = R * Bp
            wave_rows.append({
                "replicas": int(R),
                "rows_total": int(total),
                "qps_overlapped_rows": total / t_o,
                "qps_fused_rows": total / t_f,
                "overlapped_vs_fused": t_f / t_o,
            })
            print(
                f"cross-device wave-plane R={R} ({total} rows): "
                f"overlapped {total / t_o:11.0f} rows/s vs fused "
                f"{total / t_f:11.0f} ({t_f / t_o:4.2f}x)"
            )
    timed_recompiles = sentinel.total()

    # ---- R=1 anchor: overlapped R=1 == plain BatchScheduler ------------
    rset1 = ReplicaSet(make_router(), replicas=1, max_batch=per_batch,
                       max_wait_s=0.0005, max_inflight=12, coalesce=1,
                       placement="overlapped")
    r1_blk = rset1.submit_many(payloads, qemb, budget)
    rset1.drain()
    base = BatchScheduler(make_router(), max_batch=per_batch,
                          max_wait_s=0.0005, max_inflight=12, coalesce=1)
    ref = base.submit_many(payloads, qemb, budget)
    base.drain()
    r1_bitmatch = bool(
        np.array_equal(r1_blk.predictions, ref.predictions)
        and np.array_equal(r1_blk.costs, ref.costs)
        and np.array_equal(r1_blk.stop_waves, ref.stop_waves)
    )

    top = max(replicas)
    by_r = {r["replicas"]: r for r in rows}
    by_wr = {r["replicas"]: r for r in wave_rows}
    cores = os.cpu_count() or 1
    return {
        "devices": len(devs),
        "host_cores": int(cores),
        "parallel_capable": bool(cores >= len(devs)),
        "per_replica_batch": per_batch,
        "queries": n,
        "rows": rows,
        "wave_plane": {
            "rows_per_device": Bp,
            "waves": int(Tp),
            "rows": wave_rows,
        },
        "overlapped_vs_fused_at_max": by_r[top]["overlapped_vs_fused"],
        "wave_overlapped_vs_fused_at_max": by_wr[top]["overlapped_vs_fused"],
        "replicas_max": int(top),
        "r1_bitmatch": r1_bitmatch,
        "timed_recompiles": int(timed_recompiles),
    }


def feedback_drift(num_classes: int, num_arms: int, history: int,
                   chunks: int, chunk: int, seed: int = 29) -> dict:
    """Online-feedback recovery on synthetic drifted traffic.

    Builds a fresh oracle pool over *true* cluster ids (the drift is
    injected into the workload truth, so clustering error is not part of
    this measurement), caches plans, then degrades every arm the served
    plans rely on — for half the clusters — to barely-above-random (0.30 >
    1/K, keeping selection inside the paper's p > 1/K regime). The same
    post-drift stream is routed by the frozen, online and oracle pipelines;
    accuracy is reported over the drifted clusters' tail traffic (the
    second half of the stream, after the online loop has had labels to
    adapt with). Overhead is decomposed: ``steady_overhead_vs_frozen`` is
    the per-chunk cost of carrying the loop when no drift fires (label
    bookkeeping + version checks), ``replan_time_s`` the cold SurGreedy
    selection time the drift chunks paid to re-plan.
    """
    C = 4
    K, L = num_classes, num_arms

    def pool(arm_seed):
        wl = OracleWorkload(num_classes=K, num_clusters=C, num_arms=L, seed=3)
        T, emb, cid_h = wl.response_table(history * C, seed=4)
        est = SuccessProbEstimator(T, emb, cid_h)
        engine = PoolEngine(
            [OracleArm(f"a{i}", wl, i, seed=arm_seed) for i in range(L)]
        )
        return wl, est, engine, ThriftRouter(engine, est, num_classes=K)

    wl, est, engine, router = pool(11)
    wl_f, _, _, frozen_router = pool(13)
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    sched = BatchScheduler(router, max_batch=chunk, max_wait_s=0.0,
                           feedback=True)
    # frozen baseline rides the SAME front-end, just without feedback, so
    # the overhead ratio isolates the loop (labels, folds, version checks,
    # replans) instead of scheduler-vs-bare-engine differences
    frozen = BatchScheduler(frozen_router, max_batch=chunk, max_wait_s=0.0)

    # pre-drift warmup (not timed, not scored): fills the plan caches and
    # compiles the wave program on both pipelines, so `overhead_vs_frozen`
    # measures the feedback loop (labels, folds, drift-gated replans)
    # rather than first-call jit compilation. Replans can deepen plans
    # across wave-depth buckets, so every bucket a replan could land in is
    # compiled up front — warm on any long-running server.
    wrng = np.random.default_rng(seed + 1)
    wcid, wemb, wlab = wl.sample_queries(chunk, wrng)
    wq = np.column_stack([wcid, wlab])
    sched.submit_many(wq, wemb, budget)
    sched.drain()
    frozen.submit_many(wq, wemb, budget)
    frozen.drain()
    router.prewarm_compile(chunk)

    # drift: the served plans' arms degrade for half the clusters
    targets = list(range(C // 2))
    drifted_arms = sorted({
        int(a) for t in targets for a in router.plans.plan(t, budget).order
    })
    for t in targets:
        wl.drift_arms(router.plans.plan(t, budget).order, 0.30, clusters=[t])
    wl_f.p_true[:] = wl.p_true

    # oracle replan: re-estimated from post-drift truth
    T2, emb2, cid2 = wl.response_table(history * C, seed=14)
    oracle = ThriftRouter(
        PoolEngine([OracleArm(f"o{i}", wl, i, seed=12) for i in range(L)]),
        SuccessProbEstimator(T2, emb2, cid2),
        num_classes=K,
    )

    rng = np.random.default_rng(seed)
    stream = [wl.sample_queries(chunk, rng) for _ in range(chunks)]
    accs = {"online": [], "oracle": [], "frozen": []}
    t_online, t_frozen, drift_chunk = [], [], []
    for cid, qemb, lab in stream:
        m = np.isin(cid, targets)
        q = np.column_stack([cid, lab])
        drifts_before = sched.stats["feedback_drifts"]
        t0 = time.perf_counter()
        blk = sched.submit_many(q, qemb, budget)
        sched.drain()
        sched.record_outcomes(blk.request_ids, lab)
        t_online.append(time.perf_counter() - t0)
        drift_chunk.append(sched.stats["feedback_drifts"] > drifts_before)
        t0 = time.perf_counter()
        fblk = frozen.submit_many(q, qemb, budget)
        frozen.drain()
        t_frozen.append(time.perf_counter() - t0)
        ores = oracle.route_batch(q, qemb, budget)
        accs["online"].append(float((blk.predictions[m] == lab[m]).mean()))
        accs["oracle"].append(float((ores.predictions[m] == lab[m]).mean()))
        accs["frozen"].append(float((fblk.predictions[m] == lab[m]).mean()))

    tail = chunks // 2
    online, oracle_acc, frozen_acc = (
        float(np.mean(accs[k][tail:])) for k in ("online", "oracle", "frozen")
    )
    st = dict(sched.stats)
    # overhead decomposition: drift chunks pay cold SurGreedy selection for
    # the re-planned clusters (the cost the plan cache amortizes everywhere
    # else); steady chunks pay only label bookkeeping + version checks
    steady_online = [t for t, d in zip(t_online, drift_chunk) if not d]
    steady_ratio = (
        float(np.median(steady_online) / np.median(t_frozen))
        if steady_online else float("nan")
    )
    replan_s = max(0.0, float(
        sum(t for t, d in zip(t_online, drift_chunk) if d)
        - (np.median(steady_online) if steady_online else 0.0) * sum(drift_chunk)
    ))
    return {
        "chunks": chunks,
        "chunk": chunk,
        "drifted_clusters": targets,
        "drifted_arms": drifted_arms,
        "online_acc": online,
        "oracle_acc": oracle_acc,
        "frozen_acc": frozen_acc,
        "recovery": online / max(oracle_acc, 1e-12),
        "frozen_vs_oracle": frozen_acc / max(oracle_acc, 1e-12),
        "acc_trajectory": {k: [round(a, 4) for a in v] for k, v in accs.items()},
        "overhead_vs_frozen": float(sum(t_online) / max(sum(t_frozen), 1e-12)),
        "steady_overhead_vs_frozen": steady_ratio,
        "replan_time_s": replan_s,
        "drift_chunks": int(sum(drift_chunk)),
        "feedback_labels": int(st["feedback_labels"]),
        "feedback_applies": int(st["feedback_applies"]),
        "feedback_drifts": int(st["feedback_drifts"]),
        "plan_stale_dropped": int(st["plan_stale_dropped"]),
        "plan_batch_replans": int(st["plan_batch_replans"]),
        "plan_batch_replanned": int(st["plan_batch_replanned"]),
        "plan_misses": int(st["plan_misses"]),
        "estimator_version": int(est.version),
        "estimator_plan_version": int(est.plan_version),
    }


def fault_tolerance(num_classes: int, num_arms: int, history: int,
                    chunks: int, chunk: int, seed: int = 37) -> dict:
    """Accuracy + tail latency under an injected 2-arm outage.

    The two arms the cached plans lean on hardest (the wave-0/1 heads) go
    fully down (error rate 1.0). The same post-outage stream is served by
    three pipelines plus a no-fault baseline:

      * ``frozen``   — failover off, no feedback: failed waves simply
        vanish from every belief (the pre-hardening behavior);
      * ``failover`` — in-wave failover re-routes each failed slot to the
        plan's next-best affordable arm inside the compiled wave program;
      * ``replan``   — failover + the degradation tracker: failure
        evidence folds into the estimator, the Wilson drift gate replans
        the outage away, probes stand by to readmit.

    The acceptance bar (full run): ``replan`` recovers >= 80% of the
    no-fault accuracy while ``frozen`` does not.
    """
    C = 4
    K, L = num_classes, num_arms

    def pool(failover=True):
        wl = OracleWorkload(num_classes=K, num_clusters=C, num_arms=L, seed=3)
        T, emb, cid_h = wl.response_table(history * C, seed=4)
        est = SuccessProbEstimator(T, emb, cid_h)
        engine = PoolEngine(
            [OracleArm(f"a{i}", wl, i, seed=11) for i in range(L)]
        )
        router = ThriftRouter(engine, est, num_classes=K, failover=failover)
        return wl, engine, router

    wl, engine_b, baseline_r = pool()
    _, engine_z, frozen_r = pool(failover=False)
    _, engine_f, failover_r = pool()
    _, engine_p, replan_r = pool()
    # tight budget -> shallow plans: an outage of the workhorse arms leaves
    # no slack inside the frozen plan, so only replanning can recover
    budget = float(np.quantile(engine_b.costs, 0.45)) * 1.3

    scheds = {
        "baseline": BatchScheduler(baseline_r, max_batch=chunk, max_wait_s=0.0),
        "frozen": BatchScheduler(frozen_r, max_batch=chunk, max_wait_s=0.0),
        "failover": BatchScheduler(failover_r, max_batch=chunk, max_wait_s=0.0),
        "replan": BatchScheduler(replan_r, max_batch=chunk, max_wait_s=0.0,
                                 feedback=True),
    }
    # warmup (not scored): plan caches + wave-program buckets on every plane
    wrng = np.random.default_rng(seed + 1)
    wcid, wemb, wlab = wl.sample_queries(chunk, wrng)
    wq = np.column_stack([wcid, wlab])
    for s in scheds.values():
        s.submit_many(wq, wemb, budget)
        s.drain()

    # the outage: kill the two arms the served plans invoke most
    res = baseline_r.route_batch(wq, wemb, budget)
    flat = res.schedule[res.invoked]
    counts = np.bincount(flat, minlength=L)
    dead = np.argsort(-counts)[:2].tolist()
    for engine in (engine_z, engine_f, engine_p):
        engine.fault_policy = FaultPolicy(L, K, seed=seed).set_arms(
            dead, error=1.0
        )

    rng = np.random.default_rng(seed)
    accs = {name: [] for name in scheds}
    for cid, qemb, lab in [wl.sample_queries(chunk, rng) for _ in range(chunks)]:
        q = np.column_stack([cid, lab])
        for name, sched in scheds.items():
            blk = sched.submit_many(q, qemb, budget)
            sched.drain()
            accs[name].append(float((blk.predictions == lab).mean()))
            for e in (engine_z, engine_f, engine_p):
                if e.fault_policy is not None:
                    e.fault_policy.advance()

    tail = chunks // 2
    mean_acc = {k: float(np.mean(v[tail:])) for k, v in accs.items()}
    base = max(mean_acc["baseline"], 1e-12)
    st = dict(scheds["replan"].stats)
    out = {
        "chunks": chunks,
        "chunk": chunk,
        "dead_arms": dead,
        "baseline_acc": mean_acc["baseline"],
        "frozen_acc": mean_acc["frozen"],
        "failover_acc": mean_acc["failover"],
        "replan_acc": mean_acc["replan"],
        "frozen_recovery": mean_acc["frozen"] / base,
        "failover_recovery": mean_acc["failover"] / base,
        "replan_recovery": mean_acc["replan"] / base,
        "acc_trajectory": {k: [round(a, 4) for a in v] for k, v in accs.items()},
        "p99_ms": {
            name: float(s.latency_stats().get("p99_s", 0.0)) * 1e3
            for name, s in scheds.items()
        },
        "degradation_failures": int(st.get("degradation_failures", 0)),
        "feedback_drifts": int(st.get("feedback_drifts", 0)),
        "plan_stale_dropped": int(st.get("plan_stale_dropped", 0)),
    }
    return out


def selection_replan(num_arms: int, classes: int, history: int,
                     groups=(1, 8, 64), repeats: int = 3, seed: int = 31,
                     eps: float = 0.25) -> dict:
    """Serial vs batched drift-replan latency at G drifted clusters.

    The PR 5 tentpole measurement: a pool with ``max(groups)`` clusters is
    fully planned, then G clusters' estimates are invalidated
    (``estimator.touch``) and the dropped plans re-select — once through
    the serial per-pair path (``PlanService(batched=False)``: one SurGreedy
    host loop per cluster, a device dispatch per greedy round per group)
    and once through the batched planner (one ``select_many`` program for
    all G). Both paths are warmed first (plan build + one replan cycle, so
    jit compilation is excluded on both sides), the selector memo is
    cleared before every timed replan (a replan must re-select, not re-hit
    the memo), and rounds interleave serial/batched so shared-host noise
    penalizes both equally. ``eps`` sizes the Monte-Carlo budget the way a
    serving replan would (theta ~ 1/eps^2).
    """
    C = int(max(groups))
    K, L = classes, num_arms
    wl = OracleWorkload(num_classes=K, num_clusters=C, num_arms=L, seed=7)
    T, emb, cid_h = wl.response_table(history * C, seed=8)

    def mk(batched: bool):
        est = SuccessProbEstimator(T, emb, cid_h)
        engine = PoolEngine(
            [OracleArm(f"b{i}", wl, i, seed=21) for i in range(L)]
        )
        router = ThriftRouter(engine, est, num_classes=K, eps=eps)
        router.plans.batched = batched
        return est, router

    est_s, router_s = mk(False)
    est_b, router_b = mk(True)
    budget = float(np.quantile(router_s.engine.costs, 0.6)) * 2

    def replan_once(router, est, cids):
        for c in cids:
            est.touch(int(c))
        router.selector._cache.clear()   # a replan re-selects, never memo-hits
        t0 = time.perf_counter()
        n = router.plans.replan_stale()
        return time.perf_counter() - t0, n

    rows = []
    plans_match = True
    for G in groups:
        sides = [(router_s, est_s), (router_b, est_b)]
        cid_sets = [
            [int(c) for c in est.cluster_order[:G]] for _, est in sides
        ]
        for (router, est), cids in zip(sides, cid_sets):
            router.plans.plan_many([(c, budget) for c in cids])  # cold build
            replan_once(router, est, cids)                       # warm compile
        best = [np.inf, np.inf]
        rebuilt = [0, 0]
        for _ in range(repeats):
            for i, ((router, est), cids) in enumerate(zip(sides, cid_sets)):
                dt, n = replan_once(router, est, cids)
                best[i] = min(best[i], dt)
                rebuilt[i] = n
        for c_s, c_b in zip(*cid_sets):
            p_s = router_s.plans.plan(c_s, budget)
            p_b = router_b.plans.plan(c_b, budget)
            plans_match &= bool(np.array_equal(p_s.order, p_b.order))
        row = {
            "groups": int(G),
            "serial_s": best[0],
            "batched_s": best[1],
            "speedup": best[0] / best[1],
            "replanned_serial": int(rebuilt[0]),
            "replanned_batched": int(rebuilt[1]),
        }
        rows.append(row)
        print(
            f"selection replan G={G:3d}: serial {1e3 * row['serial_s']:8.1f}ms"
            f" | batched {1e3 * row['batched_s']:8.1f}ms"
            f" | {row['speedup']:5.2f}x ({row['replanned_batched']} plans)"
        )
    return {
        "rows": rows,
        "pool": {"arms": L, "classes": K, "clusters": C, "budget": budget},
        "eps": eps,
        "groups_max": int(max(groups)),
        "speedup_at_max": rows[-1]["speedup"],
        "plans_match": plans_match,
    }


# ---------------------------------------------------------------------------
# raw_speed: the PR 10 section — fully on-device planner vs the PR 9
# host-gamma plane, donation on/off wave-loop timings, and cold-start
# replan latency with/without the persistent compilation cache.
# ---------------------------------------------------------------------------


def _same_plan(a, b) -> bool:
    """Bitwise equality of two SelectionResults (everything derived)."""
    if not np.array_equal(a.chosen, b.chosen):
        return False
    if not (a.xi_est == b.xi_est and a.cost == b.cost):
        return False
    if (a.s1 is None) != (b.s1 is None):
        return False
    if a.s1 is not None:
        return bool(
            np.array_equal(a.s1, b.s1) and np.array_equal(a.s2, b.s2)
            and a.l_star == b.l_star and a.xi_s1 == b.xi_s1
            and a.xi_s2 == b.xi_s2
        )
    return True


_COLD_START_CHILD = r"""
import json, sys, time
import numpy as np
t_import0 = time.perf_counter()
import jax
from repro.core import sur_greedy_many
from repro.serving.compile_cache import cache_supported, configure_compile_cache
t_import = time.perf_counter() - t_import0
info = configure_compile_cache()          # reads REPRO_COMPILE_CACHE_DIR
rng = np.random.default_rng(0)
t0 = time.perf_counter()
sur_greedy_many(
    rng.uniform(0.2, 0.98, (8, 12)), rng.uniform(0.05, 1.0, 12),
    rng.uniform(0.5, 2.0, 8), 4, jax.random.key(0), np.full(8, 300),
)
dt = time.perf_counter() - t0
print(json.dumps({"first_plan_s": dt, "import_s": t_import,
                  "cache": info, "supported": cache_supported()}))
"""


def _cold_start_cache(repo_root: str) -> dict:
    """Cold-process replan latency, twice against one shared persistent
    compile-cache dir: the first process pays the XLA compile and seeds the
    cache, the second deserializes the executable instead of compiling.
    Honesty fields: skipped (+reason) when the backend has no cache
    serialization support, and the raw child payloads either way."""
    from repro.serving.compile_cache import cache_supported

    if not cache_supported():
        return {"skipped": True, "reason": "backend lacks persistent-cache "
                "support", "supported": False}
    out = {"skipped": False, "supported": True}
    with tempfile.TemporaryDirectory(prefix="repro-cache-") as cache_dir:
        env = dict(os.environ)
        env["REPRO_COMPILE_CACHE_DIR"] = cache_dir
        env["PYTHONPATH"] = os.path.join(repo_root, "src")
        runs = []
        for label in ("first", "second"):
            proc = subprocess.run(
                [sys.executable, "-c", _COLD_START_CHILD],
                capture_output=True, text=True, env=env, cwd=repo_root,
            )
            if proc.returncode != 0:
                return {"skipped": True, "supported": True,
                        "reason": f"{label} child failed",
                        "stderr": proc.stderr[-2000:]}
            runs.append(json.loads(proc.stdout.strip().splitlines()[-1]))
        cache_entries = len(
            [p for p in os.listdir(cache_dir) if not p.startswith(".")]
        )
    out["first_plan_s"] = runs[0]["first_plan_s"]
    out["second_plan_s"] = runs[1]["first_plan_s"]
    out["speedup"] = out["first_plan_s"] / out["second_plan_s"]
    out["improved"] = bool(out["second_plan_s"] < out["first_plan_s"])
    out["cache_entries"] = cache_entries
    out["children"] = runs
    return out


def raw_speed(num_arms: int, classes: int, groups=(1, 8, 64),
              repeats: int = 5, wave_batch: int = 256,
              wave_repeats: int = 10, seed: int = 47,
              cold_start: bool = True) -> dict:
    """The PR 10 measurements, three blocks:

    * ``planner`` — the fully on-device plane (``sur_greedy_many``: greedy-
      on-gamma, l*, and candidate scoring fused into the scan program) vs
      the retained PR 9 plane (``_sur_greedy_many_hostgamma``: device xi
      greedy + per-group host loop + separate final-xi dispatch) at G
      drifted groups, bit-identical plans asserted per pair;
    * ``donation`` — the serving wave loop with donated staged tables
      (``donate_buffers=True``, the default) vs the nodonate twin, outputs
      bit-checked (donation is a storage contract, not a numerics knob; on
      backends where the reduction outputs can't alias the staged tables
      the timing delta is expected to be noise);
    * ``cold_start`` — cold-*process* first-plan latency twice against one
      shared ``REPRO_COMPILE_CACHE_DIR``, second process cache-warmed.

    All timed loops run strictly after per-bucket warm-ups; a local
    CompileSentinel records ``timed_recompiles`` for the section.
    """
    from repro.core.selection import _sur_greedy_many_hostgamma, sur_greedy_many

    K, L = classes, num_arms
    rng = np.random.default_rng(seed)
    b = rng.uniform(0.05, 1.0, L)
    key = jax.random.key(9)
    theta = 200                      # pins one theta bucket for every G

    sentinel = CompileSentinel({
        "plan": selection_mod._sur_greedy_scan,
        "plan_nodonate": selection_mod._sur_greedy_scan_nodonate,
        "wave": router_mod._wave_scan,
        "wave_nodonate": router_mod._wave_scan_nodonate,
    })

    cases = {}
    for G in groups:
        ps = rng.uniform(0.2, 0.98, (G, L))
        budgets = rng.uniform(0.4, 2.5, G)
        thetas = np.full(G, theta)
        cases[G] = (ps, budgets, thetas)
        # warm both planes' (G-bucket, L, theta-bucket, K) programs
        sur_greedy_many(ps, b, budgets, K, key, thetas)
        _sur_greedy_many_hostgamma(ps, b, budgets, K, key, thetas)

    sentinel.snapshot()          # planner warm-ups done: timed loops start
    plan_rows = []
    plans_match = True
    for G in groups:
        ps, budgets, thetas = cases[G]
        t_host, t_fused = _time_all(
            [
                lambda: _sur_greedy_many_hostgamma(
                    ps, b, budgets, K, key, thetas
                ),
                lambda: sur_greedy_many(ps, b, budgets, K, key, thetas),
            ],
            repeats,
        )
        fused = sur_greedy_many(ps, b, budgets, K, key, thetas)
        host = _sur_greedy_many_hostgamma(ps, b, budgets, K, key, thetas)
        for f_r, h_r in zip(fused, host):
            plans_match &= _same_plan(f_r, h_r)
        row = {
            "groups": int(G),
            "hostgamma_s": t_host,
            "fused_s": t_fused,
            "speedup": t_host / t_fused,
        }
        plan_rows.append(row)
        print(
            f"raw speed planner G={G:3d}: hostgamma "
            f"{1e3 * t_host:7.1f}ms | fused {1e3 * t_fused:7.1f}ms | "
            f"{row['speedup']:5.2f}x"
        )
    timed_recompiles = sentinel.total()

    # -- donation on/off wave-loop timings -------------------------------
    wl = OracleWorkload(
        num_classes=K, num_clusters=5, num_arms=L, seed=seed + 1
    )
    T, emb, cid_h = wl.response_table(60 * 5, seed=seed + 2)
    assign, _ = kmeans(emb, 5, seed=0)
    est = SuccessProbEstimator(T, emb, assign)

    def mk(donate: bool):
        engine = PoolEngine(
            [OracleArm(f"d{i}", wl, i, seed=33) for i in range(L)]
        )
        return ThriftRouter(
            engine, est, num_classes=K, donate_buffers=donate
        )

    router_d, router_nd = mk(True), mk(False)
    budget = float(np.quantile(router_d.engine.costs, 0.6)) * 2
    qrng = np.random.default_rng(seed + 3)
    cid, qemb, lab = wl.sample_queries(wave_batch, qrng)
    queries = np.column_stack([cid, lab])
    res_d = router_d.route_batch(queries, qemb, budget)     # warm + result
    res_nd = router_nd.route_batch(queries, qemb, budget)   # (nodonate twin
    # owns a separate jit cache: this warm-up is its first-ever compile)
    donation_match = bool(
        np.array_equal(res_d.predictions, res_nd.predictions)
        and np.array_equal(res_d.costs, res_nd.costs)
        and np.array_equal(res_d.planned_costs, res_nd.planned_costs)
        and res_d.arms_used == res_nd.arms_used
    )
    sentinel.snapshot()          # donation warm-ups done: timed loop starts
    t_d, t_nd = _time_all(
        [
            lambda: router_d.route_batch(queries, qemb, budget),
            lambda: router_nd.route_batch(queries, qemb, budget),
        ],
        wave_repeats,
    )
    donation = {
        "batch": int(wave_batch),
        "donate_s": t_d,
        "nodonate_s": t_nd,
        "nodonate_over_donate": t_nd / t_d,
        "bit_identical": donation_match,
    }
    print(
        f"raw speed donation B={wave_batch}: donate {1e3 * t_d:7.2f}ms | "
        f"nodonate {1e3 * t_nd:7.2f}ms ({donation['nodonate_over_donate']:.2f}x)"
        f" | bit-identical {donation_match}"
    )
    timed_recompiles += sentinel.total()

    # -- cold-start replan latency vs the persistent compile cache -------
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cold = _cold_start_cache(repo_root) if cold_start else {
        "skipped": True, "reason": "disabled"
    }
    if cold.get("skipped"):
        print(f"raw speed cold-start: skipped ({cold.get('reason')})")
    else:
        print(
            f"raw speed cold-start: first {cold['first_plan_s']:6.2f}s | "
            f"cache-warmed {cold['second_plan_s']:6.2f}s "
            f"({cold['speedup']:.2f}x, {cold['cache_entries']} cache entries)"
        )

    from repro.kernels.ops import kernel_compile_probe

    return {
        "planner": {
            "rows": plan_rows,
            "groups_max": int(max(groups)),
            "speedup_at_max": plan_rows[-1]["speedup"],
            "plans_match": plans_match,
            "theta": theta,
        },
        "donation": donation,
        "cold_start": cold,
        "kernel_compile": kernel_compile_probe(),
        "timed_recompiles": int(timed_recompiles),
    }


def _time_all(fns, repeats: int):
    """Best-of-``repeats`` wall time per engine, *interleaved* round-robin
    so a load spike on the shared host penalizes every engine equally
    instead of whichever happened to be mid-measurement."""
    best = [np.inf] * len(fns)
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def run(args) -> dict:
    wl = OracleWorkload(
        num_classes=args.classes, num_clusters=args.clusters, num_arms=args.arms, seed=3
    )
    T, emb, _ = wl.response_table(args.history)
    assign, _ = kmeans(emb, args.clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)

    engine = PoolEngine([OracleArm(f"a{i}", wl, i, seed=11) for i in range(args.arms)])
    seed_engine = PoolEngine(
        [_SeedOracleArm(f"s{i}", wl, i, seed=11) for i in range(args.arms)]
    )
    router = ThriftRouter(engine, est, num_classes=args.classes)
    budget = float(np.quantile(engine.costs, 0.7)) * 2

    batches = args.batches or BATCH_SIZES
    rows = []
    rng = np.random.default_rng(17)
    # thriftlint's runtime half: count actual XLA compilations of the wave
    # program and the batched planner across the whole bench, and demand
    # that the *timed* sections never compile (all compiles live in the
    # per-bucket warm-ups).
    sentinel = CompileSentinel(
        {"wave": router_mod._wave_scan, "plan": selection_mod._sur_greedy_scan}
    )
    timed_recompiles = 0
    for B in batches:
        cid, qemb, lab = wl.sample_queries(B, rng)
        # (B, 2) payload array: what a serving front-end hands the engine
        # (same input to all three engines; avoids per-call list conversion)
        queries = np.column_stack([cid, lab])
        # warm-up: populates the plan/selection caches and compiles the
        # jitted wave loop for this (B, T) bucket, for all three engines
        res = router.route_batch(queries, qemb, budget)
        router.route_batch_reference(queries, qemb, budget)
        seed_route_batch(router, seed_engine, queries, qemb, budget)

        # the interesting scaling story lives at the big batches — sample
        # them harder so best-of converges despite shared-host noise
        sentinel.snapshot()          # warm-ups done: timed runs must not compile
        reps = args.repeats * (3 if B >= 512 else 1)
        t_jit, t_wave = _time_all(
            [
                lambda: router.route_batch(queries, qemb, budget),
                lambda: router.route_batch_reference(queries, qemb, budget),
            ],
            reps,
        )
        (t_seed,) = _time_all(
            [lambda: seed_route_batch(router, seed_engine, queries, qemb, budget)],
            max(1, args.repeats // 2),
        )
        res = router.route_batch(queries, qemb, budget)
        row = {
            "batch": B,
            "qps": B / t_jit,                       # jitted engine (route_batch)
            "wavefront_qps": B / t_wave,            # PR 1 compacting wavefront
            "seed_qps": B / t_seed,
            "speedup": t_seed / t_jit,              # jit vs seed
            "jit_over_wavefront": t_wave / t_jit,   # PR 2 vs PR 1
            "waves": int(res.waves),
            "mean_realized_cost": float(res.costs.mean()),
            "mean_planned_cost": float(res.planned_costs.mean()),
            "realized_over_planned": float(res.costs.sum() / res.planned_costs.sum()),
            "accuracy": float((res.predictions == lab).mean()),
        }
        timed_recompiles += sentinel.total()
        rows.append(row)
        print(
            f"batch {B:5d}: jit {row['qps']:9.0f} qps | wavefront "
            f"{row['wavefront_qps']:9.0f} ({row['jit_over_wavefront']:4.2f}x) | "
            f"seed {row['seed_qps']:8.0f} ({row['speedup']:4.1f}x) | "
            f"realized/planned {row['realized_over_planned']:.3f} | "
            f"acc {row['accuracy']:.3f}"
        )

    # continuous-batching front-end under a steady-state arrival process
    steady = steady_state(
        router, wl, budget, batch=args.steady_batch,
        n_queries=args.steady_queries or 8 * args.steady_batch,
        load=args.load,
    )
    print(
        f"steady-state (max_batch {steady['max_batch']}): saturated "
        f"{steady['saturated_qps']:9.0f} qps "
        f"({steady['vs_jit_engine']:4.2f}x one-shot jit, paired)"
        f" | offered {steady['offered_qps']:9.0f} -> {steady['steady_qps']:9.0f} qps"
        f" | p50 {steady['p50_ms']:.2f}ms p99 {steady['p99_ms']:.2f}ms"
        f" | planes jit={steady['spec_jit']} ref={steady['spec_reference']}"
    )

    # R-replica serving plane: qps/p99 vs R at fixed per-replica batch
    def make_router():
        eng = PoolEngine(
            [OracleArm(f"r{i}", wl, i, seed=61) for i in range(args.arms)]
        )
        return ThriftRouter(eng, est, num_classes=args.classes)

    replica = replica_scaling(
        router, wl, budget, per_batch=args.replica_batch,
        make_router=make_router,
        repeats=max(2 if args.smoke else 6, args.repeats // 4),
    )
    print(
        f"replica scaling: {replica['speedup_at_max']:.2f}x aggregate qps at "
        f"R={replica['replicas_max']} (per-replica batch "
        f"{replica['per_replica_batch']}) | R=1 bit-matches steady path: "
        f"{replica['r1_bitmatch_steady']} | timed recompiles "
        f"{replica['timed_recompiles']}"
    )

    # cross-device placement curve (overlapped-R-devices vs fused-1-device)
    replica["cross_device"] = cross_device(
        router, wl, budget, per_batch=args.replica_batch,
        make_router=make_router,
        repeats=2 if args.smoke else max(3, args.repeats // 8),
        wave_rows_per_device=1024 if args.smoke else 4096,
    )
    cd = replica["cross_device"]
    if cd.get("skipped"):
        print("cross-device: skipped (single-device process — run under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    else:
        print(
            f"cross-device: serving {cd['overlapped_vs_fused_at_max']:.2f}x, "
            f"wave-plane {cd['wave_overlapped_vs_fused_at_max']:.2f}x "
            f"overlapped-vs-fused at R={cd['replicas_max']} on "
            f"{cd['devices']} device(s) / {cd['host_cores']} core(s) "
            f"(parallel-capable: {cd['parallel_capable']}) | R=1 bit-match "
            f"{cd['r1_bitmatch']} | timed recompiles {cd['timed_recompiles']}"
        )

    # batched planner: serial vs batched drift-replan latency
    selection = selection_replan(
        args.arms, args.classes, history=args.selection_history,
        repeats=args.selection_repeats,
    )
    print(
        f"selection replan: {selection['speedup_at_max']:.2f}x batched over "
        f"serial at G={selection['groups_max']} drifted clusters "
        f"(plans match: {selection['plans_match']})"
    )

    # raw-speed pass: fused on-device planner vs PR 9 host-gamma plane,
    # donated vs non-donated wave dispatch, cold-start compile cache
    raw = raw_speed(
        args.arms, args.classes,
        repeats=args.raw_repeats,
        wave_batch=min(256, max(batches)),
        wave_repeats=max(4, args.repeats // 2),
        cold_start=not args.no_cold_start,
    )
    print(
        f"raw speed: planner {raw['planner']['speedup_at_max']:.2f}x fused "
        f"over hostgamma at G={raw['planner']['groups_max']} (plans match: "
        f"{raw['planner']['plans_match']}) | donation bit-identical "
        f"{raw['donation']['bit_identical']} | timed recompiles "
        f"{raw['timed_recompiles']}"
    )

    # online estimation feedback on drifted traffic
    feedback = feedback_drift(
        args.classes, args.arms, history=args.feedback_history,
        chunks=args.feedback_chunks, chunk=args.feedback_chunk,
    )
    print(
        f"feedback (drifted traffic): online {feedback['online_acc']:.3f} "
        f"vs oracle {feedback['oracle_acc']:.3f} "
        f"({feedback['recovery']:.2f} recovery) vs frozen "
        f"{feedback['frozen_acc']:.3f} ({feedback['frozen_vs_oracle']:.2f})"
        f" | drifts {feedback['feedback_drifts']} replans "
        f"{feedback['plan_stale_dropped']} | steady overhead "
        f"{feedback['steady_overhead_vs_frozen']:.2f}x frozen, replans "
        f"{feedback['replan_time_s']:.2f}s over {feedback['drift_chunks']} chunks"
    )

    # failure plane: accuracy + p99 under an injected 2-arm outage
    fault = fault_tolerance(
        args.classes, args.arms, history=args.feedback_history,
        chunks=args.feedback_chunks, chunk=args.feedback_chunk,
    )
    print(
        f"fault tolerance (2-arm outage {fault['dead_arms']}): baseline "
        f"{fault['baseline_acc']:.3f} | frozen {fault['frozen_acc']:.3f} "
        f"({fault['frozen_recovery']:.2f}) | failover "
        f"{fault['failover_acc']:.3f} ({fault['failover_recovery']:.2f}) | "
        f"failover+replan {fault['replan_acc']:.3f} "
        f"({fault['replan_recovery']:.2f}) | failures folded "
        f"{fault['degradation_failures']}, drifts {fault['feedback_drifts']}"
    )

    # compile-bucket budgets: every wave program is keyed by a (B, T)
    # bucket pair and every planner program by a (G, theta) bucket pair, so
    # the whole bench — including the continuous-batching steady state and
    # every drift replan — may compile at most |buckets| programs, and the
    # timed row sections exactly zero.
    wave_b = {bucket_size(n, 8) for n in range(1, max(
        list(batches) + [args.steady_batch, 4 * args.replica_batch]) + 1)}
    cd = replica.get("cross_device", {})
    wp = cd.get("wave_plane")
    if wp:   # cross-device wave-plane shapes join the bucket census
        wave_b.add(bucket_size(wp["rows_per_device"], 8))
        for r in wp["rows"]:
            wave_b.add(bucket_size(r["rows_total"], 8))
    wave_t = {bucket_size(t, 4) for t in range(1, args.arms + 1)}
    plan_g = {bucket_size(g, 8) for g in range(1, 129)}
    plan_theta = {bucket_size(t, 4) for t in range(1, 4097)}
    # the jit cache keys executables by (bucket, device): a multi-device
    # process may legitimately hold one copy of a bucket program per device
    n_devices = max(1, int(cd.get("devices", 1)))
    timed_recompiles += raw["timed_recompiles"]   # raw_speed's own sentinel
    compile_sentinel = {
        "timed_recompiles": timed_recompiles,
        "wave_compiles": compile_cache_size(sentinel.entries["wave"]),
        "wave_bucket_budget": len(wave_b) * len(wave_t) * n_devices,
        "plan_compiles": compile_cache_size(sentinel.entries["plan"]),
        "plan_bucket_budget": len(plan_g) * len(plan_theta),
    }
    compile_sentinel["within_budget"] = bool(
        timed_recompiles == 0
        and compile_sentinel["wave_compiles"]
        <= compile_sentinel["wave_bucket_budget"]
        and compile_sentinel["plan_compiles"]
        <= compile_sentinel["plan_bucket_budget"]
    )
    print(
        f"compile sentinel: wave {compile_sentinel['wave_compiles']}"
        f"/{compile_sentinel['wave_bucket_budget']} bucket programs, plan "
        f"{compile_sentinel['plan_compiles']}"
        f"/{compile_sentinel['plan_bucket_budget']}, timed-section "
        f"recompiles {timed_recompiles} (budget holds: "
        f"{compile_sentinel['within_budget']})"
    )

    report = {
        "bench": "serving_throughput",
        "engine": "continuous-batching",
        "pool": {
            "arms": args.arms,
            "classes": args.classes,
            "clusters": args.clusters,
            "budget": budget,
        },
        "rows": rows,
        "steady_state": steady,
        "replica_scaling": replica,
        "selection": selection,
        "raw_speed": raw,
        "feedback": feedback,
        "fault_tolerance": fault,
        "compile_sentinel": compile_sentinel,
        "plan_cache": router.plans.stats(),
        "history": _load_history(args.out),
    }
    for key, field in (
        ("speedup_at_256", "speedup"),
        ("jit_over_wavefront_at_1024", "jit_over_wavefront"),
    ):
        vals = [r[field] for r in rows if r["batch"] == int(key.rsplit("_", 1)[1])]
        if vals:
            report[key] = vals[0]
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    msg = ", ".join(
        f"{k} = {report[k]:.1f}x"
        for k in ("speedup_at_256", "jit_over_wavefront_at_1024")
        if k in report
    )
    print(f"wrote {args.out} ({msg})" if msg else f"wrote {args.out}")
    return report


def _load_history(path: str) -> list:
    """Earlier reports at ``path`` become compact history entries (summary
    scalars + per-batch qps, not full rows), so the file keeps the whole
    seed -> wavefront -> jitted trajectory across PRs without ballooning."""
    try:
        with open(path) as f:
            prev = json.load(f)
    except (OSError, ValueError):
        return []
    history = prev.get("history", [])
    entry = {
        "engine": prev.get("engine", "wavefront"),   # pre-PR2 reports
        "pool": prev.get("pool"),
        "qps": {str(r["batch"]): r["qps"] for r in prev.get("rows", []) if "qps" in r},
    }
    for key in ("speedup_at_256", "jit_over_wavefront_at_1024"):
        if key in prev:
            entry[key] = prev[key]
    steady = prev.get("steady_state")
    if steady:
        entry["steady_state"] = {
            k: steady[k]
            for k in ("max_batch", "saturated_qps", "steady_qps",
                      "p50_ms", "p99_ms", "vs_jit_engine")
            if k in steady
        }
    replica = prev.get("replica_scaling")
    if replica:
        entry["replica_scaling"] = {
            k: replica[k]
            for k in ("per_replica_batch", "replicas_max", "speedup_at_max",
                      "r1_bitmatch_steady")
            if k in replica
        }
        entry["replica_scaling"]["qps"] = {
            str(r["replicas"]): r["qps"] for r in replica.get("rows", [])
        }
        cd = replica.get("cross_device")
        if cd and not cd.get("skipped"):
            entry["replica_scaling"]["cross_device"] = {
                k: cd[k]
                for k in ("devices", "host_cores", "parallel_capable",
                          "overlapped_vs_fused_at_max",
                          "wave_overlapped_vs_fused_at_max", "r1_bitmatch")
                if k in cd
            }
    feedback = prev.get("feedback")
    if feedback:
        entry["feedback"] = {
            k: feedback[k]
            for k in ("online_acc", "oracle_acc", "frozen_acc", "recovery",
                      "overhead_vs_frozen")
            if k in feedback
        }
    selection = prev.get("selection")
    if selection:
        entry["selection"] = {
            k: selection[k]
            for k in ("groups_max", "speedup_at_max", "plans_match")
            if k in selection
        }
    raw = prev.get("raw_speed")
    if raw:
        planner = raw.get("planner", {})
        entry["raw_speed"] = {
            k: planner[k]
            for k in ("groups_max", "speedup_at_max", "plans_match")
            if k in planner
        }
        donation = raw.get("donation", {})
        if donation:
            entry["raw_speed"]["donation_bit_identical"] = donation.get(
                "bit_identical"
            )
            entry["raw_speed"]["nodonate_over_donate"] = donation.get(
                "nodonate_over_donate"
            )
        cold = raw.get("cold_start", {})
        if cold and not cold.get("skipped"):
            entry["raw_speed"]["cold_start_speedup"] = cold.get("speedup")
    fault = prev.get("fault_tolerance")
    if fault:
        entry["fault_tolerance"] = {
            k: fault[k]
            for k in ("baseline_acc", "frozen_recovery", "failover_recovery",
                      "replan_recovery", "dead_arms")
            if k in fault
        }
    history.append(entry)
    return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arms", type=int, default=12)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--history", type=int, default=2000)
    ap.add_argument("--repeats", type=int, default=25)
    ap.add_argument("--batches", type=int, nargs="*", default=None)
    ap.add_argument(
        "--steady-batch", type=int, default=256,
        help="admission batch size of the steady-state front-end run",
    )
    ap.add_argument(
        "--steady-queries", type=int, default=None,
        help="request-stream length for the steady-state run (default 8x batch)",
    )
    ap.add_argument(
        "--replica-batch", type=int, default=24,
        help="fixed per-replica admission batch for the replica_scaling sweep",
    )
    ap.add_argument(
        "--load", type=float, default=0.7,
        help="steady-state offered load as a fraction of measured capacity",
    )
    ap.add_argument(
        "--feedback-chunks", type=int, default=8,
        help="drifted-traffic chunks streamed through the feedback loop",
    )
    ap.add_argument(
        "--feedback-chunk", type=int, default=256,
        help="requests per drifted-traffic chunk",
    )
    ap.add_argument(
        "--feedback-history", type=int, default=120,
        help="historical responses per cluster for the feedback scenario",
    )
    ap.add_argument(
        "--selection-history", type=int, default=120,
        help="historical responses per cluster for the replan scenario",
    )
    ap.add_argument(
        "--selection-repeats", type=int, default=3,
        help="best-of rounds for the serial-vs-batched replan timing",
    )
    ap.add_argument(
        "--raw-repeats", type=int, default=5,
        help="best-of rounds for the raw-speed planner timings",
    )
    ap.add_argument(
        "--no-cold-start", action="store_true",
        help="skip the two-subprocess persistent-compile-cache measurement",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny sweep for CI: small batches, few repeats",
    )
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    if args.smoke:
        args.batches = args.batches or [32, 64]
        args.repeats = min(args.repeats, 2)
        args.history = min(args.history, 600)
        args.steady_batch = min(args.steady_batch, 64)
        args.steady_queries = args.steady_queries or 4 * args.steady_batch
        args.replica_batch = min(args.replica_batch, 32)
        args.feedback_chunks = min(args.feedback_chunks, 6)
        args.feedback_chunk = min(args.feedback_chunk, 128)
        args.feedback_history = min(args.feedback_history, 80)
        args.selection_history = min(args.selection_history, 60)
        args.selection_repeats = min(args.selection_repeats, 2)
        args.raw_repeats = min(args.raw_repeats, 2)
    run(args)


if __name__ == "__main__":
    main()
