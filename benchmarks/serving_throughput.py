"""Serving throughput: batched wavefront engine vs the seed router.

Sweeps batch sizes on an oracle pool and reports queries/sec plus realized-
vs-planned cost for the vectorized ``ThriftRouter.route_batch``, against a
faithful reproduction of the seed implementation (per-query Python belief
updates in the wave loop AND a per-query Python loop inside the oracle arm).
Writes ``BENCH_serving.json`` so later PRs have a perf trajectory.

Run:  PYTHONPATH=src python -m benchmarks.serving_throughput [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List

import numpy as np

from repro.core.belief import empty_log_belief, log_weight
from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.core.types import clip_probs
from repro.data import OracleWorkload
from repro.serving import OracleArm, PoolEngine, ThriftRouter

BATCH_SIZES = [32, 64, 128, 256, 512, 1024]


@dataclasses.dataclass
class _SeedOracleArm:
    """Seed-commit oracle arm: one workload.invoke per query (Python loop)."""

    name: str
    workload: OracleWorkload
    arm_index: int
    seed: int = 0

    def __post_init__(self):
        self.cost = float(self.workload.costs[self.arm_index])
        self._rng = np.random.default_rng(self.seed + 7919 * self.arm_index)

    def classify_batch(self, queries) -> np.ndarray:
        out = np.empty(len(queries), np.int64)
        for i, (cid, label) in enumerate(queries):
            out[i] = self.workload.invoke(self.arm_index, cid, label, self._rng)
        return out

    def latency_s(self, batch: int) -> float:
        return 0.0


def _seed_lookup_batch(est: SuccessProbEstimator, embeddings: np.ndarray) -> np.ndarray:
    """Seed-commit lookup_batch: full (B, C, d) difference tensor."""
    d = ((embeddings[:, None, :] - est._centroids[None, :, :]) ** 2).sum(-1)
    return est._cids[np.argmin(d, axis=1)]


def seed_route_batch(router: ThriftRouter, engine: PoolEngine, queries, embeddings, budget):
    """The seed ``ThriftRouter.route_batch``, verbatim modulo imports: per-
    cluster groups routed serially, per-query Python loops updating beliefs."""
    B = len(queries)
    K = router.num_classes
    cluster_ids = _seed_lookup_batch(router.estimator, embeddings)

    predictions = np.zeros(B, np.int64)
    costs = np.zeros(B, np.float64)
    planned = np.zeros(B, np.float64)
    arms_used: List[List[int]] = [[] for _ in range(B)]

    for cid in np.unique(cluster_ids):
        q_idx = np.flatnonzero(cluster_ids == cid)
        stats = router.estimator.clusters[int(cid)]
        p = stats.p_hat
        sel = router.selector.select(p, K, budget)
        order = sorted(sel.chosen, key=lambda i: -p[i])
        w = log_weight(clip_probs(p), K)
        empty = empty_log_belief(p)

        nb = q_idx.size
        beliefs = np.full((nb, K), empty, np.float64)
        counts = np.zeros((nb, K), np.int64)
        active = np.ones(nb, bool)
        planned[q_idx] = float(engine.costs[order].sum()) if order else 0.0

        for wave, arm in enumerate(order):
            log_f = float(np.sum(w[order[wave:]]))
            srt = np.sort(beliefs, axis=1)
            h1, h2 = srt[:, -1], srt[:, -2]
            still = active & (log_f + h2 > h1 - 1e-9)
            if not still.any():
                break
            full_active = np.zeros(B, bool)
            full_active[q_idx[still]] = True
            resp = engine.invoke_arm(arm, queries, full_active)[q_idx]
            hit = np.flatnonzero(still)
            for j in hit:
                r = int(resp[j])
                if counts[j, r] == 0:
                    beliefs[j, r] = w[arm]
                else:
                    beliefs[j, r] += w[arm]
                counts[j, r] += 1
                costs[q_idx[j]] += engine.costs[arm]
                arms_used[q_idx[j]].append(arm)
            active = still

        predictions[q_idx] = np.argmax(beliefs, axis=1)
    return predictions, costs, planned


def _time(fn, repeats: int) -> float:
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(args) -> dict:
    wl = OracleWorkload(
        num_classes=args.classes, num_clusters=args.clusters, num_arms=args.arms, seed=3
    )
    T, emb, _ = wl.response_table(args.history)
    assign, _ = kmeans(emb, args.clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)

    engine = PoolEngine([OracleArm(f"a{i}", wl, i, seed=11) for i in range(args.arms)])
    seed_engine = PoolEngine(
        [_SeedOracleArm(f"s{i}", wl, i, seed=11) for i in range(args.arms)]
    )
    router = ThriftRouter(engine, est, num_classes=args.classes)
    budget = float(np.quantile(engine.costs, 0.7)) * 2

    rows = []
    rng = np.random.default_rng(17)
    for B in BATCH_SIZES:
        cid, qemb, lab = wl.sample_queries(B, rng)
        queries = list(zip(cid, lab))
        # warm-up: populates the per-(cluster, budget) selection cache for both
        res = router.route_batch(queries, qemb, budget)
        seed_route_batch(router, seed_engine, queries, qemb, budget)

        t_new = _time(lambda: router.route_batch(queries, qemb, budget), args.repeats)
        t_seed = _time(
            lambda: seed_route_batch(router, seed_engine, queries, qemb, budget),
            max(1, args.repeats // 2),
        )
        res = router.route_batch(queries, qemb, budget)
        row = {
            "batch": B,
            "qps": B / t_new,
            "seed_qps": B / t_seed,
            "speedup": t_seed / t_new,
            "waves": int(res.waves),
            "mean_realized_cost": float(res.costs.mean()),
            "mean_planned_cost": float(res.planned_costs.mean()),
            "realized_over_planned": float(res.costs.sum() / res.planned_costs.sum()),
            "accuracy": float((res.predictions == lab).mean()),
        }
        rows.append(row)
        print(
            f"batch {B:5d}: {row['qps']:9.0f} qps (seed {row['seed_qps']:8.0f}, "
            f"{row['speedup']:4.1f}x) | realized/planned cost "
            f"{row['realized_over_planned']:.3f} | acc {row['accuracy']:.3f}"
        )

    report = {
        "bench": "serving_throughput",
        "pool": {
            "arms": args.arms,
            "classes": args.classes,
            "clusters": args.clusters,
            "budget": budget,
        },
        "rows": rows,
        "speedup_at_256": next(r["speedup"] for r in rows if r["batch"] == 256),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out} (speedup@256 = {report['speedup_at_256']:.1f}x)")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arms", type=int, default=12)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--history", type=int, default=2000)
    ap.add_argument("--repeats", type=int, default=9)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    run(args)


if __name__ == "__main__":
    main()
