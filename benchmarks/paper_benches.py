"""One benchmark per paper table/figure (synthetic-workload analogues).

Each function returns (us_per_call, derived) where ``derived`` is the
headline metric of the corresponding paper artifact. ``run.py`` prints the
``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.core import (
    FrugalCascade,
    McXiEstimator,
    adaptive_invoke,
    blender_all,
    gamma_value_batch,
    greedy,
    single_best,
    sur_greedy,
    theta_for,
    topk_weighted,
)
from repro.core.belief import aggregate_predict
from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import OracleArm, PoolEngine, ThriftRouter

BUDGETS = [1e-5, 5e-5, 1e-4, 5e-4, 1e-3]

# Five synthetic text-classification suites standing in for the paper's
# datasets (Table 2): (name, K, clusters, skill_spread).
SUITES = [
    ("overruling", 2, 3, 0.15),
    ("agnews", 4, 6, 0.25),
    ("sciq", 4, 5, 0.2),
    ("hellaswag", 4, 8, 0.35),
    ("banking77", 77, 10, 0.3),
]
# Entity-matching suites (Table 3): binary with skewed class balance.
EM_SUITES = [
    ("wdc", 2, 4, 0.3),
    ("abt-buy", 2, 4, 0.25),
    ("walmart-amazon", 2, 5, 0.3),
    ("amazon-google", 2, 5, 0.35),
    ("dblp-scholar", 2, 3, 0.15),
]


def _setup(K, clusters, spread, seed=0, n_hist=2000):
    wl = OracleWorkload(
        num_classes=K, num_clusters=clusters, num_arms=12, seed=seed,
        skill_spread=spread,
    )
    engine = PoolEngine([OracleArm(f"a{i}", wl, i, seed=seed + 1) for i in range(12)])
    T, emb, _ = wl.response_table(n_hist, seed=seed + 2)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    router = ThriftRouter(engine, est, num_classes=K, seed=seed)
    return wl, engine, est, router


def _test_queries(wl, n, seed=42):
    rng = np.random.default_rng(seed)
    cid, emb, lab = wl.sample_queries(n, rng)
    return list(zip(cid, lab)), emb, lab


def accuracy_vs_cost(n=400) -> Tuple[float, str]:
    """Fig. 4: accuracy at each budget, averaged over the 5 suites."""
    t0 = time.time()
    accs = {b: [] for b in BUDGETS}
    for name, K, cl, spread in SUITES:
        wl, engine, est, router = _setup(K, cl, spread, seed=hash(name) % 997)
        queries, emb, lab = _test_queries(wl, n)
        for b in BUDGETS:
            res = router.route_batch(queries, emb, b)
            assert (res.costs <= b + 1e-15).all()
            accs[b].append((res.predictions == lab).mean())
    us = (time.time() - t0) * 1e6 / (n * len(SUITES) * len(BUDGETS))
    derived = ";".join(f"B={b:.0e}:acc={np.mean(a):.3f}" for b, a in accs.items())
    return us, derived


def entity_matching(n=400) -> Tuple[float, str]:
    """Fig. 5: F1 on binary suites at mid budget."""
    t0 = time.time()
    f1s = []
    for name, K, cl, spread in EM_SUITES:
        wl, engine, est, router = _setup(K, cl, spread, seed=hash(name) % 499)
        queries, emb, lab = _test_queries(wl, n)
        res = router.route_batch(queries, emb, 1e-4)
        tp = ((res.predictions == 1) & (lab == 1)).sum()
        fp = ((res.predictions == 1) & (lab == 0)).sum()
        fn = ((res.predictions == 0) & (lab == 1)).sum()
        f1 = 2 * tp / max(2 * tp + fp + fn, 1)
        f1s.append(f1)
    us = (time.time() - t0) * 1e6 / (n * len(EM_SUITES))
    return us, f"meanF1={np.mean(f1s):.3f}"


def adaptive_saving(n=300) -> Tuple[float, str]:
    """Fig. 6: ThriftLLM realized cost vs SurGreedy planned cost."""
    t0 = time.time()
    wl, engine, est, router = _setup(4, 6, 0.25, seed=3)
    queries, emb, lab = _test_queries(wl, n)
    savings, accs = [], []
    for b in BUDGETS:
        res = router.route_batch(queries, emb, b)
        savings.append(1 - res.costs.sum() / max(res.planned_costs.sum(), 1e-15))
        accs.append((res.predictions == lab).mean())
    us = (time.time() - t0) * 1e6 / (n * len(BUDGETS))
    return us, (
        f"saving_min={min(savings):.1%};saving_max={max(savings):.1%};acc@max={accs[-1]:.3f}"
    )


def vs_blender(n=300) -> Tuple[float, str]:
    """Table 5: best ThriftLLM accuracy vs use-all majority fusion."""
    t0 = time.time()
    rows = []
    for name, K, cl, spread in SUITES[:3]:
        wl, engine, est, router = _setup(K, cl, spread, seed=hash(name) % 997)
        queries, emb, lab = _test_queries(wl, n)
        res = router.route_batch(queries, emb, BUDGETS[-1])
        rng = np.random.default_rng(0)
        bl = np.mean([
            blender_all(wl.p_true.mean(0), K,
                        lambda a: wl.invoke(a, int(c), int(l), rng),
                        engine.costs).prediction == l
            for c, l in queries
        ])
        rows.append(((res.predictions == lab).mean(), bl))
    us = (time.time() - t0) * 1e6 / (2 * n * 3)
    th = np.mean([r[0] for r in rows])
    bl = np.mean([r[1] for r in rows])
    return us, f"thrift={th:.3f};blender={bl:.3f}"


def vs_single_llm(n=400) -> Tuple[float, str]:
    """Table 7: ThriftLLM vs strongest single arms."""
    t0 = time.time()
    wl, engine, est, router = _setup(4, 6, 0.25, seed=9)
    queries, emb, lab = _test_queries(wl, n)
    res = router.route_batch(queries, emb, BUDGETS[-1])
    th = (res.predictions == lab).mean()
    rng = np.random.default_rng(1)
    singles = []
    for a in np.argsort(-wl.p_true.mean(0))[:3]:
        singles.append(np.mean([
            wl.invoke(int(a), int(c), int(l), rng) == l for c, l in queries
        ]))
    us = (time.time() - t0) * 1e6 / (4 * n)
    return us, f"thrift={th:.3f};best_single={max(singles):.3f}"


def ci_robustness(n=300) -> Tuple[float, str]:
    """Table 6: accuracy across confidence-interval widths alpha."""
    t0 = time.time()
    wl, engine, est, router = _setup(4, 6, 0.25, seed=5)
    queries, emb, lab = _test_queries(wl, n)
    base = None
    spread = []
    for alpha in [0.0, 0.02, 0.04, 0.08, 0.1]:
        accs = []
        for bound in ("lo", "hi"):
            # perturb the cluster estimates by +/- alpha/2
            import copy

            est2 = copy.deepcopy(est)
            for c in est2.clusters.values():
                delta = -alpha / 2 if bound == "lo" else alpha / 2
                c.p_hat = np.clip(c.p_hat + delta, 0.01, 0.995)
            r2 = ThriftRouter(engine, est2, num_classes=4, seed=5)
            res = r2.route_batch(queries, emb, 1e-4)
            accs.append((res.predictions == lab).mean())
        if alpha == 0.0:
            base = np.mean(accs)
        spread.append(np.mean(accs))
    us = (time.time() - t0) * 1e6 / (n * 10)
    return us, f"base={base:.3f};max_dev={max(abs(s - base) for s in spread):.3f}"


def history_sensitivity(n=300) -> Tuple[float, str]:
    """Table 8: accuracy across historical-data fractions."""
    t0 = time.time()
    wl = OracleWorkload(num_classes=4, num_clusters=6, num_arms=12, seed=7)
    engine = PoolEngine([OracleArm(f"a{i}", wl, i, seed=8) for i in range(12)])
    T, emb, _ = wl.response_table(2000, seed=9)
    accs = []
    for frac in [0.2, 0.4, 0.6, 0.8, 1.0]:
        m = int(2000 * frac)
        assign, _ = kmeans(emb[:m], 6, seed=0)
        est = SuccessProbEstimator(T[:m], emb[:m], assign)
        router = ThriftRouter(engine, est, num_classes=4, seed=7)
        queries, qemb, lab = _test_queries(wl, n)
        res = router.route_batch(queries, qemb, 1e-4)
        accs.append((res.predictions == lab).mean())
    us = (time.time() - t0) * 1e6 / (n * 5)
    return us, f"min={min(accs):.3f};max={max(accs):.3f}"


def xi_vs_gamma(n_classes=4) -> Tuple[float, str]:
    """Fig. 11: greedy-on-xi vs greedy-on-gamma selection quality."""
    t0 = time.time()
    rng = np.random.default_rng(0)
    diffs, calls = [], 0
    from repro.core.correctness import xi_exact

    for s in range(40):
        p = rng.uniform(0.4, 0.95, 8)
        b = rng.uniform(0.1, 0.6, 8)
        budget = 1.0
        est = McXiEstimator(jax.random.key(s), p, n_classes, theta=8000)
        s1, _ = greedy(p, b, budget, est, empty_value=1 / n_classes)
        s2, _ = greedy(p, b, budget, gamma_value_batch(p), empty_value=0.0)
        x1 = xi_exact(p[s1], n_classes, p_all=p) if s1 else 1 / n_classes
        x2 = xi_exact(p[s2], n_classes, p_all=p) if s2 else 1 / n_classes
        diffs.append(x1 - x2)
        calls += 2
    us = (time.time() - t0) * 1e6 / calls
    return us, f"mean_xi_gain={np.mean(diffs):+.4f};max={np.max(diffs):.4f}"


def aggregation_ablation(n=500) -> Tuple[float, str]:
    """Fig. 14: ML belief vs weighted vote vs majority vote.

    Hard regime (wide skill spread, weak-arm-heavy ensembles at a tight
    budget) so the aggregators separate, as on the paper's AGNews/Hellaswag."""
    t0 = time.time()
    wl = OracleWorkload(
        num_classes=4, num_clusters=6, num_arms=12, seed=11,
        skill_spread=0.3, base_low=0.3, base_high=0.92,
    )
    engine = PoolEngine([OracleArm(f"a{i}", wl, i, seed=12) for i in range(12)])
    T, emb, _ = wl.response_table(2000, seed=13)
    assign, _ = kmeans(emb, 6, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    router = ThriftRouter(engine, est, num_classes=4, seed=11)
    queries, qemb, lab = _test_queries(wl, n)
    cl_of = est.lookup_batch(qemb)
    rng = np.random.default_rng(2)
    sel_cache = {}
    accs = {m: 0.0 for m in ("ml", "weighted", "majority")}
    for (cid, label), c in zip(queries, cl_of):
        p = est.clusters[int(c)].p_hat
        key = int(c)
        if key not in sel_cache:
            sel_cache[key] = router.selector.select(p, 4, 2.5e-5).chosen
        chosen = sel_cache[key]
        resp = np.asarray([wl.invoke(int(a), int(cid), int(label), rng) for a in chosen])
        for m in accs:
            pred = aggregate_predict(resp, p[chosen], 4, method=m, p_all=p)
            accs[m] += pred == label
    us = (time.time() - t0) * 1e6 / (3 * n)
    return us, ";".join(f"{m}={v/n:.3f}" for m, v in accs.items())


def selection_runtime() -> Tuple[float, str]:
    """Fig. 13: selection time vs (simulated) inference time.

    Selection runs once per (query-class, budget) and is cached by the
    router, so the amortized per-query cost is selection_ms / queries_per
    cluster; we report the raw per-selection latency after jit warm-up."""
    rng = np.random.default_rng(0)
    p = rng.uniform(0.4, 0.95, 12)
    b = np.geomspace(1e-6, 2e-4, 12)
    theta = theta_for(0.1, 0.01, float(p.max()), 12)
    sur_greedy(p, b, 1e-4, 4, jax.random.key(99), theta)  # compile warm-up
    t0 = time.time()
    n = 8
    for s in range(n):
        sur_greedy(p, b, 1e-4, 4, jax.random.key(s), theta)
    sel_s = (time.time() - t0) / n
    infer_s = 1.5  # simulated per-query pool inference latency (paper Fig 13)
    return sel_s * 1e6, (
        f"selection={sel_s*1e3:.1f}ms;frac_of_infer={sel_s/infer_s:.1%};"
        f"theta={theta};amortized_over_cluster=yes"
    )


def assumption_check(n_hist=1500) -> Tuple[float, str]:
    """App. B: semantic-similarity mapping vs random vs dissimilar."""
    t0 = time.time()
    wl = OracleWorkload(num_classes=4, num_clusters=6, num_arms=12, seed=13)
    T, emb, cid = wl.response_table(n_hist, seed=14)
    assign, cents = kmeans(emb, 6, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(3)
    tc, temb, _ = wl.sample_queries(400, rng)
    errs = {"ssm": [], "rm": [], "sdm": []}
    cids = list(est.clusters)
    for i in range(400):
        truth = wl.p_true[tc[i]]
        d = [np.linalg.norm(est.clusters[c].centroid - temb[i]) for c in cids]
        near = cids[int(np.argmin(d))]
        far = cids[int(np.argmax(d))]
        rand = cids[rng.integers(len(cids))]
        errs["ssm"].append(np.abs(est.clusters[near].p_hat - truth).mean())
        errs["rm"].append(np.abs(est.clusters[rand].p_hat - truth).mean())
        errs["sdm"].append(np.abs(est.clusters[far].p_hat - truth).mean())
    us = (time.time() - t0) * 1e6 / 1200
    return us, ";".join(f"{k}={np.mean(v):.4f}" for k, v in errs.items())


ALL = [
    ("fig4_accuracy_vs_cost", accuracy_vs_cost),
    ("fig5_entity_matching", entity_matching),
    ("fig6_adaptive_saving", adaptive_saving),
    ("table5_vs_blender", vs_blender),
    ("table6_ci_robustness", ci_robustness),
    ("table7_vs_single_llm", vs_single_llm),
    ("table8_history_sensitivity", history_sensitivity),
    ("fig11_xi_vs_gamma", xi_vs_gamma),
    ("fig13_selection_runtime", selection_runtime),
    ("fig14_aggregation_ablation", aggregation_ablation),
    ("appB_assumption_check", assumption_check),
]
