"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Kernel micro-benches and the
roofline report (from the dry-run artifacts) are appended when available.

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig4]
"""
from __future__ import annotations

import argparse
import sys
import time


def kernel_microbench():
    """Interpret-mode allclose + timing of each Pallas kernel vs oracle."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.belief import empty_log_belief, log_weight
    from repro.core.mc import sample_pool_responses
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    rows = []

    theta, L, K, C = 4096, 12, 4, 8
    p = rng.uniform(0.4, 0.95, L).astype(np.float32)
    resp = sample_pool_responses(jax.random.key(0), jnp.asarray(p), K, theta)
    masks = (rng.random((C, L)) < 0.6).astype(np.float32)
    w = jnp.asarray(log_weight(p, K), jnp.float32)
    empty = jnp.float32(empty_log_belief(p))
    t0 = time.time()
    got = ops.mc_correctness(resp, jnp.asarray(masks), w, empty, K)
    t_k = time.time() - t0
    want = ref.mc_correctness_ref(resp, jnp.asarray(masks), w, empty, K)
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    rows.append(("kernel_mc_correctness", t_k * 1e6, f"max_err={err:.1e}"))

    B, M = 256, 12
    responses = rng.integers(-1, K, (B, M)).astype(np.int32)
    bw = rng.uniform(0.3, 3.0, (B, M)).astype(np.float32)
    t0 = time.time()
    gb, gp = ops.belief_aggregate(jnp.asarray(responses), jnp.asarray(bw), empty, K)
    t_k = time.time() - t0
    wb, wp = ref.belief_aggregate_ref(jnp.asarray(responses), jnp.asarray(bw), empty, K)
    err = float(np.max(np.abs(np.asarray(gb) - np.asarray(wb))))
    rows.append(("kernel_belief_aggregate", t_k * 1e6 / B, f"max_err={err:.1e}"))

    q = jnp.asarray(rng.normal(0, 1, (1, 256, 4, 64)), jnp.float32)
    kv = jnp.asarray(rng.normal(0, 1, (1, 256, 2, 64)), jnp.float32)
    t0 = time.time()
    got = ops.flash_attention(q, kv, kv, causal=True, block_q=64, block_kv=64)
    t_k = time.time() - t0
    want = ref.flash_attention_ref(q, kv, kv, causal=True)
    err = float(np.max(np.abs(np.asarray(got, np.float32) - np.asarray(want, np.float32))))
    rows.append(("kernel_flash_attention", t_k * 1e6, f"max_err={err:.1e}"))

    la = -np.abs(rng.normal(0, 0.5, (2, 128, 256))).astype(np.float32)
    u = rng.normal(0, 1, (2, 128, 256)).astype(np.float32)
    h0 = np.zeros((2, 256), np.float32)
    t0 = time.time()
    gh, gl = ops.rglru_scan(la, u, h0)
    t_k = time.time() - t0
    wh, wl = ref.rglru_scan_ref(jnp.asarray(la), jnp.asarray(u), jnp.asarray(h0))
    err = float(np.max(np.abs(np.asarray(gh) - np.asarray(wh))))
    rows.append(("kernel_rglru_scan", t_k * 1e6, f"max_err={err:.1e}"))
    return rows


def roofline_report():
    """Summarize the dry-run roofline table (if artifacts exist)."""
    import glob
    import json

    import numpy as np

    recs = []
    for f in sorted(glob.glob("benchmarks/results/dryrun/*__16x16.json")):
        r = json.load(open(f))
        if "roofline" in r:
            recs.append(r)
    if not recs:
        return [("roofline_report", 0.0, "no dry-run artifacts (run repro.launch.dryrun --all)")]
    n_fit = sum(r["fits_hbm"] for r in recs)
    bottl = {}
    for r in recs:
        bottl[r["roofline"]["bottleneck"]] = bottl.get(r["roofline"]["bottleneck"], 0) + 1
    ratios = [r["useful_flops_ratio"] for r in recs if r["kind"] == "train"]
    return [(
        "roofline_summary", 0.0,
        f"cells={len(recs)};fits={n_fit};bottlenecks={bottl};"
        f"train_useful_flops_ratio_mean={np.mean(ratios):.2f}",
    )]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on bench name")
    args = ap.parse_args()

    from benchmarks.paper_benches import ALL

    print("name,us_per_call,derived")
    for name, fn in ALL:
        if args.only and args.only not in name:
            continue
        try:
            us, derived = fn()
            print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # keep the harness running
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}")
        sys.stdout.flush()
    if not args.only or "kernel" in args.only:
        for name, us, derived in kernel_microbench():
            print(f"{name},{us:.1f},{derived}")
    if not args.only or "roofline" in args.only:
        for name, us, derived in roofline_report():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
