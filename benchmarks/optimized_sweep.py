"""Optimized dry-run sweep: every (arch x shape) cell with its per-arch best
settings from the §Perf iterations, producing the beyond-paper roofline
table (compare against the paper-faithful baseline in results/dryrun).

Run:  PYTHONPATH=src python -m benchmarks.optimized_sweep [--out ...]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import traceback

# Per-arch optimized knobs (see EXPERIMENTS.md §Perf for the measurements
# motivating each): bucketed causal attention for every self-attention arch,
# int8 KV for every decode cache, shard_map EP for MoE, zero3 for
# indivisible-head archs, plain FSDPxTP elsewhere.
OPT = {
    "moonshot-v1-16b-a3b": (dict(), dict(attn_buckets=8, kv_quant="int8", moe_ep=True)),
    "granite-moe-1b-a400m": (dict(fsdp=None), dict(attn_buckets=8, kv_quant="int8", moe_ep=True)),
    "falcon-mamba-7b": (dict(), dict()),
    "internvl2-2b": (dict(), dict(attn_buckets=8, kv_quant="int8")),
    "h2o-danube-1.8b": (dict(), dict(attn_buckets=8, kv_quant="int8")),
    "qwen1.5-110b": (dict(), dict(attn_buckets=8, kv_quant="int8")),
    "starcoder2-7b": (dict(zero3=True), dict(attn_buckets=8, kv_quant="int8")),
    "smollm-135m": (dict(), dict(attn_buckets=8, kv_quant="int8")),
    "recurrentgemma-9b": (dict(), dict(attn_buckets=8, kv_quant="int8")),
    "musicgen-medium": (dict(), dict(attn_buckets=8, kv_quant="int8")),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/dryrun_opt")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    from repro.launch.dryrun import dryrun_cell
    from repro.models import SHAPES

    for arch, (ro, co) in OPT.items():
        for shape in SHAPES:
            tag = f"{arch}__{shape}__16x16"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                continue
            try:
                rec = dryrun_cell(arch, shape, multi_pod=False,
                                  rules_overrides=ro or None, cfg_overrides=co or None)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": "16x16",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
                print(f"FAIL {tag}: {rec['error']}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=float)


if __name__ == "__main__":
    main()
