"""Render the EXPERIMENTS.md roofline tables from the dry-run artifacts.

Run:  PYTHONPATH=src python -m benchmarks.roofline_table [--dir benchmarks/results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json


def load(dirname: str, mesh: str):
    recs = []
    for f in sorted(glob.glob(f"{dirname}/*__{mesh}.json")):
        recs.append(json.load(open(f)))
    return recs


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


def render(dirname: str) -> str:
    out = []
    recs = load(dirname, "16x16")
    out.append(
        "| arch | shape | kind | mem/chip GB | fits | compute ms | memory ms | "
        "collective ms | bottleneck | useful-FLOPs ratio |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    shape_order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    recs.sort(key=lambda r: (r["arch"], shape_order.get(r["shape"], 9)))
    for r in recs:
        if "skipped" in r:
            out.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | "
                f"skip (full attn @500k) | — |"
            )
            continue
        t = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{r['analytic_memory']['total']/1e9:.2f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} | "
            f"{fmt_ms(t['compute_s'])} | {fmt_ms(t['memory_s'])} | "
            f"{fmt_ms(t['collective_s'])} | {t['bottleneck'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} |"
        )
    # multi-pod pass summary
    mrecs = [r for r in load(dirname, "2x16x16") if "skipped" not in r]
    ok = sum(1 for r in mrecs if "error" not in r)
    out.append("")
    out.append(
        f"Multi-pod (2x16x16 = 512 chips) pass: {ok}/{len(mrecs)} cells "
        "lower+compile successfully (the pod axis shards batch jointly with data)."
    )
    return "\n".join(out)


def render_compare(base_dir: str, opt_dir: str) -> str:
    """Baseline vs optimized table (step lower bounds and dominant terms)."""
    base = {(r["arch"], r["shape"]): r for r in load(base_dir, "16x16") if "roofline" in r}
    opt = {(r["arch"], r["shape"]): r for r in load(opt_dir, "16x16") if "roofline" in r}
    out = [
        "| arch | shape | baseline bound (ms) | optimized bound (ms) | speedup | "
        "baseline bottleneck | optimized bottleneck |",
        "|---|---|---|---|---|---|---|",
    ]
    shape_order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    speedups = []
    for key in sorted(base, key=lambda k: (k[0], shape_order.get(k[1], 9))):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        bb = b["roofline"]["step_s_lower_bound"]
        ob = o["roofline"]["step_s_lower_bound"]
        sp = bb / ob if ob else float("inf")
        speedups.append(sp)
        out.append(
            f"| {key[0]} | {key[1]} | {bb*1e3:.2f} | {ob*1e3:.2f} | "
            f"**{sp:.2f}x** | {b['roofline']['bottleneck'].replace('_s','')} | "
            f"{o['roofline']['bottleneck'].replace('_s','')} |"
        )
    if speedups:
        import numpy as np

        out.append("")
        out.append(
            f"Geomean speedup of the step-time lower bound over "
            f"{len(speedups)} cells: **{float(np.exp(np.mean(np.log(speedups)))):.2f}x** "
            f"(max {max(speedups):.1f}x)."
        )
    return "\n".join(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--compare", default=None, help="optimized results dir")
    args = ap.parse_args()
    if args.compare:
        print(render_compare(args.dir, args.compare))
    else:
        print(render(args.dir))
