#!/usr/bin/env bash
# CI: hygiene guards, router/serving correctness, a serving-throughput smoke
# (one-shot engines + the continuous-batching steady-state path) with JSON
# well-formedness assertions, a docs link check, then the FULL tier-1 suite
# with zero tolerated failures — there is no allowlist of known-bad tests.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# hygiene: compiled artifacts must never be tracked again (they were, once)
if git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$' >/dev/null; then
    echo "FAIL: tracked __pycache__/*.pyc artifacts:" >&2
    git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$' >&2
    exit 1
fi
echo "pycache hygiene OK"

python -m pytest -x -q tests/test_router_batched.py tests/test_serving.py \
    tests/test_scheduler_continuous.py tests/test_plans.py \
    tests/test_core_selection.py tests/test_properties.py

# serving-throughput smoke: the benchmark must run end to end — including
# the steady-state continuous-batching scheduler path — and write a
# well-formed report (without clobbering the committed trajectory)
SMOKE_OUT="${TMPDIR:-/tmp}/BENCH_serving_smoke.json"
rm -f "$SMOKE_OUT"
python -m benchmarks.serving_throughput --smoke --out "$SMOKE_OUT"
SMOKE_OUT="$SMOKE_OUT" python - <<'PY'
import json, os
report = json.load(open(os.environ["SMOKE_OUT"]))
assert report["bench"] == "serving_throughput", "unexpected bench name"
assert report["rows"], "bench report has no rows"
for row in report["rows"]:
    for key in ("batch", "qps", "wavefront_qps", "seed_qps", "accuracy"):
        assert key in row, f"bench row missing {key}"
        assert row[key] > 0 or key == "accuracy", f"bench row has bad {key}"
steady = report["steady_state"]
for key in ("saturated_qps", "oneshot_qps", "vs_jit_engine", "steady_qps",
            "p50_ms", "p99_ms", "accuracy"):
    assert key in steady, f"steady_state missing {key}"
    assert steady[key] > 0, f"steady_state has bad {key}"
assert steady["spec_jit"] + steady["spec_reference"] > 0, "no groups routed"
print("serving smoke OK:", [(r["batch"], round(r["qps"])) for r in report["rows"]],
      "| steady", round(steady["saturated_qps"]),
      f"({steady['vs_jit_engine']:.2f}x jit), p99 {steady['p99_ms']:.2f}ms")
PY

# docs link check: README.md / docs/serving.md must not reference files
# that do not exist in the repo
python - <<'PY'
import pathlib, re, sys
bad = []
for doc in ("README.md", "docs/serving.md"):
    text = pathlib.Path(doc).read_text()
    refs = set(re.findall(r"`([A-Za-z0-9_./-]+\.(?:py|md|sh|json))`", text))
    refs |= set(re.findall(r"\]\(([A-Za-z0-9_./-]+\.md)\)", text))
    for ref in refs:
        if not pathlib.Path(ref).exists():
            bad.append((doc, ref))
if bad:
    sys.exit(f"dangling doc references: {bad}")
print("docs link check OK")
PY

# tier-1: the whole suite gates — zero failures, no exceptions
python -m pytest -q
echo "tier-1 green"
