#!/usr/bin/env bash
# CI: hygiene guards, the thriftlint static-analysis gate (zero findings
# across every rule including the PR 10 donation-contract pass, every
# suppression reasoned), router/serving/replica correctness, the
# multi-device replica suite under 4 forced host devices (overlapped
# placement bit-identity, fault-grid equivalence, zero timed recompiles —
# must RUN, not skip), a
# no-skip gate on the property suites (hypothesis or the in-repo fallback
# engine — they must RUN; the cost-ledger and replica shard-merge suites
# gate here too), a serving-throughput
# smoke — also under 4 forced host devices so the cross-device curve is
# exercised — (one-shot engines + the steady-state continuous-batching
# path + the online feedback-vs-drift section + the fault-tolerance
# section + the replica-scaling sweep + the cross_device subsection + the
# raw-speed section with its two-subprocess persistent-compile-cache
# cold-start gate + the compile-sentinel budget) with JSON well-formedness and
# history-preservation assertions, a docs link check plus a docs symbol
# check (every doc-mentioned repro.* identifier must resolve against the
# tree), then the FULL tier-1
# suite — tracer-leak-guarded via tests/conftest.py — with zero tolerated
# failures; there is no allowlist of known-bad tests.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# hygiene: compiled artifacts must never be tracked again (they were, once)
if git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$' >/dev/null; then
    echo "FAIL: tracked __pycache__/*.pyc artifacts:" >&2
    git ls-files | grep -E '(^|/)__pycache__/|\.py[co]$' >&2
    exit 1
fi
echo "pycache hygiene OK"

# thriftlint: the jit/determinism contracts gate statically. Exit is
# non-zero on any finding — including reason-less suppression comments,
# which surface as bad-suppression findings and cannot be silenced.
python scripts/lint.py
echo "thriftlint OK (zero findings)"

python -m pytest -x -q tests/test_router_batched.py tests/test_serving.py \
    tests/test_scheduler_continuous.py tests/test_plans.py \
    tests/test_core_selection.py tests/test_feedback.py \
    tests/test_selection_batched.py tests/test_failover.py \
    tests/test_replica.py

# multi-device replica placement: force 4 host CPU devices (the same knob
# `repro.launch.serve --devices` uses) so the overlapped placement path is
# real, not the single-device fallback. These tests skip themselves below
# 2 devices — a skip here means the forcing flag stopped working; fail
# loudly instead of silently testing nothing.
DEV_OUT=$(XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
    python -m pytest -q -rs tests/test_replica_devices.py 2>&1) || {
    echo "$DEV_OUT"; exit 1; }
echo "$DEV_OUT" | tail -1
if echo "$DEV_OUT" | grep -qiE "skipped"; then
    echo "FAIL: device-placement tests were skipped — forced host devices" \
         "did not take effect" >&2
    echo "$DEV_OUT" >&2
    exit 1
fi
echo "multi-device replica suite ran on 4 forced devices (no skips)"

# property suites must RUN — on the real hypothesis engine when installed,
# on the in-repo tests/_hypolite.py fallback otherwise. A skip here means
# the importorskip hole is back; fail loudly instead of masking it. (This
# is their one gated run; the fast batch above deliberately omits them.)
PROP_OUT=$(python -m pytest -q -rs tests/test_properties.py \
    tests/test_estimation_properties.py tests/test_cost_ledger.py \
    tests/test_replica_merge.py 2>&1) || {
    echo "$PROP_OUT"; exit 1; }
echo "$PROP_OUT" | tail -1
if echo "$PROP_OUT" | grep -qiE "skipped"; then
    echo "FAIL: property tests were skipped — they must always run" >&2
    echo "$PROP_OUT" >&2
    exit 1
fi
echo "property suites ran (no skips)"

# serving-throughput smoke: the benchmark must run end to end — including
# the steady-state continuous-batching scheduler path and the online
# feedback-vs-drift section — and write a well-formed report (without
# clobbering the committed trajectory). The pre-seeded stub verifies the
# history-preservation contract: an existing report must fold into the new
# file's `history`, never be clobbered.
SMOKE_OUT="${TMPDIR:-/tmp}/BENCH_serving_smoke.json"
rm -f "$SMOKE_OUT"
printf '%s' '{"engine": "ci-history-stub", "rows": [{"batch": 1, "qps": 1.0}]}' \
    > "$SMOKE_OUT"
# forced host devices so the cross-device curve measures real overlapped
# placement rather than reporting {"skipped": true} on a 1-device process
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
    python -m benchmarks.serving_throughput --smoke --out "$SMOKE_OUT"
SMOKE_OUT="$SMOKE_OUT" python - <<'PY'
import json, os
report = json.load(open(os.environ["SMOKE_OUT"]))
assert report["bench"] == "serving_throughput", "unexpected bench name"
assert report["rows"], "bench report has no rows"
for row in report["rows"]:
    for key in ("batch", "qps", "wavefront_qps", "seed_qps", "accuracy"):
        assert key in row, f"bench row missing {key}"
        assert row[key] > 0 or key == "accuracy", f"bench row has bad {key}"
steady = report["steady_state"]
for key in ("saturated_qps", "oneshot_qps", "vs_jit_engine", "steady_qps",
            "p50_ms", "p99_ms", "accuracy"):
    assert key in steady, f"steady_state missing {key}"
    assert steady[key] > 0, f"steady_state has bad {key}"
assert steady["spec_jit"] + steady["spec_reference"] > 0, "no groups routed"

# the online-feedback drift section: present, well-formed, and directionally
# right even at smoke scale (the committed full-size report carries the
# >= 0.9 oracle-recovery acceptance bar)
fb = report["feedback"]
for key in ("online_acc", "oracle_acc", "frozen_acc", "recovery",
            "frozen_vs_oracle", "steady_overhead_vs_frozen", "replan_time_s",
            "feedback_labels", "feedback_drifts", "plan_stale_dropped",
            "estimator_version", "acc_trajectory"):
    assert key in fb, f"feedback missing {key}"
for key in ("online_acc", "oracle_acc", "frozen_acc"):
    assert 0.0 < fb[key] <= 1.0, f"feedback has bad {key}: {fb[key]}"
assert fb["feedback_labels"] > 0, "no labels flowed through the loop"
assert fb["feedback_drifts"] > 0, "drift never detected on drifted traffic"
assert fb["plan_stale_dropped"] > 0, "drift never re-selected a plan"
assert fb["plan_batch_replans"] > 0, "drift replans did not go batched"
assert fb["plan_batch_replanned"] >= fb["plan_batch_replans"], \
    "batched replans rebuilt nothing"
assert fb["estimator_version"] > 0, "estimator never versioned"
assert fb["online_acc"] > fb["frozen_acc"], "feedback did not beat frozen plans"
assert fb["recovery"] > fb["frozen_vs_oracle"], "no recovery over frozen"

# the batched-planner replan section: serial vs batched drift-replan
# latency, bit-identical plans, and a real speedup at the largest G (the
# committed full-size report carries the >= 3x acceptance bar at G = 64)
sel = report["selection"]
for key in ("rows", "pool", "groups_max", "speedup_at_max", "plans_match"):
    assert key in sel, f"selection missing {key}"
assert sel["rows"], "selection section has no rows"
for row in sel["rows"]:
    for key in ("groups", "serial_s", "batched_s", "speedup"):
        assert key in row, f"selection row missing {key}"
    assert row["serial_s"] > 0 and row["batched_s"] > 0, "bad replan timing"
    assert row["replanned_batched"] == row["groups"], "replan did not cover G"
assert sel["plans_match"], "batched planner diverged from serial plans"
assert sel["groups_max"] >= 8, "no multi-group replan measured"
# the >= 3x speedup acceptance bar lives in the committed full-size report;
# a wall-clock assert at smoke scale would make CI flaky on loaded hosts
assert sel["speedup_at_max"] > 0, "replan timing is malformed"

# the raw-speed section (PR 10): fused on-device planner vs the PR 9
# host-gamma plane with bit-identical plans, donated vs non-donated wave
# dispatch bit-checked, the two-subprocess cold-start measurement against
# a shared persistent compile-cache dir (skip-gated with an honesty
# reason when the backend lacks cache support), the kernel-compile honesty
# probe, and zero recompiles inside the section's timed loops. The
# >= 1.3x planner bar at G = 64 lives in the committed full-size report;
# wall-clock bars at smoke scale would make CI flaky on loaded hosts.
raw = report["raw_speed"]
for key in ("planner", "donation", "cold_start", "kernel_compile",
            "timed_recompiles"):
    assert key in raw, f"raw_speed missing {key}"
pl = raw["planner"]
assert pl["rows"], "raw_speed planner has no rows"
for row in pl["rows"]:
    for key in ("groups", "hostgamma_s", "fused_s", "speedup"):
        assert key in row, f"raw_speed planner row missing {key}"
    assert row["hostgamma_s"] > 0 and row["fused_s"] > 0, "bad planner timing"
assert pl["plans_match"], "fused planner diverged from the host-gamma plane"
assert pl["groups_max"] >= 8, "raw_speed planner never measured multi-group"
dn = raw["donation"]
assert dn["bit_identical"], "donated wave dispatch diverged from nodonate"
assert dn["donate_s"] > 0 and dn["nodonate_s"] > 0, "bad donation timing"
cold = raw["cold_start"]
if cold.get("skipped"):
    assert cold.get("reason"), "cold_start skipped without an honesty reason"
    print(f"cold-start cache stage skipped: {cold['reason']}")
else:
    assert cold["cache_entries"] > 0, "cache-warmed run left no cache entries"
    assert cold["improved"], (
        f"persistent compile cache did not improve the second cold process: "
        f"first {cold['first_plan_s']:.2f}s, second {cold['second_plan_s']:.2f}s")
kc = raw["kernel_compile"]
assert "backend" in kc and "kernels" in kc, "kernel_compile probe malformed"
for kname, entry in kc["kernels"].items():
    assert "compiled" in entry, f"kernel probe entry malformed: {kname}"
    if not entry["compiled"]:
        assert entry.get("error"), f"uncompiled kernel {kname} with no reason"
assert raw["timed_recompiles"] == 0, \
    f"recompiles inside raw_speed timed loops: {raw['timed_recompiles']}"

# the fault-tolerance section: present, well-formed, failures really
# injected and folded; directionally right even at smoke scale (the
# committed full-size report carries the >= 0.8 replan-recovery acceptance
# bar under the 2-arm outage)
ft = report["fault_tolerance"]
for key in ("dead_arms", "baseline_acc", "frozen_acc", "failover_acc",
            "replan_acc", "frozen_recovery", "failover_recovery",
            "replan_recovery", "acc_trajectory", "p99_ms",
            "degradation_failures", "feedback_drifts"):
    assert key in ft, f"fault_tolerance missing {key}"
assert len(ft["dead_arms"]) == 2, "outage must kill exactly two arms"
for key in ("baseline_acc", "frozen_acc", "failover_acc", "replan_acc"):
    assert 0.0 < ft[key] <= 1.0, f"fault_tolerance has bad {key}: {ft[key]}"
assert ft["degradation_failures"] > 0, "outage produced no fault evidence"
assert ft["feedback_drifts"] > 0, "fault evidence never drifted the estimator"
assert ft["baseline_acc"] > ft["frozen_acc"], "outage did not hurt frozen plans"
assert ft["replan_acc"] >= ft["frozen_acc"], "replanning lost to frozen plans"
for name, p99 in ft["p99_ms"].items():
    assert p99 > 0, f"fault_tolerance p99 malformed for {name}"

# the R-replica scaling section: present, well-formed, the R=1 row
# bit-matched against the plain BatchScheduler steady path, fusion really
# fired at R > 1, and zero recompiles inside the timed sweep (the >= 2x
# aggregate-qps acceptance bar at R=4 lives in the committed full-size
# report; a wall-clock assert at smoke scale would make CI flaky)
rs = report["replica_scaling"]
for key in ("per_replica_batch", "queries", "rows", "r1_bitmatch_steady",
            "speedup_at_max", "replicas_max", "timed_recompiles"):
    assert key in rs, f"replica_scaling missing {key}"
assert rs["rows"], "replica_scaling has no rows"
for row in rs["rows"]:
    for key in ("replicas", "per_replica_batch", "qps", "p50_ms", "p99_ms",
                "speedup_vs_r1", "fused_dispatches", "fused_rows", "spills",
                "accuracy"):
        assert key in row, f"replica_scaling row missing {key}"
    assert row["qps"] > 0 and row["p99_ms"] > 0, "bad replica_scaling row"
assert rs["rows"][0]["replicas"] == 1, "replica_scaling must anchor at R=1"
assert rs["rows"][0]["fused_dispatches"] == 0, \
    "R=1 must never fuse (bit-identity contract with the steady path)"
assert any(r["replicas"] > 1 and r["fused_dispatches"] > 0
           for r in rs["rows"]), "fusion never fired at R > 1"
assert rs["replicas_max"] >= 4, "sweep did not reach R=4"
assert rs["r1_bitmatch_steady"], "ReplicaSet R=1 diverged from BatchScheduler"
assert rs["timed_recompiles"] == 0, \
    f"recompiles inside the replica sweep: {rs['timed_recompiles']}"
assert rs["speedup_at_max"] > 0, "replica scaling timing is malformed"

# the cross-device subsection: overlapped per-device placement vs the fused
# single-device anchor at R in {1, 2, 4} on the 4 forced host devices. The
# correctness bars gate unconditionally (R=1 bit-match vs the plain
# BatchScheduler, zero timed recompiles, rows well-formed); the >= 1.5x
# aggregate-qps bar gates only when the host can actually run the device
# programs in parallel (host_cores >= devices) — forced host devices
# multiplex one physical core on a 1-core CI box, where overlapped
# dispatch cannot beat fused no matter how the code is shaped, and a bar
# that can never pass is a bar nobody reads.
cd = rs["cross_device"]
for key in ("devices", "host_cores", "parallel_capable", "rows",
            "wave_plane", "overlapped_vs_fused_at_max",
            "wave_overlapped_vs_fused_at_max", "replicas_max",
            "r1_bitmatch", "timed_recompiles"):
    assert key in cd, f"cross_device missing {key}"
assert not cd.get("skipped"), "cross_device skipped despite forced devices"
assert cd["devices"] >= 4, f"forced 4 devices, saw {cd['devices']}"
assert sorted(r["replicas"] for r in cd["rows"]) == [1, 2, 4], \
    "cross_device must sweep R in {1, 2, 4}"
for row in cd["rows"]:
    for key in ("replicas", "devices_used", "qps_overlapped", "qps_fused",
                "overlapped_vs_fused", "overlapped_dispatches"):
        assert key in row, f"cross_device row missing {key}"
    assert row["qps_overlapped"] > 0 and row["qps_fused"] > 0, \
        "bad cross_device row"
    assert row["devices_used"] == min(row["replicas"], cd["devices"]), \
        "overlapped placement did not spread across the forced devices"
assert cd["rows"][-1]["overlapped_dispatches"] > 0, \
    "overlapped placement never dispatched at R=4"
wp = cd["wave_plane"]
assert wp["rows"], "cross_device wave-plane curve is empty"
for row in wp["rows"]:
    assert row["qps_overlapped_rows"] > 0 and row["qps_fused_rows"] > 0, \
        "bad cross_device wave-plane row"
assert cd["replicas_max"] >= 4, "cross_device sweep did not reach R=4"
assert cd["r1_bitmatch"], \
    "overlapped R=1 diverged from the plain BatchScheduler"
assert cd["timed_recompiles"] == 0, \
    f"recompiles inside the cross-device sweep: {cd['timed_recompiles']}"
if cd["parallel_capable"]:
    assert cd["overlapped_vs_fused_at_max"] >= 1.5, (
        f"overlapped R={cd['replicas_max']} only "
        f"{cd['overlapped_vs_fused_at_max']:.2f}x over fused on "
        f"{cd['host_cores']} cores")

# the compile-sentinel budget: every XLA compile of the wave/planner
# programs must land in a per-bucket warm-up (zero in timed sections) and
# total program counts must stay within the declared bucket budgets
cs = report["compile_sentinel"]
for key in ("timed_recompiles", "wave_compiles", "wave_bucket_budget",
            "plan_compiles", "plan_bucket_budget", "within_budget"):
    assert key in cs, f"compile_sentinel missing {key}"
assert cs["timed_recompiles"] == 0, \
    f"recompilation inside a timed section: {cs['timed_recompiles']}"
assert cs["wave_compiles"] > 0, "sentinel saw no wave compiles at all"
assert cs["within_budget"], (
    f"compile budget exceeded: wave {cs['wave_compiles']}/"
    f"{cs['wave_bucket_budget']}, plan {cs['plan_compiles']}/"
    f"{cs['plan_bucket_budget']}")

# history preservation: the pre-existing report (the stub seeded above)
# must survive as a history entry
hist = report["history"]
assert isinstance(hist, list) and hist, "prior report was clobbered, not kept"
assert hist[-1].get("engine") == "ci-history-stub", f"history lost: {hist[-1]}"

print("serving smoke OK:", [(r["batch"], round(r["qps"])) for r in report["rows"]],
      "| steady", round(steady["saturated_qps"]),
      f"({steady['vs_jit_engine']:.2f}x jit), p99 {steady['p99_ms']:.2f}ms",
      f"| feedback recovery {fb['recovery']:.2f} (frozen {fb['frozen_vs_oracle']:.2f})",
      f"| fault recovery {ft['replan_recovery']:.2f} (frozen {ft['frozen_recovery']:.2f})",
      f"| batched replan {sel['speedup_at_max']:.2f}x at G={sel['groups_max']}",
      f"| raw planner {pl['speedup_at_max']:.2f}x at G={pl['groups_max']}"
      f" (plans match {pl['plans_match']}, donation bit-id {dn['bit_identical']},"
      f" cold-start " + ("skipped" if cold.get("skipped")
                         else f"{cold['speedup']:.1f}x") + ")",
      f"| replicas {rs['speedup_at_max']:.2f}x at R={rs['replicas_max']}"
      f" (R=1 bitmatch {rs['r1_bitmatch_steady']})",
      f"| compiles wave {cs['wave_compiles']}/{cs['wave_bucket_budget']}"
      f" plan {cs['plan_compiles']}/{cs['plan_bucket_budget']}, timed 0")
PY

# docs link check: README.md / docs/serving.md / docs/analysis.md must not
# reference files that do not exist in the repo
python - <<'PY'
import pathlib, re, sys
bad = []
for doc in ("README.md", "docs/serving.md", "docs/analysis.md"):
    text = pathlib.Path(doc).read_text()
    refs = set(re.findall(r"`([A-Za-z0-9_./-]+\.(?:py|md|sh|json))`", text))
    refs |= set(re.findall(r"\]\(([A-Za-z0-9_./-]+\.md)\)", text))
    for ref in refs:
        if not pathlib.Path(ref).exists():
            bad.append((doc, ref))
if bad:
    sys.exit(f"dangling doc references: {bad}")
print("docs link check OK")
PY

# docs symbol check: every `repro.*` identifier the docs mention must
# resolve against the tree — as an importable module, or as an attribute
# (class, function, constant) of one. Catches docs drifting ahead of (or
# behind) the code: a doc naming repro.distributed.sharding.replica_mesh
# fails here until that symbol actually exists.
python - <<'PY'
import importlib, pathlib, re, sys
names = set()
for doc in ("README.md", "docs/serving.md", "docs/analysis.md"):
    text = pathlib.Path(doc).read_text()
    names |= set(re.findall(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`", text))
bad = []
for name in sorted(names):
    parts = name.split(".")
    obj = None
    depth = 0
    for i in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:i]))
            depth = i
            break
        except ImportError:
            continue
    if obj is None:
        bad.append(name)
        continue
    for attr in parts[depth:]:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            bad.append(name)
            break
if bad:
    sys.exit(f"docs name repro.* symbols that do not resolve: {bad}")
print(f"docs symbol check OK ({len(names)} repro.* identifiers resolve)")
PY

# tier-1: the whole suite gates — zero failures, no exceptions
python -m pytest -q
echo "tier-1 green"
