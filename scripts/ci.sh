#!/usr/bin/env bash
# Minimal CI: router/serving correctness first (must be green), then a
# serving-throughput smoke + docs link check (must be green), then the
# tier-1 suite. Known pre-existing failures outside the serving path
# (roofline, elastic/multipod dryrun) are tracked in ROADMAP.md open items;
# the tier-1 step reports but does not gate on them.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

set -e
python -m pytest -x -q tests/test_router_batched.py tests/test_serving.py \
    tests/test_plans.py tests/test_core_selection.py tests/test_properties.py

# serving-throughput smoke: the benchmark must run end to end and write a
# well-formed report (without clobbering the committed trajectory)
SMOKE_OUT="${TMPDIR:-/tmp}/BENCH_serving_smoke.json"
rm -f "$SMOKE_OUT"
python -m benchmarks.serving_throughput --smoke --out "$SMOKE_OUT"
SMOKE_OUT="$SMOKE_OUT" python - <<'PY'
import json, os
report = json.load(open(os.environ["SMOKE_OUT"]))
assert report["bench"] == "serving_throughput", "unexpected bench name"
assert report["rows"], "bench report has no rows"
for row in report["rows"]:
    for key in ("batch", "qps", "wavefront_qps", "seed_qps", "accuracy"):
        assert key in row, f"bench row missing {key}"
        assert row[key] > 0 or key == "accuracy", f"bench row has bad {key}"
print("serving smoke OK:", [(r["batch"], round(r["qps"])) for r in report["rows"]])
PY

# docs link check: README.md / docs/serving.md must not reference files
# that do not exist in the repo
python - <<'PY'
import pathlib, re, sys
bad = []
for doc in ("README.md", "docs/serving.md"):
    text = pathlib.Path(doc).read_text()
    refs = set(re.findall(r"`([A-Za-z0-9_./-]+\.(?:py|md|sh|json))`", text))
    refs |= set(re.findall(r"\]\(([A-Za-z0-9_./-]+\.md)\)", text))
    for ref in refs:
        if not pathlib.Path(ref).exists():
            bad.append((doc, ref))
if bad:
    sys.exit(f"dangling doc references: {bad}")
print("docs link check OK")
PY
set +e

python -m pytest -q
tier1=$?
echo "tier-1 exit: $tier1 (pre-existing failures tracked in ROADMAP.md)"
exit 0
