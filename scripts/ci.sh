#!/usr/bin/env bash
# Minimal CI: router/serving correctness first (must be green), then the
# tier-1 suite. Known pre-existing failures outside the serving path
# (rglru/mamba kernel sweeps, roofline, elastic/multipod dryrun) are tracked
# in ROADMAP.md open items; the tier-1 step reports but does not gate on them.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

set -e
python -m pytest -x -q tests/test_router_batched.py tests/test_serving.py \
    tests/test_core_selection.py tests/test_properties.py
set +e

python -m pytest -q
tier1=$?
echo "tier-1 exit: $tier1 (pre-existing failures tracked in ROADMAP.md)"
exit 0
