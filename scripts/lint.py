#!/usr/bin/env python
"""thriftlint CLI — static enforcement of the repo's jit/determinism
contracts.

    python scripts/lint.py                    # all rules over src/repro
    python scripts/lint.py --rule jit-purity --rule prng-discipline
    python scripts/lint.py --format=json      # machine-readable report
    python scripts/lint.py --list-rules

Exit status is non-zero when any finding survives — including
`bad-suppression` findings for `# thriftlint: ignore[...]` comments that
omit a rule list or a reason.  See docs/analysis.md for the rule
catalogue and suppression policy.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis import ALL_RULES, run_lint  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--rule",
        action="append",
        default=[],
        help="run only this rule (repeatable); default: all rules",
    )
    parser.add_argument(
        "--format",
        choices=("text", "json"),
        default="text",
        help="output format",
    )
    parser.add_argument(
        "--src",
        default=str(REPO / "src"),
        help="source root containing the package (default: src/)",
    )
    parser.add_argument(
        "--package", default="repro", help="package to scan (default: repro)"
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="list rule ids and exit"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for name in ALL_RULES:
            print(name)
        return 0

    report = run_lint(
        src_root=args.src, package=args.package, rules=tuple(args.rule)
    )

    if args.format == "json":
        print(json.dumps(report.to_dict(), indent=2))
    else:
        for f in report.findings:
            print(f.format())
        reasoned = sum(1 for s in report.suppressions if s.has_reason)
        print(
            f"thriftlint: {len(report.findings)} finding(s), "
            f"{len(report.suppressed)} suppressed "
            f"({reasoned} reasoned suppression comment(s)), "
            f"{report.files_scanned} files, "
            f"rules: {', '.join(report.rules_run)}"
        )
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
