"""Jit-compiled training step with microbatched gradient accumulation,
optional gradient compression, and remat-friendly structure.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_params
from repro.models import LM

from .compression import CompressionConfig, compress_grads, init_residuals
from .optimizer import OptimizerConfig, adamw_init, adamw_update


def init_train_state(model: LM, key, comp: CompressionConfig = CompressionConfig()):
    params = model.init(key)
    opt = adamw_init(params)
    if comp.codec != "none" and comp.error_feedback:
        opt["residuals"] = init_residuals(params)
    return params, opt


def _split_microbatches(batch: Dict[str, jnp.ndarray], m: int):
    def split(x):
        b = x.shape[0]
        assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
        return x.reshape(m, b // m, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    model: LM,
    opt_cfg: OptimizerConfig,
    comp_cfg: CompressionConfig = CompressionConfig(),
) -> Callable:
    """Build the jit-able train_step(params, opt_state, batch)."""

    def loss_fn(params, microbatch):
        loss, metrics = model.loss(params, microbatch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        m = model.cfg.num_microbatches
        if m <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbatches = _split_microbatches(batch, m)

            def accum(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + l), None

            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (gzero, jnp.float32(0.0)), mbatches)
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss = lsum / m
            metrics = {}

        residuals = opt_state.get("residuals")
        grads, new_res, comp_stats = compress_grads(grads, residuals, comp_cfg)

        opt_core = {k: v for k, v in opt_state.items() if k != "residuals"}
        new_params, new_opt, opt_stats = adamw_update(grads, opt_core, params, opt_cfg)
        if new_res is not None and comp_cfg.codec != "none":
            new_opt["residuals"] = new_res
        out_metrics = {"loss": loss, **opt_stats, **comp_stats}
        # pin outputs to the canonical param layout so step N+1's explicit
        # in_shardings still match (see constrain_params for the failure)
        return constrain_params(new_params), constrain_params(new_opt), out_metrics

    return train_step
