"""Gradient compression with error feedback (distributed-optimization trick).

Two codecs:
  * int8 uniform quantization per-leaf (8x volume reduction on the DP
    all-reduce) with error-feedback residuals, and
  * top-k magnitude sparsification (k as a fraction) with residuals.

In a pjit program the DP all-reduce is implicit, so the codec runs as
quantize -> (collective on the low-precision payload) -> dequantize around
the gradient tree; the error-feedback buffer lives in the optimizer state
and provably preserves convergence (Stich et al., 2018).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    codec: str = "none"          # none | int8 | topk
    topk_frac: float = 0.01
    error_feedback: bool = True


def init_residuals(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_codec(g: jnp.ndarray) -> jnp.ndarray:
    """Quantize-dequantize to int8 grid (symmetric, per-tensor scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_codec(g: jnp.ndarray, frac: float) -> jnp.ndarray:
    g32 = g.astype(jnp.float32)
    flat = g32.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g32) >= thresh, g32, 0.0)


def compress_grads(
    grads: Any, residuals: Optional[Any], cfg: CompressionConfig
) -> Tuple[Any, Any, Dict[str, jnp.ndarray]]:
    """Apply codec with error feedback. Returns (grads, new_residuals, stats)."""
    if cfg.codec == "none":
        return grads, residuals, {}

    def one(g, r):
        g32 = g.astype(jnp.float32)
        if cfg.error_feedback and r is not None:
            g32 = g32 + r
        if cfg.codec == "int8":
            out = _int8_codec(g32)
        elif cfg.codec == "topk":
            out = _topk_codec(g32, cfg.topk_frac)
        else:
            raise ValueError(cfg.codec)
        new_r = (g32 - out) if cfg.error_feedback else jnp.zeros_like(g32)
        return out, new_r

    if residuals is None:
        residuals = init_residuals(grads)
    pairs = jax.tree.map(one, grads, residuals)
    out = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    err = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(new_res)))
    return out, new_res, {"compression_err_norm": err}
