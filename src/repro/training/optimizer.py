"""AdamW with fp32 master weights, built from scratch (no optax).

Optimizer state is a pytree mirroring the parameters:
  {"m": fp32, "v": fp32, "master": fp32 copy, "step": int32 scalar}
so ZeRO-style sharding is just "shard the state like the params".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"       # cosine | linear | constant


def lr_at(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * t))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1.0 - t)
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def adamw_init(params: Any) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, opt_state: Dict[str, Any], params: Any, cfg: OptimizerConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.betas

    gnorm = global_norm(grads)
    scale = jnp.where(
        gnorm > cfg.grad_clip, cfg.grad_clip / jnp.maximum(gnorm, 1e-12), 1.0
    )

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master)
        return m, v, new_master

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], opt_state["master"])
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(
        lambda mp, p: mp.astype(p.dtype), master, params
    )
    new_state = {"m": m, "v": v, "master": master, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
