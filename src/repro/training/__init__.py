"""Training substrate: optimizer, train step, gradient compression."""
from .compression import CompressionConfig, compress_grads, init_residuals
from .optimizer import OptimizerConfig, adamw_init, adamw_update, global_norm, lr_at
from .train_loop import init_train_state, make_train_step

__all__ = [
    "OptimizerConfig", "adamw_init", "adamw_update", "global_norm", "lr_at",
    "CompressionConfig", "compress_grads", "init_residuals",
    "init_train_state", "make_train_step",
]
