"""Distribution: sharding rules, collectives helpers, fault tolerance."""
from .sharding import (
    AxisRules,
    active_rules,
    batch_specs,
    cache_specs,
    constrain,
    param_specs,
    replicated,
    use_rules,
)

__all__ = [
    "AxisRules", "active_rules", "batch_specs", "cache_specs",
    "constrain", "param_specs", "replicated", "use_rules",
]
