"""Logical-axis sharding rules (MaxText-style) for every architecture.

Model code annotates activations with *logical* axes (``constrain(h,
"batch", "seq", "embed")``) and parameter leaves carry name-derived logical
specs. A :class:`AxisRules` binding maps logical axes onto mesh axes with
divisibility checks — non-divisible dims silently fall back to replication,
which is what makes one rule-set serve all 10 architectures (36-head
starcoder2 simply replicates heads and keeps the flat-feature TP sharding).

When no rules are active (unit tests, CPU smoke runs) ``constrain`` is the
identity, so the model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalAxes = Union[None, str, Tuple[str, ...]]

# Default logical -> mesh-axis mapping. "fsdp" shards parameter rows over the
# data axis (ZeRO-3 style); "tp"/"heads"/"vocab"/"ff" shard over model.
DEFAULT_RULES: Dict[str, LogicalAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "ff": "model",
    "tp": "model",
    "experts": "model",
    "fsdp": "data",
    # decode KV-cache time dimension: sharding it over 'model' divides the
    # dominant decode memory by the TP degree regardless of KV-head count
    # (GQA head counts rarely divide 16; the 32k time axis always does).
    # GSPMD turns the cache update into a masked per-shard write and the
    # softmax over time into tiny (B,H)-scale cross-shard reductions.
    "kv": "model",
    # ZeRO-3 output-dim sharding (perf iteration #2g): weight matrices shard
    # their OUTPUT dim over (data, model) jointly, leaving contraction dims
    # whole — GSPMD then all-gathers weight shards (weight-sized traffic)
    # instead of partial-summing activation-sized tensors. Enabled per arch
    # via rules override {"zero3": True}.
    "fsdp_tp": ("data", "model"),
    "zero3": False,
}

# 2-D weight leaves that flip to (None, "fsdp_tp") under zero3.
ZERO3_LEAVES = {
    "wq", "wk", "wv", "wo", "wg", "wu", "wd", "w_in", "w_out",
    "wy", "wx", "wr", "wi",
}


@dataclasses.dataclass
class AxisRules:
    mesh: Mesh
    rules: Dict[str, LogicalAxes] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        merged = dict(DEFAULT_RULES)
        merged.update(self.rules)
        self.rules = merged

    def mesh_axes(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        ax = self.rules.get(logical)
        if ax is None:
            return ()
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        return tuple(a for a in axes if a in self.mesh.shape)

    def axis_size(self, axes: Tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1

    def spec_for(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> P:
        """Resolve logical dims to a PartitionSpec with divisibility checks
        and no mesh-axis reuse."""
        used: set = set()
        out = []
        for dim, name in zip(shape, logical):
            axes = self.mesh_axes(name)
            if axes and not (set(axes) & used) and dim % self.axis_size(axes) == 0:
                out.append(axes if len(axes) > 1 else axes[0])
                used.update(axes)
            else:
                out.append(None)
        return P(*out)

    def sharding_for(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, logical))


_ACTIVE: Optional[AxisRules] = None


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = rules
    try:
        yield rules
    finally:
        _ACTIVE = prev


def active_rules() -> Optional[AxisRules]:
    return _ACTIVE


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate activation sharding; identity when no rules are active."""
    r = _ACTIVE
    if r is None:
        return x
    if x.ndim != len(logical):
        return x
    spec = r.spec_for(x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter / cache / batch specs by leaf name
# ---------------------------------------------------------------------------

# leaf-name -> logical axes of its *unstacked* dims
PARAM_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "tok": ("vocab", "fsdp"),
    "w": ("fsdp", "vocab"),          # untied head
    "final_norm": (None,),
    "ln": (None,), "ln1": (None,), "ln2": (None,),
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    "wg": ("fsdp", "tp"), "wu": ("fsdp", "tp"), "wd": ("tp", "fsdp"),
    "router": ("fsdp", None),
    "ewg": ("experts", "fsdp", None),
    "ewu": ("experts", "fsdp", None),
    "ewd": ("experts", None, "fsdp"),
    "w_in": ("fsdp", "tp"),
    "conv_w": ("tp", None), "conv_b": ("tp",),
    "w_x": ("tp", None), "w_dt": (None, "tp"), "b_dt": ("tp",),
    "a_log": ("tp", None), "d_skip": ("tp",),
    "w_out": ("tp", "fsdp"),
    "wy": ("fsdp", "tp"), "wx": ("fsdp", "tp"),
    "wr": ("fsdp", "tp"), "wi": ("fsdp", "tp"),
    "br": ("tp",), "bi": ("tp",), "lam": ("tp",),
}

CACHE_LOGICAL: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("batch", "kv", "heads", None),   # heads dropped if 'model' taken by kv
    "v": ("batch", "kv", "heads", None),
    "k_scale": ("batch", "kv", "heads", None),   # int8-KV scales (perf #3)
    "v_scale": ("batch", "kv", "heads", None),
    "conv": ("batch", None, "tp"),
    "h": ("batch", "tp", None),      # ssm state (B, Din, N); rec uses 2 dims
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def param_specs(shapes_tree: Any, rules: AxisRules) -> Any:
    """Tree of NamedSharding for a parameter pytree (stacked segment leaves
    get a leading replicated dim)."""

    zero3 = bool(rules.rules.get("zero3"))

    def spec(path, leaf):
        name = _leaf_name(path)
        logical = PARAM_LOGICAL.get(name)
        shape = tuple(leaf.shape)
        if logical is None:
            return NamedSharding(rules.mesh, P())
        if zero3 and name in ZERO3_LEAVES and len(logical) == 2:
            logical = (None, "fsdp_tp")
        if len(shape) == len(logical) + 1:       # stacked scan dim
            logical = (None, *logical)
        elif len(shape) != len(logical):
            return NamedSharding(rules.mesh, P())
        return rules.sharding_for(shape, logical)

    return jax.tree_util.tree_map_with_path(spec, shapes_tree)


def cache_specs(cache_tree: Any, rules: AxisRules) -> Any:
    def spec(path, leaf):
        name = _leaf_name(path)
        shape = tuple(leaf.shape)
        if name in ("pos",) or leaf.ndim == 0:
            return NamedSharding(rules.mesh, P())
        if name == "ring":
            return NamedSharding(rules.mesh, P())
        logical = CACHE_LOGICAL.get(name)
        if logical is None:
            return NamedSharding(rules.mesh, P())
        if name == "h" and len(shape) == 3:       # stacked rec state (n,B,Dr)
            logical = ("batch", "tp")
        if len(shape) == len(logical) + 1:
            logical = (None, *logical)
        elif len(shape) != len(logical):
            return NamedSharding(rules.mesh, P())
        return rules.sharding_for(shape, logical)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def batch_specs(batch_tree: Any, rules: AxisRules) -> Any:
    def spec(path, leaf):
        logical = ("batch",) + (None,) * (leaf.ndim - 1)
        return rules.sharding_for(tuple(leaf.shape), logical)

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def replicated(tree: Any, rules: AxisRules) -> Any:
    return jax.tree.map(lambda _: NamedSharding(rules.mesh, P()), tree)


def constrain_params(tree: Any) -> Any:
    """Pin a parameter/optimizer pytree to its canonical :func:`param_specs`
    layout under the active rules; identity when no rules are active.

    Applied to train-step *outputs*: without an output pin, XLA is free to
    pick a different layout for an output leaf than ``param_specs`` assigned
    the matching input (e.g. replicating a small norm vector on the way in
    but sharding it over ``model`` on the way out). The next call of a step
    function jitted with explicit ``in_shardings`` then rejects the
    now-mismatched committed argument instead of resharding it — which is
    exactly what broke step 2 of the elastic re-mesh restart path.
    """
    r = _ACTIVE
    if r is None:
        return tree
    return jax.tree.map(
        jax.lax.with_sharding_constraint, tree, param_specs(tree, r)
    )


# ---------------------------------------------------------------------------
# Replica-plane device placement (see repro/serving/replica.py)
# ---------------------------------------------------------------------------


def replica_devices(replicas: int) -> list:
    """Device assignment for an R-replica serving plane.

    With more than one local device, replicas round-robin over the device
    list: under ``ReplicaSet(placement="overlapped")`` each
    :class:`~repro.serving.replica.ReplicaWorker`'s router pins its wave
    dispatches to its assigned device (``jax.device_put`` of the padded
    wave tables + the per-device jit executable), so R wave programs from
    one drive cycle run concurrently. On a single device the assignment
    is ``None`` everywhere — placement is a no-op and the ReplicaSet
    instead *fuses* same-budget replica waves along the batch axis, the
    single-device degenerate of sharding the wave program's (T, B) tables
    over a batch-axis device slice.
    """
    devs = jax.devices()
    if len(devs) <= 1:
        return [None] * int(replicas)
    return [devs[i % len(devs)] for i in range(int(replicas))]


def replica_mesh(replicas: int) -> Optional[Mesh]:
    """1-axis ``("replica",)`` mesh over ``min(replicas, local devices)``
    devices — the binding a ``jax.shard_map`` lowering of the fused wave
    dispatch would shard the batch axis over. None on a single device
    (nothing to shard; the fused batch-axis dispatch covers it)."""
    devs = jax.devices()
    n = min(int(replicas), len(devs))
    if n <= 1:
        return None
    return Mesh(np.asarray(devs[:n]), axis_names=("replica",))
