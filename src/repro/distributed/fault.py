"""Fault tolerance for 1000+-node runs: failure detection, elastic re-mesh
planning, straggler mitigation, the checkpoint/restart driver, and the
arm-level fault-injection plane for the serving stack.

The detection plane is deliberately host-side python (it must keep working
when devices are wedged). On this CPU container failures are injected by
tests; the logic is identical on a real cluster where heartbeats come from
per-host agents.

Arm fault injection (:class:`FaultPolicy`) lives here rather than in
``serving/engine.py`` so the injection machinery stays out of traced code:
fault draws are a pure counter-based hash evaluated host-side on the
original wave schedule, and the jitted wave program only ever sees the
resulting ``src``/``valid`` failover gather as plain data arrays (thriftlint
jit-purity: no RNG state, clocks, or mutable policy objects inside jit).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class HeartbeatMonitor:
    """Declares a worker dead after ``timeout_s`` without a heartbeat."""

    num_workers: int
    timeout_s: float = 30.0

    def __post_init__(self):
        now = time.monotonic()
        self.last_seen = {w: now for w in range(self.num_workers)}

    def beat(self, worker: int, t: Optional[float] = None):
        self.last_seen[worker] = time.monotonic() if t is None else t

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [w for w, t in self.last_seen.items() if now - t > self.timeout_s]


def plan_elastic_remesh(
    mesh_shape: Dict[str, int], failed_hosts: Sequence[int], hosts_per_data_row: int = 1
) -> Dict[str, int]:
    """Shrink the data axis past failed hosts, keeping the model axis intact.

    TP shards within a model row are tightly coupled (they hold disjoint
    parameter shards with per-layer collectives), so the recovery unit is a
    whole data row: drop as many rows as have a failure, keep batch
    divisibility by recomputing per-row batch. Returns the new mesh shape;
    the restart path is checkpoint-restore under the new mesh (parameters
    are re-sharded by pjit's in_shardings on load).
    """
    if not failed_hosts:
        return dict(mesh_shape)
    rows_lost = len(set(h // hosts_per_data_row for h in failed_hosts))
    new = dict(mesh_shape)
    new["data"] = max(1, mesh_shape["data"] - rows_lost)
    return new


def rebatch_for_mesh(global_batch: int, old_data: int, new_data: int) -> int:
    """Largest batch <= global_batch divisible by the new data-axis size,
    preserving per-row microbatch shape where possible."""
    per_row = global_batch // old_data
    return per_row * new_data


@dataclasses.dataclass
class StragglerMitigator:
    """Per-step worker timing tracker with hedged-work decisions.

    A worker is a straggler when its step time exceeds
    ``threshold x median`` over a sliding window. Mitigation hooks:
      * training: drop the row's contribution this step (bounded staleness)
        and rescale the gradient, or
      * serving: hedge — re-issue the slow arm's request to a replica; for
        ThriftLLM ensembles the adaptive early-stop (Prop. 4) often makes
        the straggler's response unnecessary, so the hedge is free.
    """

    num_workers: int
    window: int = 20
    threshold: float = 2.0

    def __post_init__(self):
        self.history: List[np.ndarray] = []

    def record_step(self, times: Sequence[float]):
        assert len(times) == self.num_workers
        self.history.append(np.asarray(times, np.float64))
        if len(self.history) > self.window:
            self.history.pop(0)

    def stragglers(self) -> List[int]:
        if not self.history:
            return []
        mean_t = np.mean(np.stack(self.history), axis=0)
        med = float(np.median(mean_t))
        return [int(w) for w in np.flatnonzero(mean_t > self.threshold * med)]

    def hedge_plan(self, pending_arms: Sequence[int], slow_arm: int) -> List[int]:
        """Serving-side: reorder so the slow arm is polled last (its answer
        is most likely to be early-stopped away)."""
        plan = [a for a in pending_arms if a != slow_arm]
        if slow_arm in pending_arms:
            plan.append(slow_arm)
        return plan


@dataclasses.dataclass
class FaultTolerantDriver:
    """Wraps a train loop with checkpoint/restart + failure handling.

    Usage::

        driver = FaultTolerantDriver(ckpt_manager, save_every=50)
        state, start = driver.restore(state_template)
        for step in range(start, total):
            state = train_step(state, batch)
            driver.maybe_save(step, state)
            if driver.check_failures(monitor):  # -> elastic re-mesh restart
                break
    """

    ckpt: "object"
    save_every: int = 100

    def restore(self, template):
        step, state = self.ckpt.restore_latest(template)
        return state, (0 if step is None else step + 1)

    def maybe_save(self, step: int, state):
        if step % self.save_every == 0:
            self.ckpt.save(step, state)

    def check_failures(self, monitor: HeartbeatMonitor) -> List[int]:
        return monitor.dead_workers()


# ---------------------------------------------------------------------------
# Arm-level fault injection for the serving plane.
#
# Faults are drawn from a counter-based hash keyed on
# (seed, epoch, arm, wave slot, batch row) — no RNG object, no hidden state.
# That determinism is load-bearing: the jit and reference data planes must
# observe the *same* fault schedule for the bit-equivalence pin to extend to
# faulted runs, and a re-run of the same batch must fault identically so the
# failover tests are reproducible. Time only advances when the caller calls
# :meth:`FaultPolicy.advance` (e.g. once per served batch in a chaos bench);
# the router never advances it.
# ---------------------------------------------------------------------------

FAULT_OK = 0
FAULT_TIMEOUT = 1
FAULT_ERROR = 2
FAULT_DEGRADE = 3

#: virtual wave index used when hashing probe-traffic fault draws, chosen
#: far above any real plan length so probes never collide with wave cells
PROBE_WAVE = 1 << 20


def _mix64(z: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer over uint64 arrays (vectorized, stateless)."""
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _hash_cells(seed: int, epoch: int, arms, waves, rows, salt: int) -> np.ndarray:
    """uint64 hash per (arm, wave, row) cell under (seed, epoch, salt)."""
    a = np.asarray(arms, np.uint64)
    w = np.asarray(waves, np.uint64)
    r = np.asarray(rows, np.uint64)
    with np.errstate(over="ignore"):      # uint64 wraparound IS the hash
        k = (
            np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15)
            ^ np.uint64(epoch) * np.uint64(0xC2B2AE3D27D4EB4F)
            ^ np.uint64(salt) * np.uint64(0x165667B19E3779F9)
        )
        z = k ^ (a * np.uint64(0xFF51AFD7ED558CCD))
        z ^= w * np.uint64(0xC4CEB9FE1A85EC53)
        z ^= r * np.uint64(0x2545F4914F6CDD1D)
        return _mix64(z)


def _uniform(h: np.ndarray) -> np.ndarray:
    """Map uint64 hashes to f64 uniforms in [0, 1)."""
    return (h >> np.uint64(11)).astype(np.float64) * (2.0**-53)


@dataclasses.dataclass
class ArmFaultSpec:
    """Per-arm fault rates; each invocation draws one of the outcomes.

    ``timeout`` and ``error`` both mean no usable response (they differ only
    in how they are tallied); ``degrade`` means the arm answers, but with a
    hash-drawn class instead of its real prediction (silent degradation).
    """

    timeout: float = 0.0
    error: float = 0.0
    degrade: float = 0.0

    def __post_init__(self):
        for name in ("timeout", "error", "degrade"):
            v = float(getattr(self, name))
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} rate must be in [0, 1], got {v}")
            setattr(self, name, v)
        if self.timeout + self.error + self.degrade > 1.0 + 1e-12:
            raise ValueError("fault rates must sum to <= 1")


class FaultPolicy:
    """Deterministic per-arm fault schedules for a :class:`PoolEngine`.

    ``grid_codes`` evaluates the whole (T, B) wave schedule in one
    vectorized pass and is the single authority both data planes consume —
    computing it once host-side (never inside jit) is what keeps the planes
    bit-identical under faults. ``corrupt_grid`` is response-independent
    (pure hash of the cell), so silent degradation can be applied to the
    jit plane's speculative response grid and to the reference plane's live
    invocations without any cross-plane coordination.
    """

    def __init__(self, num_arms: int, num_classes: int, seed: int = 0):
        self.num_arms = int(num_arms)
        self.num_classes = int(num_classes)
        self.seed = int(seed)
        self.epoch = 0
        self._timeout = np.zeros(self.num_arms, np.float64)
        self._error = np.zeros(self.num_arms, np.float64)
        self._degrade = np.zeros(self.num_arms, np.float64)

    # -- configuration ------------------------------------------------------
    def set_arm(self, arm: int, *, timeout: float = 0.0, error: float = 0.0,
                degrade: float = 0.0) -> "FaultPolicy":
        spec = ArmFaultSpec(timeout=timeout, error=error, degrade=degrade)
        self._timeout[arm] = spec.timeout
        self._error[arm] = spec.error
        self._degrade[arm] = spec.degrade
        return self

    def set_arms(self, arms: Sequence[int], **rates) -> "FaultPolicy":
        for a in arms:
            self.set_arm(int(a), **rates)
        return self

    def clear(self, arm: Optional[int] = None) -> "FaultPolicy":
        sel = slice(None) if arm is None else arm
        self._timeout[sel] = 0.0
        self._error[sel] = 0.0
        self._degrade[sel] = 0.0
        return self

    def advance(self, n: int = 1) -> "FaultPolicy":
        """Move to a new fault epoch: fresh draws for the same cells."""
        self.epoch += int(n)
        return self

    @property
    def active(self) -> bool:
        return bool((self._timeout + self._error + self._degrade > 0.0).any())

    def spec(self, arm: int) -> ArmFaultSpec:
        return ArmFaultSpec(
            timeout=float(self._timeout[arm]),
            error=float(self._error[arm]),
            degrade=float(self._degrade[arm]),
        )

    # -- draws --------------------------------------------------------------
    def _codes(self, arms: np.ndarray, waves, rows) -> np.ndarray:
        """Fault code per cell; arms < 0 (padding) always draw OK."""
        safe = np.maximum(arms, 0)
        u = _uniform(_hash_cells(self.seed, self.epoch, safe, waves, rows, 1))
        t = self._timeout[safe]
        e = self._error[safe]
        d = self._degrade[safe]
        codes = np.zeros(arms.shape, np.int8)
        codes[u < t + e + d] = FAULT_DEGRADE
        codes[u < t + e] = FAULT_ERROR
        codes[u < t] = FAULT_TIMEOUT
        codes[arms < 0] = FAULT_OK
        return codes

    def grid_codes(self, sched_T: np.ndarray, row_offset: int = 0) -> np.ndarray:
        """(T, B) fault codes for a wave schedule (arm ids, -1 = no wave).

        ``row_offset`` shifts the batch-row coordinate of every cell: a
        worker dispatching rows ``[lo, lo+B)`` of a logically fused batch
        passes ``row_offset=lo`` so its draws are bit-identical to the same
        rows' draws in the single fused dispatch (the overlapped/fused
        placement equivalence contract of the replica plane).
        """
        T, B = sched_T.shape
        waves = np.broadcast_to(np.arange(T, dtype=np.int64)[:, None], (T, B))
        rows = np.broadcast_to(
            (np.arange(B, dtype=np.int64) + int(row_offset))[None, :], (T, B)
        )
        return self._codes(sched_T, waves, rows)

    def row_codes(self, arm_ids: np.ndarray, rows: np.ndarray,
                  wave: int = PROBE_WAVE) -> np.ndarray:
        """Fault codes for a flat (arm, row) list (probe traffic)."""
        arm_ids = np.asarray(arm_ids, np.int64)
        return self._codes(arm_ids, np.full(arm_ids.shape, wave, np.int64),
                           np.asarray(rows, np.int64))

    def corrupt_grid(self, sched_T: np.ndarray, row_offset: int = 0) -> np.ndarray:
        """(T, B) hash-drawn class per cell — the degraded 'response'.

        Response-independent by design: both planes can overwrite a
        degraded cell with the same class without knowing what the arm
        would have said. ``row_offset`` shifts batch-row coordinates the
        same way :meth:`grid_codes` does.
        """
        T, B = sched_T.shape
        safe = np.maximum(sched_T, 0)
        waves = np.broadcast_to(np.arange(T, dtype=np.int64)[:, None], (T, B))
        rows = np.broadcast_to(
            (np.arange(B, dtype=np.int64) + int(row_offset))[None, :], (T, B)
        )
        h = _hash_cells(self.seed, self.epoch, safe, waves, rows, 2)
        return (h % np.uint64(self.num_classes)).astype(np.int64)

    def corrupt_rows(self, arm_ids: np.ndarray, rows: np.ndarray,
                     wave: int = PROBE_WAVE) -> np.ndarray:
        arm_ids = np.asarray(arm_ids, np.int64)
        h = _hash_cells(self.seed, self.epoch, np.maximum(arm_ids, 0),
                        np.full(arm_ids.shape, wave, np.int64),
                        np.asarray(rows, np.int64), 2)
        return (h % np.uint64(self.num_classes)).astype(np.int64)


def failover_gather(
    sched_T: np.ndarray, failed: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Compaction gather implementing in-wave failover.

    Given the plan-order wave schedule ``sched_T`` (T, B) and a boolean
    ``failed`` mask over it, slot ``u`` of each query's wave program serves
    the plan's ``u``-th *available* arm (scheduled and not failed) — i.e. a
    failed arm's slot re-routes to the plan's next-best arm. SurGreedy
    orders the plan by marginal gain per cost under the budget, so "next in
    plan order" is exactly "next-best affordable".

    Returns ``(src, valid, rank, navail)``:
      * ``src``    (T, B) int32 — original wave index serving slot u
        (0 where invalid; masked by ``valid``),
      * ``valid``  (T, B) bool — slot u has an available arm,
      * ``rank``   (T, B) int64 — failover slot each original cell would
        occupy (cumulative count of available cells above it),
      * ``navail`` (B,) int64 — available arms per query.

    With no failures this is the identity gather (``src[t] == t``,
    ``valid == sched_T >= 0``) — the wave program's failover mask is a
    provable no-op on fault-free traffic.
    """
    T, B = sched_T.shape
    avail = (sched_T >= 0) & ~failed
    rank = np.cumsum(avail, axis=0, dtype=np.int64) - avail
    src = np.zeros((T, B), np.int32)
    valid = np.zeros((T, B), bool)
    tt, bb = np.nonzero(avail)
    src[rank[tt, bb], bb] = tt.astype(np.int32)
    valid[rank[tt, bb], bb] = True
    return src, valid, rank, avail.sum(axis=0)


def attempted_failures(
    failed: np.ndarray,
    sched_T: np.ndarray,
    stop_wave: np.ndarray,
    rank: Optional[np.ndarray] = None,
    navail: Optional[np.ndarray] = None,
) -> np.ndarray:
    """(T, B) mask of failed cells the wavefront actually attempted.

    With failover (``rank``/``navail`` given), a failed cell was attempted
    iff its failover slot lies inside the effective stop: the wave program
    reached that position in plan order before Prop. 4 stopped (strictly
    before, except when the query exhausted every available arm — then the
    failures past the last served slot were attempted too). Without
    failover (frozen plans), attempted simply means the failed cell's wave
    index precedes the positional stop.
    """
    hit = failed & (sched_T >= 0)
    if rank is None:
        T = sched_T.shape[0]
        return hit & (np.arange(T)[:, None] < stop_wave[None, :])
    reach = stop_wave + (stop_wave == navail)
    return hit & (rank < reach[None, :])


def observed_faults(
    codes: Optional[np.ndarray],
    sched_T: np.ndarray,
    stop_wave: np.ndarray,
    rank: Optional[np.ndarray] = None,
    navail: Optional[np.ndarray] = None,
) -> Optional[np.ndarray]:
    """(T, B) int8 fault codes at cells the route actually observed.

    Attempted timeout/error failures plus silently-degraded cells that were
    really served; everything else (including injected faults past the stop
    wave, which no one ever saw) reads ``FAULT_OK``.
    """
    if codes is None:
        return None
    failed = (codes == FAULT_TIMEOUT) | (codes == FAULT_ERROR)
    attempted = attempted_failures(failed, sched_T, stop_wave, rank, navail)
    degrade = (codes == FAULT_DEGRADE) & (sched_T >= 0)
    if rank is None:
        T = sched_T.shape[0]
        served = degrade & (np.arange(T)[:, None] < stop_wave[None, :])
    else:
        served = degrade & (rank < stop_wave[None, :])
    return np.where(attempted | served, codes, FAULT_OK).astype(np.int8)
