"""Fault tolerance for 1000+-node runs: failure detection, elastic re-mesh
planning, straggler mitigation, and the checkpoint/restart driver.

The detection plane is deliberately host-side python (it must keep working
when devices are wedged). On this CPU container failures are injected by
tests; the logic is identical on a real cluster where heartbeats come from
per-host agents.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class HeartbeatMonitor:
    """Declares a worker dead after ``timeout_s`` without a heartbeat."""

    num_workers: int
    timeout_s: float = 30.0

    def __post_init__(self):
        now = time.monotonic()
        self.last_seen = {w: now for w in range(self.num_workers)}

    def beat(self, worker: int, t: Optional[float] = None):
        self.last_seen[worker] = time.monotonic() if t is None else t

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [w for w, t in self.last_seen.items() if now - t > self.timeout_s]


def plan_elastic_remesh(
    mesh_shape: Dict[str, int], failed_hosts: Sequence[int], hosts_per_data_row: int = 1
) -> Dict[str, int]:
    """Shrink the data axis past failed hosts, keeping the model axis intact.

    TP shards within a model row are tightly coupled (they hold disjoint
    parameter shards with per-layer collectives), so the recovery unit is a
    whole data row: drop as many rows as have a failure, keep batch
    divisibility by recomputing per-row batch. Returns the new mesh shape;
    the restart path is checkpoint-restore under the new mesh (parameters
    are re-sharded by pjit's in_shardings on load).
    """
    if not failed_hosts:
        return dict(mesh_shape)
    rows_lost = len(set(h // hosts_per_data_row for h in failed_hosts))
    new = dict(mesh_shape)
    new["data"] = max(1, mesh_shape["data"] - rows_lost)
    return new


def rebatch_for_mesh(global_batch: int, old_data: int, new_data: int) -> int:
    """Largest batch <= global_batch divisible by the new data-axis size,
    preserving per-row microbatch shape where possible."""
    per_row = global_batch // old_data
    return per_row * new_data


@dataclasses.dataclass
class StragglerMitigator:
    """Per-step worker timing tracker with hedged-work decisions.

    A worker is a straggler when its step time exceeds
    ``threshold x median`` over a sliding window. Mitigation hooks:
      * training: drop the row's contribution this step (bounded staleness)
        and rescale the gradient, or
      * serving: hedge — re-issue the slow arm's request to a replica; for
        ThriftLLM ensembles the adaptive early-stop (Prop. 4) often makes
        the straggler's response unnecessary, so the hedge is free.
    """

    num_workers: int
    window: int = 20
    threshold: float = 2.0

    def __post_init__(self):
        self.history: List[np.ndarray] = []

    def record_step(self, times: Sequence[float]):
        assert len(times) == self.num_workers
        self.history.append(np.asarray(times, np.float64))
        if len(self.history) > self.window:
            self.history.pop(0)

    def stragglers(self) -> List[int]:
        if not self.history:
            return []
        mean_t = np.mean(np.stack(self.history), axis=0)
        med = float(np.median(mean_t))
        return [int(w) for w in np.flatnonzero(mean_t > self.threshold * med)]

    def hedge_plan(self, pending_arms: Sequence[int], slow_arm: int) -> List[int]:
        """Serving-side: reorder so the slow arm is polled last (its answer
        is most likely to be early-stopped away)."""
        plan = [a for a in pending_arms if a != slow_arm]
        if slow_arm in pending_arms:
            plan.append(slow_arm)
        return plan


@dataclasses.dataclass
class FaultTolerantDriver:
    """Wraps a train loop with checkpoint/restart + failure handling.

    Usage::

        driver = FaultTolerantDriver(ckpt_manager, save_every=50)
        state, start = driver.restore(state_template)
        for step in range(start, total):
            state = train_step(state, batch)
            driver.maybe_save(step, state)
            if driver.check_failures(monitor):  # -> elastic re-mesh restart
                break
    """

    ckpt: "object"
    save_every: int = 100

    def restore(self, template):
        step, state = self.ckpt.restore_latest(template)
        return state, (0 if step is None else step + 1)

    def maybe_save(self, step: int, state):
        if step % self.save_every == 0:
            self.ckpt.save(step, state)

    def check_failures(self, monitor: HeartbeatMonitor) -> List[int]:
        return monitor.dead_workers()
