"""falcon-mamba-7b — attention-free Mamba-1 SSM. [arXiv:2410.05355]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=256,
    block_pattern=("ssm",),
    tie_embeddings=False,
    dtype="bfloat16",
    num_microbatches=4,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=8,
    ssm_chunk=8,
    block_pattern=("ssm",),
    tie_embeddings=False,
    dtype="float32",
    remat=False,
)
