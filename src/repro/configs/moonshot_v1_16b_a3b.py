"""moonshot-v1-16b-a3b — MoE, 64 experts top-6 (Moonlight-16B-A3B family).
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    block_pattern=("moe",),
    rope_theta=50000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    num_microbatches=4,
    loss_chunk=1024,
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=48,
    vocab_size=512,
    num_experts=8,
    experts_per_token=2,
    block_pattern=("moe",),
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)
