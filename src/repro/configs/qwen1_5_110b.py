"""qwen1.5-110b — dense GQA with QKV bias. [hf:Qwen/Qwen1.5-*; hf]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    dtype="bfloat16",
    num_microbatches=16,
    loss_chunk=1024,
)

SMOKE = ModelConfig(
    name="qwen-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    qkv_bias=True,
    tie_embeddings=False,
    dtype="float32",
    remat=False,
)
