"""internvl2-2b — VLM: InternViT frontend (stub) + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf]

The vision frontend is a stub per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, frontend_len, d_model).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    frontend_len=256,
    rope_theta=1000000.0,
    tie_embeddings=False,
    dtype="bfloat16",
    num_microbatches=2,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    frontend="vision",
    frontend_len=8,
    tie_embeddings=False,
    dtype="float32",
    remat=False,
)
