"""musicgen-medium — decoder-only over EnCodec tokens. [arXiv:2306.05284]

The EnCodec/conditioning frontend is a stub per the assignment:
``input_specs`` provides precomputed conditioning frame embeddings.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    mlp_variant="gelu",
    frontend="audio",
    frontend_len=64,
    rope_theta=10000.0,
    tie_embeddings=False,
    dtype="bfloat16",
    num_microbatches=2,
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    mlp_variant="gelu",
    frontend="audio",
    frontend_len=8,
    tie_embeddings=False,
    dtype="float32",
    remat=False,
)
