"""smollm-135m — small llama-arch dense. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    num_microbatches=1,
)

SMOKE = ModelConfig(
    name="smollm-smoke",
    family="dense",
    num_layers=3,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)
