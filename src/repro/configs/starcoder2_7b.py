"""starcoder2-7b — dense GQA + RoPE, non-gated GELU MLP. [arXiv:2402.19173]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp_variant="gelu",
    rope_theta=100000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    num_microbatches=4,
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    family="dense",
    num_layers=2,
    d_model=72,                   # keeps 36-head-style non-pow2 ratio (9 heads)
    num_heads=9,
    num_kv_heads=3,
    d_ff=256,
    vocab_size=512,
    mlp_variant="gelu",
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)
