"""Architecture registry: ``--arch <id>`` resolves through here.

Every assigned architecture has a module exporting ``CONFIG`` (the exact
published configuration) and ``SMOKE`` (a reduced same-family config for CPU
tests). ``thrift_pool`` builds the paper's LLM-operator pool over these.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models import ModelConfig

_MODULES: Dict[str, str] = {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-2b": "internvl2_2b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "starcoder2-7b": "starcoder2_7b",
    "smollm-135m": "smollm_135m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-medium": "musicgen_medium",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE
