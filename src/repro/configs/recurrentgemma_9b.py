"""recurrentgemma-9b — hybrid RG-LRU + local attention, 2:1 pattern.
[arXiv:2402.19427]
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,                 # MQA
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    rnn_width=4096,
    local_window=2048,
    block_pattern=("rec", "rec", "attn"),
    logits_softcap=30.0,
    tie_embeddings=True,
    dtype="bfloat16",
    num_microbatches=4,
    loss_chunk=1024,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    rnn_width=64,
    local_window=16,
    block_pattern=("rec", "rec", "attn"),
    logits_softcap=30.0,
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)
