"""granite-moe-1b-a400m — MoE, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    block_pattern=("moe",),
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    num_microbatches=2,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=32,
    vocab_size=512,
    num_experts=4,
    experts_per_token=2,
    block_pattern=("moe",),
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)
