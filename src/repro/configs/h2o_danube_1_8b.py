"""h2o-danube-1.8b — dense llama/mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    window=4096,                  # sliding-window attention
    rope_theta=10000.0,
    tie_embeddings=False,
    dtype="bfloat16",
    num_microbatches=2,
)

SMOKE = ModelConfig(
    name="danube-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    window=16,
    tie_embeddings=False,
    dtype="float32",
    remat=False,
)
