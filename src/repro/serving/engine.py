"""Model-pool execution engine.

Each *arm* of the ensemble is an operator with a uniform interface:
``classify_batch(queries) -> class ids`` plus a per-query cost and a
simulated latency (proportional to FLOPs on this CPU container; on a real
cluster the engine dispatches to per-arm serving replicas).

Two arm families:
  * :class:`LMArm` — a real JAX model (repro.models.LM) classifying by
    constrained decoding over class-signature tokens;
  * :class:`OracleArm` — Bernoulli oracle from the synthetic workload
    (paper-faithful benchmark pool).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM, ModelConfig

USD_PER_FLOP = 3.5e-18          # calibrated so pool prices match Table 4's range


@dataclasses.dataclass
class LMArm:
    """A real model arm. ``classify_batch`` runs constrained decoding:
    argmax over the class-signature token logits at the answer position."""

    name: str
    model: LM
    params: Any
    class_token_ids: np.ndarray
    tokens_per_query: int = 128

    def __post_init__(self):
        cfg = self.model.cfg
        self.flops_per_query = cfg.flops_per_token(self.tokens_per_query) * self.tokens_per_query / 3.0
        self.cost = float(self.flops_per_query * USD_PER_FLOP)
        self._fwd = jax.jit(self.model.forward)

    def classify_batch(self, tokens: np.ndarray) -> np.ndarray:
        """tokens (B, S) — the answer position is the final token slot."""
        logits = self._fwd(self.params, jnp.asarray(tokens[:, :-1]))
        last = logits[:, -1]                                   # predicts final slot
        class_logits = last[:, jnp.asarray(self.class_token_ids)]
        return np.asarray(jnp.argmax(class_logits, axis=-1), np.int64)

    def latency_s(self, batch: int) -> float:
        return 1e-12 * self.flops_per_query * batch            # simulated


@dataclasses.dataclass
class OracleArm:
    """Bernoulli oracle arm over an OracleWorkload."""

    name: str
    workload: Any
    arm_index: int
    seed: int = 0

    def __post_init__(self):
        self.cost = float(self.workload.costs[self.arm_index])
        self._rng = np.random.default_rng(self.seed + 7919 * self.arm_index)

    def classify_batch(self, queries: Sequence) -> np.ndarray:
        """queries: sequence of (cluster_id, label)."""
        out = np.empty(len(queries), np.int64)
        for i, (cid, label) in enumerate(queries):
            out[i] = self.workload.invoke(self.arm_index, cid, label, self._rng)
        return out

    def latency_s(self, batch: int) -> float:
        return 1e-4 * self.cost / max(self.workload.costs.min(), 1e-12) * batch


@dataclasses.dataclass
class PoolEngine:
    """Holds the arm pool; executes per-arm batched calls with accounting."""

    arms: List[Any]

    @property
    def costs(self) -> np.ndarray:
        return np.asarray([a.cost for a in self.arms], np.float64)

    def invoke_arm(self, arm_idx: int, queries, active: np.ndarray) -> np.ndarray:
        """Run one arm on the active subset; inactive slots return -1."""
        out = np.full(len(queries), -1, np.int64)
        idx = np.flatnonzero(active)
        if idx.size == 0:
            return out
        if isinstance(queries, np.ndarray):
            sub = queries[idx]
        else:
            sub = [queries[i] for i in idx]
        out[idx] = self.arms[arm_idx].classify_batch(sub)
        return out
