"""Model-pool execution engine.

Each *arm* of the ensemble is an operator with a uniform interface:
``classify_batch(queries) -> class ids`` plus a per-query cost and a
simulated latency (proportional to FLOPs on this CPU container; on a real
cluster the engine dispatches to per-arm serving replicas).

Two arm families:
  * :class:`LMArm` — a real JAX model (repro.models.LM) classifying by
    constrained decoding over class-signature tokens;
  * :class:`OracleArm` — Bernoulli oracle from the synthetic workload
    (paper-faithful benchmark pool).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import LM, ModelConfig

USD_PER_FLOP = 3.5e-18          # calibrated so pool prices match Table 4's range


@dataclasses.dataclass
class LMArm:
    """A real model arm. ``classify_batch`` runs constrained decoding:
    argmax over the class-signature token logits at the answer position."""

    name: str
    model: LM
    params: Any
    class_token_ids: np.ndarray
    tokens_per_query: int = 128
    # Self-hosted model: invoking it costs FLOPs we already own, not metered
    # API dollars — speculative invocation is free throughput.
    metered: bool = False

    def __post_init__(self):
        cfg = self.model.cfg
        self.flops_per_query = cfg.flops_per_token(self.tokens_per_query) * self.tokens_per_query / 3.0
        self.cost = float(self.flops_per_query * USD_PER_FLOP)
        self._fwd = jax.jit(self.model.forward)

    def classify_batch(self, tokens: np.ndarray) -> np.ndarray:
        """tokens (B, S) — the answer position is the final token slot."""
        logits = self._fwd(self.params, jnp.asarray(tokens[:, :-1]))
        last = logits[:, -1]                                   # predicts final slot
        class_logits = last[:, jnp.asarray(self.class_token_ids)]
        return np.asarray(jnp.argmax(class_logits, axis=-1), np.int64)

    def latency_s(self, batch: int) -> float:
        return 1e-12 * self.flops_per_query * batch            # simulated


@dataclasses.dataclass
class OracleArm:
    """Bernoulli oracle arm over an OracleWorkload."""

    name: str
    workload: Any
    arm_index: int
    seed: int = 0
    # Set True to model a metered upstream API arm: every invocation bills
    # real money, so the router's speculation switch (see
    # ``ThriftRouter.begin_route``) must not gather its responses for waves
    # the Prop. 4 stop rule may cancel.
    metered: bool = False

    def __post_init__(self):
        self.cost = float(self.workload.costs[self.arm_index])
        self._rng = np.random.default_rng(self.seed + 7919 * self.arm_index)
        # simulated per-query latency, snapshotted once (latency_s sits on
        # the scheduler's per-flush accounting path)
        self._lat_per_query = 1e-4 * self.cost / max(
            float(self.workload.costs.min()), 1e-12
        )

    def classify_batch(self, queries: Sequence) -> np.ndarray:
        """queries: sequence of (cluster_id, label) — fully vectorized so
        oracle-pool throughput benchmarks measure the router, not the oracle."""
        q = np.asarray(queries, np.int64).reshape(-1, 2)
        return self.workload.invoke_batch(self.arm_index, q[:, 0], q[:, 1], self._rng)

    def latency_s(self, batch: int) -> float:
        return self._lat_per_query * batch


@dataclasses.dataclass
class PoolEngine:
    """Holds the arm pool; executes per-arm batched calls with accounting.

    When every arm is an :class:`OracleArm` over one shared workload, the
    engine exposes a pooled fast path: a wave of heterogeneous arm
    assignments is answered by a single vectorized ``invoke_assigned`` call
    (one rng draw per query) instead of one ``classify_batch`` per distinct
    arm. Mixed or model-backed pools fall back to grouped per-arm calls.
    """

    arms: List[Any]
    # Optional arm-level fault injection (see repro.distributed.fault):
    # draws are evaluated host-side on the original wave schedule, never
    # inside traced code. None / inactive policies cost nothing.
    fault_policy: Optional[Any] = None

    def __post_init__(self):
        self._workload = None
        if self.arms and all(isinstance(a, OracleArm) for a in self.arms):
            workloads = {id(a.workload) for a in self.arms}
            if len(workloads) == 1:
                self._workload = self.arms[0].workload
                self._workload_arm = np.asarray(
                    [a.arm_index for a in self.arms], np.int64
                )
                # SFC64: ~2x faster than PCG64 for the pooled draw that
                # dominates speculative grid invocation; any counter-based
                # generator is fine for the synthetic oracle
                self._pool_rng = np.random.Generator(
                    np.random.SFC64(self.arms[0].seed + 104729)
                )

    @property
    def costs(self) -> np.ndarray:
        return np.asarray([a.cost for a in self.arms], np.float64)

    @property
    def metered_mask(self) -> np.ndarray:
        """(L,) bool — arms whose invocations bill a metered upstream API.
        Arms without a ``metered`` attribute count as unmetered (oracle /
        tabular / self-hosted pools), so speculation stays free for them."""
        return np.asarray(
            [bool(getattr(a, "metered", False)) for a in self.arms], bool
        )

    @property
    def any_metered(self) -> bool:
        return bool(self.metered_mask.any())

    @property
    def pooled(self) -> bool:
        """True when every arm shares one oracle workload, enabling the
        single-call heterogeneous fast paths (``invoke_rows`` pooled draw,
        the router's all-cells speculative gather)."""
        return self._workload is not None

    def fault_grid(self, sched_T: np.ndarray, row_offset: int = 0):
        """(codes, failed) for a wave schedule, or (None, None) when no
        active fault policy is attached. ``codes`` is the (T, B) int8 fault
        grid (see FAULT_* in repro.distributed.fault); ``failed`` marks
        cells whose arm produced no usable response (timeout or error —
        silently-degraded cells still answer, just wrongly).

        ``row_offset`` positions this schedule's rows inside a logically
        fused batch (overlapped replica dispatch) so per-worker draws match
        the fused dispatch cell for cell."""
        policy = self.fault_policy
        if policy is None or not policy.active:
            return None, None
        from repro.distributed.fault import FAULT_ERROR, FAULT_TIMEOUT

        codes = policy.grid_codes(sched_T, row_offset=row_offset)
        return codes, (codes == FAULT_TIMEOUT) | (codes == FAULT_ERROR)

    def fingerprint(self) -> bytes:
        """Digest of the pool's pricing identity. The PlanService folds this
        into every plan-cache key, so re-pricing an arm (or swapping the
        pool) invalidates cached selections instead of serving stale plans."""
        return np.ascontiguousarray(self.costs).tobytes()

    def prepare_payloads(self, queries) -> Any:
        """One-time per-batch payload conversion for fast row gathering."""
        if self._workload is not None:
            return np.asarray(queries, np.int64)    # (B, 2) (cluster, label)
        if isinstance(queries, np.ndarray):
            return queries
        try:
            arr = np.asarray(queries)
        except Exception:
            return queries
        return queries if arr.dtype == object else arr

    def invoke_arm(self, arm_idx: int, queries, active: np.ndarray) -> np.ndarray:
        """Run one arm on the active subset; inactive slots return -1."""
        out = np.full(len(queries), -1, np.int64)
        idx = np.flatnonzero(active)
        if idx.size == 0:
            return out
        if isinstance(queries, np.ndarray):
            sub = queries[idx]
        else:
            sub = [queries[i] for i in idx]
        out[idx] = self.arms[arm_idx].classify_batch(sub)
        return out

    def invoke_grid(self, sched_T: np.ndarray, payloads: np.ndarray) -> np.ndarray:
        """Whole-grid pooled invocation: serve cell (t, b) with arm
        ``sched_T[t, b]`` (cells flagged -1 are drawn on arm 0 — callers
        mask them out). Pooled-workload engines only; broadcasts the
        (cluster, label) payload columns instead of gathering rows, so the
        jitted router's speculative gather is a single vectorized draw.

        Returns (T, B) class ids."""
        assert self._workload is not None, "invoke_grid needs a pooled engine"
        T, B = sched_T.shape
        arms = self._workload_arm[np.maximum(sched_T.ravel(), 0)]
        cl = np.broadcast_to(payloads[:, 0], (T, B)).reshape(-1)
        lab = np.broadcast_to(payloads[:, 1], (T, B)).reshape(-1)
        return self._workload.invoke_assigned(
            arms, cl, lab, self._pool_rng
        ).reshape(T, B)

    def invoke_rows(
        self, arm_ids: np.ndarray, queries, rows: np.ndarray
    ) -> np.ndarray:
        """One wavefront step: query ``rows[i]`` is served by ``arm_ids[i]``.

        Returns (n,) class ids aligned with ``rows``. ``queries`` should be
        the output of :meth:`prepare_payloads`.
        """
        arm_ids = np.asarray(arm_ids, np.int64)
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return np.zeros(0, np.int64)
        if self._workload is not None:
            if not isinstance(queries, np.ndarray):
                queries = np.asarray(queries, np.int64)
            q = queries[rows]
            return self._workload.invoke_assigned(
                self._workload_arm[arm_ids], q[:, 0], q[:, 1], self._pool_rng
            )
        out = np.empty(rows.size, np.int64)
        for a in np.unique(arm_ids):
            m = arm_ids == a
            sel = rows[m]
            if isinstance(queries, np.ndarray):
                sub = queries[sel]
            else:
                sub = [queries[i] for i in sel]
            out[m] = self.arms[int(a)].classify_batch(sub)
        return out
