"""ThriftLLM router: per-query-class selection + wavefront adaptive invocation.

Serving pipeline per batch (Figure 1 of the paper, batched for TPU):
  1. embed queries, map to historical clusters -> p-hat vector per query
  2. group queries by (cluster, budget); SurGreedyLLM selection per group
     (cached — selection depends only on the p-vector, K and budget)
  3. *wavefront* adaptive invocation: arms of the selected set are invoked
     in decreasing-p order; before each wave, every query's early-stop
     condition F(T*)·H2 <= H1 (Prop. 4) is evaluated and stopped queries
     drop out of the wave — batch-efficient on accelerators while returning
     exactly the predictions of the full ensemble at reduced cost.
  4. belief aggregation (the belief_aggregate kernel on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.belief import empty_log_belief, log_weight
from repro.core.estimation import SuccessProbEstimator
from repro.core.selection import ThriftLLM

from .engine import PoolEngine


@dataclasses.dataclass
class RouteResult:
    predictions: np.ndarray          # (B,)
    costs: np.ndarray                # (B,) realized USD
    planned_costs: np.ndarray        # (B,) full-ensemble USD
    arms_used: List[List[int]]       # per query
    clusters: np.ndarray             # (B,)


class ThriftRouter:
    def __init__(
        self,
        engine: PoolEngine,
        estimator: SuccessProbEstimator,
        num_classes: int,
        eps: float = 0.1,
        delta: float = 0.01,
        seed: int = 0,
    ):
        self.engine = engine
        self.estimator = estimator
        self.num_classes = int(num_classes)
        self.selector = ThriftLLM(engine.costs, eps=eps, delta=delta, seed=seed)

    # ------------------------------------------------------------------
    def route_batch(
        self,
        queries: Any,                    # arm-payloads, len B (array or list)
        embeddings: np.ndarray,          # (B, d)
        budget: float,
        stop_margin: float = 1e-9,
    ) -> RouteResult:
        B = len(queries)
        K = self.num_classes
        cluster_ids = self.estimator.lookup_batch(embeddings)

        predictions = np.zeros(B, np.int64)
        costs = np.zeros(B, np.float64)
        planned = np.zeros(B, np.float64)
        arms_used: List[List[int]] = [[] for _ in range(B)]

        for cid in np.unique(cluster_ids):
            q_idx = np.flatnonzero(cluster_ids == cid)
            stats = self.estimator.clusters[int(cid)]
            p = stats.p_hat
            sel = self.selector.select(p, K, budget)
            order = sorted(sel.chosen, key=lambda i: -p[i])
            w = log_weight(np.clip(p, 1e-4, 1 - 1e-4), K)
            empty = empty_log_belief(p)

            nb = q_idx.size
            beliefs = np.full((nb, K), empty, np.float64)
            counts = np.zeros((nb, K), np.int64)
            active = np.ones(nb, bool)
            planned[q_idx] = float(self.engine.costs[order].sum()) if order else 0.0

            for wave, arm in enumerate(order):
                # early-stop check per query (Prop. 4)
                log_f = float(np.sum(w[order[wave:]]))
                srt = np.sort(beliefs, axis=1)
                h1, h2 = srt[:, -1], srt[:, -2]
                still = active & (log_f + h2 > h1 - stop_margin)
                if not still.any():
                    break
                full_active = np.zeros(B, bool)
                full_active[q_idx[still]] = True
                resp = self.engine.invoke_arm(arm, queries, full_active)[q_idx]
                hit = np.flatnonzero(still)
                for j in hit:
                    r = int(resp[j])
                    if counts[j, r] == 0:
                        beliefs[j, r] = w[arm]
                    else:
                        beliefs[j, r] += w[arm]
                    counts[j, r] += 1
                    costs[q_idx[j]] += self.engine.costs[arm]
                    arms_used[q_idx[j]].append(arm)
                active = still

            predictions[q_idx] = np.argmax(beliefs, axis=1)

        return RouteResult(
            predictions=predictions,
            costs=costs,
            planned_costs=planned,
            arms_used=arms_used,
            clusters=cluster_ids,
        )
