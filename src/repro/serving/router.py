"""ThriftLLM router: per-query-class selection + batched wavefront invocation.

Serving pipeline per batch (Figure 1 of the paper, batched for TPU):
  1. embed queries, map to historical clusters -> p-hat vector per query
  2. group queries by (cluster, budget); SurGreedyLLM selection per group
     (cached — selection depends only on the p-vector, K and budget), and the
     derived wave plan (arm order, log-weights, Prop. 4 residuals) is cached
     per (p-vector, budget) too
  3. *wavefront* adaptive invocation across the WHOLE batch: every group's
     selected arms are laid out as a per-query wave schedule (arm invoked at
     wave t), heterogeneous (cluster, budget) groups advance through one
     shared wave loop, and before each wave every in-flight query's
     early-stop condition F(T*)·H2 <= H1 (Prop. 4) is evaluated as one array
     op. The wavefront *compacts*: stopped queries are dropped from the
     index set, so wave t only touches the queries still in flight, and each
     wave issues one heterogeneous-arm engine call
     (:meth:`PoolEngine.invoke_rows`). No per-query Python work happens in
     the loop: belief state is a (B, K) log-belief table updated by
     scatter-adds, so the engine returns exactly the predictions of
     per-query ``adaptive_invoke`` at batch throughput.
  4. belief aggregation: float64 numpy scatter tables by default, or the
     ``belief_aggregate`` Pallas kernel (``use_kernel=True``) which
     recomputes the in-flight rows' beliefs from the response history each
     wave — identical masking semantics, float32 accumulation on TPU.
     Caveat: the kernel backend evaluates the Prop. 4 stop rule on float32
     beliefs, so a query whose margin lands within float32 resolution
     (~1e-7) of the STOP_MARGIN boundary may take one wave more or fewer
     than the float64 path; everywhere else the two backends are identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.belief import empty_log_belief, log_weight, tie_break_argmax
from repro.core.estimation import SuccessProbEstimator
from repro.core.selection import STOP_MARGIN, ThriftLLM, adaptive_invoke
from repro.core.types import clip_probs

from .engine import PoolEngine


class RouteResult:
    """Batched routing output. ``arms_used`` is derived lazily from the
    (schedule, invoked) matrices so the hot path never builds Python lists."""

    def __init__(
        self,
        predictions: np.ndarray,         # (B,)
        costs: np.ndarray,               # (B,) realized USD
        planned_costs: np.ndarray,       # (B,) full-ensemble USD
        clusters: np.ndarray,            # (B,)
        budgets: np.ndarray,             # (B,) per-query budget applied
        schedule: np.ndarray,            # (B, T) arm id per wave, -1 = none
        responses: np.ndarray,           # (B, T) class id per wave, -1 = not run
        invoked: np.ndarray,             # (B, T) bool, wave actually ran
        arm_query_counts: np.ndarray,    # (L,) queries served per arm
        waves: int,
    ):
        self.predictions = predictions
        self.costs = costs
        self.planned_costs = planned_costs
        self.clusters = clusters
        self.budgets = budgets
        self.schedule = schedule
        self.responses = responses
        self.invoked = invoked
        self.arm_query_counts = arm_query_counts
        self.waves = waves
        self._arms_used: Optional[List[List[int]]] = None

    @property
    def arms_used(self) -> List[List[int]]:
        """Per query, arms actually invoked in invocation order."""
        if self._arms_used is None:
            self._arms_used = [
                self.schedule[b, self.invoked[b]].tolist()
                for b in range(self.schedule.shape[0])
            ]
        return self._arms_used


@dataclasses.dataclass
class _GroupPlan:
    """Wave plan of one (cluster p-vector, budget) group."""

    order: np.ndarray        # (n,) arm ids in decreasing-p invocation order
    weights: np.ndarray      # (n,) log belief weight per wave
    residual: np.ndarray     # (n,) log F of arms t..n-1 (Prop. 4)
    wave_costs: np.ndarray   # (n,) USD of order[t]
    empty: float             # empty-class log belief
    planned: float           # full selected-set cost


class ThriftRouter:
    def __init__(
        self,
        engine: PoolEngine,
        estimator: SuccessProbEstimator,
        num_classes: int,
        eps: float = 0.1,
        delta: float = 0.01,
        seed: int = 0,
        use_kernel: bool = False,
    ):
        self.engine = engine
        self.estimator = estimator
        self.num_classes = int(num_classes)
        self.use_kernel = bool(use_kernel)
        self.selector = ThriftLLM(
            engine.costs, eps=eps, delta=delta, seed=seed, use_kernel=use_kernel
        )
        self._plan_cache: Dict[Tuple[bytes, float], _GroupPlan] = {}

    # ------------------------------------------------------------------
    # Planning: (cluster, budget) groups -> one cross-group wave schedule
    # ------------------------------------------------------------------
    def _group_plan(self, cid: int, budget: float) -> _GroupPlan:
        p = self.estimator.clusters[cid].p_hat
        key = (p.tobytes(), budget)
        plan = self._plan_cache.get(key)
        if plan is not None:
            return plan
        K = self.num_classes
        pc = clip_probs(p)
        sel = self.selector.select(p, K, budget)
        # identical ordering to adaptive_invoke: stable sort on clipped p
        order = np.asarray(sorted(list(sel.chosen), key=lambda i: -pc[i]), np.int64)
        w_order = log_weight(pc, K)[order]
        # residual log F exactly as the sequential loop sums it each round
        residual = np.asarray(
            [np.sum(w_order[t:]) for t in range(order.size)], np.float64
        )
        plan = _GroupPlan(
            order=order,
            weights=w_order,
            residual=residual,
            wave_costs=self.engine.costs[order],
            empty=empty_log_belief(pc),
            planned=float(self.engine.costs[order].sum()) if order.size else 0.0,
        )
        self._plan_cache[key] = plan
        return plan

    def _batch_plan(self, cluster_ids: np.ndarray, budgets: np.ndarray):
        """Merge per-group plans into batch-wide (B, T) wave matrices.

        Groups are the unique (cluster, budget) pairs; the per-group plan
        rows are stacked once into (G, T) tables and expanded to the batch
        by a single gather on the group-inverse index."""
        if budgets[0] == budgets[-1] and (budgets == budgets[0]).all():
            c_vals, inverse = np.unique(cluster_ids, return_inverse=True)
            group_keys = [(int(c), float(budgets[0])) for c in c_vals]
        else:
            b_vals, b_inv = np.unique(budgets, return_inverse=True)
            c_vals, c_inv = np.unique(cluster_ids, return_inverse=True)
            combo_vals, inverse = np.unique(
                c_inv * b_vals.size + b_inv, return_inverse=True
            )
            group_keys = [
                (int(c_vals[v // b_vals.size]), float(b_vals[v % b_vals.size]))
                for v in combo_vals
            ]
        plans = [self._group_plan(c, b) for c, b in group_keys]
        G = len(plans)
        T = max(1, max(p.order.size for p in plans))
        order_m = np.full((G, T), -1, np.int64)
        w_m = np.zeros((G, T), np.float64)
        res_m = np.full((G, T), -np.inf, np.float64)
        wc_m = np.zeros((G, T), np.float64)
        empty_v = np.empty(G, np.float64)
        planned_v = np.empty(G, np.float64)
        for g, plan in enumerate(plans):
            n = plan.order.size
            order_m[g, :n] = plan.order
            w_m[g, :n] = plan.weights
            res_m[g, :n] = plan.residual
            wc_m[g, :n] = plan.wave_costs
            empty_v[g] = plan.empty
            planned_v[g] = plan.planned
        return (
            order_m[inverse],
            w_m[inverse],
            res_m[inverse],
            wc_m[inverse],
            empty_v[inverse],
            planned_v[inverse],
        )

    # ------------------------------------------------------------------
    # Belief backend: float64 scatter tables or the Pallas kernel
    # ------------------------------------------------------------------
    def _kernel_beliefs(
        self, responses: np.ndarray, weights: np.ndarray, empty: np.ndarray
    ) -> np.ndarray:
        import jax.numpy as jnp

        from repro.kernels import ops

        bel, _ = ops.belief_aggregate(
            jnp.asarray(responses, jnp.int32),
            jnp.asarray(weights, jnp.float32),
            jnp.asarray(empty, jnp.float32),
            self.num_classes,
        )
        return np.asarray(bel, np.float64)

    # ------------------------------------------------------------------
    def route_batch(
        self,
        queries: Any,                    # arm-payloads, len B (array or list)
        embeddings: np.ndarray,          # (B, d)
        budget: Any,                     # scalar or (B,) per-query budgets
        stop_margin: float = STOP_MARGIN,
        rng: Optional[np.random.Generator] = None,
    ) -> RouteResult:
        B = len(queries)
        K = self.num_classes
        budgets = np.broadcast_to(np.asarray(budget, np.float64), (B,))
        if B == 0:
            return RouteResult(
                predictions=np.zeros(0, np.int64),
                costs=np.zeros(0, np.float64),
                planned_costs=np.zeros(0, np.float64),
                clusters=np.zeros(0, np.int64),
                budgets=np.asarray(budgets),
                schedule=np.full((0, 1), -1, np.int64),
                responses=np.full((0, 1), -1, np.int64),
                invoked=np.zeros((0, 1), bool),
                arm_query_counts=np.zeros(len(self.engine.arms), np.int64),
                waves=0,
            )
        cluster_ids = self.estimator.lookup_batch(embeddings)
        schedule, weights, residual, wave_costs, empty, planned = self._batch_plan(
            cluster_ids, budgets
        )
        T = schedule.shape[1]
        L = len(self.engine.arms)
        payloads = self.engine.prepare_payloads(queries)

        # wave-major layouts: contiguous (B,) row per wave in the hot loop
        sched_T = np.ascontiguousarray(schedule.T)
        w_T = np.ascontiguousarray(weights.T)
        res_T = np.ascontiguousarray(residual.T)
        wc_T = np.ascontiguousarray(wave_costs.T)
        resp_T = np.full((T, B), -1, np.int64)

        vote = np.zeros((B, K), np.float64)      # scatter-add log-weight table
        voted = np.zeros((B, K), bool)           # any vote -> real belief
        costs = np.zeros(B, np.float64)
        arm_query_counts = np.zeros(L, np.int64)
        cur = np.arange(B)                       # queries still in flight
        waves = 0

        for t in range(T):
            # Prop. 4 early-stop on the in-flight set, one mask per wave
            if self.use_kernel:
                # per-row independent contraction: feeding only in-flight rows
                # gives identical beliefs at a fraction of the kernel work
                bel = self._kernel_beliefs(
                    np.ascontiguousarray(resp_T.T[cur]), weights[cur], empty[cur]
                )
            else:
                bel = np.where(voted[cur], vote[cur], empty[cur][:, None])
            if K >= 2:
                part = np.partition(bel, K - 2, axis=1)
                h1, h2 = part[:, K - 1], part[:, K - 2]
            else:
                h1, h2 = bel[:, 0], np.full(cur.size, -np.inf)
            sched_t = sched_T[t]
            keep = (sched_t[cur] >= 0) & (res_T[t][cur] + h2 > h1 - stop_margin)
            cur = cur[keep]
            if cur.size == 0:
                break
            waves += 1
            arms_t = sched_t[cur]
            votes = self.engine.invoke_rows(arms_t, payloads, cur)
            arm_query_counts += np.bincount(arms_t, minlength=L)
            vote[cur, votes] += w_T[t][cur]
            voted[cur, votes] = True
            costs[cur] += wc_T[t][cur]
            resp_T[t][cur] = votes

        responses = np.ascontiguousarray(resp_T.T)
        if self.use_kernel:
            beliefs = self._kernel_beliefs(responses, weights, empty)
        else:
            beliefs = np.where(voted, vote, empty[:, None])
        predictions, _ = tie_break_argmax(beliefs, rng)
        invoked = responses >= 0
        return RouteResult(
            predictions=predictions,
            costs=costs,
            planned_costs=planned,
            clusters=cluster_ids,
            budgets=np.asarray(budgets),
            schedule=schedule,
            responses=responses,
            invoked=invoked,
            arm_query_counts=arm_query_counts,
            waves=waves,
        )

    # ------------------------------------------------------------------
    def route_batch_reference(
        self,
        queries: Any,
        embeddings: np.ndarray,
        budget: Any,
        rng: Optional[np.random.Generator] = None,
    ) -> RouteResult:
        """Sequential oracle: one ``adaptive_invoke`` per query.

        The semantics source for :meth:`route_batch` (equivalence-tested in
        ``tests/test_router_batched.py``) and the baseline of the serving
        throughput benchmark. Shares the selection cache with the batched
        path, so both route the same selected sets.

        Exact output equality with :meth:`route_batch` holds for
        *deterministic* arms (responses a pure function of (arm, query),
        e.g. the test TabularArm or LMArm). Stochastic ``OracleArm`` pools
        consume different rng streams on the two paths (pooled
        ``invoke_rows`` draws vs per-arm draws here), so per-seed
        realizations differ even though the distributions match.
        """
        B = len(queries)
        K = self.num_classes
        budgets = np.broadcast_to(np.asarray(budget, np.float64), (B,))
        cluster_ids = self.estimator.lookup_batch(embeddings)
        L = len(self.engine.arms)

        predictions = np.zeros(B, np.int64)
        costs = np.zeros(B, np.float64)
        planned = np.zeros(B, np.float64)
        arms_used: List[List[int]] = []
        resp_rows: List[np.ndarray] = []
        arm_query_counts = np.zeros(L, np.int64)
        for j in range(B):
            p = self.estimator.clusters[int(cluster_ids[j])].p_hat
            sel = self.selector.select(p, K, float(budgets[j]))

            def invoke_one(arm: int) -> int:
                mask = np.zeros(B, bool)
                mask[j] = True
                return int(self.engine.invoke_arm(int(arm), queries, mask)[j])

            inv = adaptive_invoke(
                list(sel.chosen), p, K, invoke_one, rng=rng, costs=self.engine.costs
            )
            predictions[j] = inv.prediction
            costs[j] = inv.cost
            planned[j] = inv.planned_cost
            arms_used.append([int(a) for a in inv.used])
            resp_rows.append(np.asarray(inv.responses, np.int64))
            arm_query_counts[inv.used] += 1
        T = max(1, max((len(a) for a in arms_used), default=1))
        schedule = np.full((B, T), -1, np.int64)
        responses = np.full((B, T), -1, np.int64)
        invoked = np.zeros((B, T), bool)
        for j, used in enumerate(arms_used):
            schedule[j, : len(used)] = used
            responses[j, : len(used)] = resp_rows[j]
            invoked[j, : len(used)] = True
        res = RouteResult(
            predictions=predictions,
            costs=costs,
            planned_costs=planned,
            clusters=cluster_ids,
            budgets=np.asarray(budgets),
            schedule=schedule,
            responses=responses,
            invoked=invoked,
            arm_query_counts=arm_query_counts,
            waves=T,
        )
        res._arms_used = arms_used
        return res
