"""ThriftLLM router: per-query-class selection + batched wavefront invocation.

Serving pipeline per batch (Figure 1 of the paper, batched for TPU):
  1. embed queries, map to historical clusters -> p-hat vector per query
  2. group queries by (cluster, budget); SurGreedyLLM selection per group is
     memoized by the :class:`~repro.serving.plans.PlanService` — selection
     depends only on (cluster, budget, pool fingerprint) — and the derived
     wave plan (arm order, log-weights, Prop. 4 residuals) is what the hot
     path consumes. Hot pairs can be precomputed ahead of traffic and the
     cache invalidates itself when the pool changes.
  3. *wavefront* adaptive invocation across the WHOLE batch. Two data-plane
     implementations with identical semantics for deterministic arms:

     * :meth:`route_batch` (default, ``jit_waves=True``) — the **jitted
       wave loop**. The per-group plans are padded to one fixed
       (B, max_waves) layout (bucketed to limit recompilation), every
       scheduled (query, wave) response is gathered up front in a single
       heterogeneous-arm engine call, and the entire wave loop — Prop. 4
       early-stop mask, belief accumulation, in-flight carry — runs as one
       jitted on-device program in float64. Because responses are
       pre-gathered, the sequential recurrence collapses into a parallel
       prefix scan (see :func:`_wave_scan`); Python never touches the
       loop and there is one dispatch per batch.
     * :meth:`route_batch_reference` — the compacting host-side wavefront
       (PR 1). Stopped queries are dropped from the index set each wave and
       each wave issues one engine call for the rows still in flight, so
       arms are only ever invoked for queries that need them. This is the
       fallback for pools where speculative invocation costs real money
       (live LLM APIs), and the semantics pin for equivalence tests.

     The trade: the jitted loop invokes every *scheduled* (query, wave)
     cell — including waves the stop rule later masks out — so realized
     **reported** costs still count only invoked waves, but the engine does
     speculative work. For oracle/tabular/self-hosted pools that is pure
     throughput; for metered upstream APIs use ``jit_waves=False``.
  4. belief aggregation: float64 scatter tables by default, or the
     ``belief_aggregate`` Pallas kernel (``use_kernel=True``), dispatched
     from *inside* the jitted scan — identical masking semantics, float32
     accumulation on TPU. Caveat: the kernel backend evaluates the Prop. 4
     stop rule on float32 beliefs, so a query whose margin lands within
     float32 resolution (~1e-7) of the STOP_MARGIN boundary may take one
     wave more or fewer than the float64 path; everywhere else the two
     backends are identical.
"""
from __future__ import annotations

import functools
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.belief import tie_break_argmax
from repro.core.estimation import SuccessProbEstimator
from repro.core.selection import STOP_MARGIN, ThriftLLM, adaptive_invoke
from repro.kernels import ops

from .engine import PoolEngine
from .plans import GroupPlan, PlanService, stack_plans

# retained name for PR 1 call sites / pickles
_GroupPlan = GroupPlan


class RouteResult:
    """Batched routing output.

    One instance summarizes a whole ``route_batch`` call. Fields:

    Attributes:
      predictions: (B,) aggregated class id per query (Eq. 4 argmax with
        shared tie-breaking).
      costs: (B,) realized USD per query — only waves actually invoked.
      planned_costs: (B,) USD of each query's full selected set (the spend
        ceiling if no early stop fires; ``costs <= planned_costs`` always).
      clusters: (B,) historical cluster each query mapped to.
      budgets: (B,) per-query budget applied.
      schedule: (B, T) arm id scheduled at wave t, ``-1`` = no arm (plan
        shorter than T).
      responses: (B, T) class id returned at wave t, ``-1`` = wave not run.
      invoked: (B, T) bool — wave t really ran for this query (the Prop. 4
        stop rule had not fired and an arm was scheduled).
      arm_query_counts: (L,) number of queries each pool arm actually
        served — the scheduler's latency accounting input.
      waves: number of waves the batch executed before every query stopped.

    ``arms_used`` is derived lazily from the (schedule, invoked) matrices so
    the hot path never builds Python lists.
    """

    def __init__(
        self,
        predictions: np.ndarray,         # (B,)
        costs: np.ndarray,               # (B,) realized USD
        planned_costs: np.ndarray,       # (B,) full-ensemble USD
        clusters: np.ndarray,            # (B,)
        budgets: np.ndarray,             # (B,) per-query budget applied
        schedule: np.ndarray,            # (B, T) arm id per wave, -1 = none
        responses: np.ndarray,           # (B, T) class id per wave, -1 = not run
        invoked: np.ndarray,             # (B, T) bool, wave actually ran
        arm_query_counts: np.ndarray,    # (L,) queries served per arm
        waves: int,
    ):
        self.predictions = predictions
        self.costs = costs
        self.planned_costs = planned_costs
        self.clusters = clusters
        self.budgets = budgets
        self.schedule = schedule
        self.responses = responses
        self.invoked = invoked
        self.arm_query_counts = arm_query_counts
        self.waves = waves
        self._arms_used: Optional[List[List[int]]] = None

    @property
    def arms_used(self) -> List[List[int]]:
        """Per query, arms actually invoked in invocation order."""
        if self._arms_used is None:
            self._arms_used = [
                self.schedule[b, self.invoked[b]].tolist()
                for b in range(self.schedule.shape[0])
            ]
        return self._arms_used


# ---------------------------------------------------------------------------
# The on-device wave loop
# ---------------------------------------------------------------------------


def _bucket(n: int, *, base: int) -> int:
    """Round ``n`` up so the jitted loop compiles once per bucket instead
    of once per exact (B, T): multiples of ``base`` up to 4x base (tight —
    padded waves/rows cost real device work), powers of two beyond."""
    if n <= 4 * base:
        return max(base, -(-n // base) * base)
    m = 4 * base
    while m < n:
        m *= 2
    return m


@functools.partial(jax.jit, static_argnames=("num_classes", "use_kernel"))
def _wave_scan(
    schedule: jnp.ndarray,    # (T, B) int32 arm ids, -1 = none (wave-major)
    responses: jnp.ndarray,   # (T, B) int32 precomputed responses, -1 = none
    weights: jnp.ndarray,     # (T, B) f64 log belief weight per wave
    residual: jnp.ndarray,    # (T, B) f64 Prop. 4 log F residuals
    empty: jnp.ndarray,       # (B,)  f64 empty-class log belief
    stop_margin,
    *,
    num_classes: int,
    use_kernel: bool,
):
    """Entire wavefront loop as one fused on-device program.

    Because the per-wave responses are gathered up front, each query's
    trajectory is a pure *prefix* of its schedule: if it is still in flight
    at wave t it has invoked exactly waves 0..t-1. The sequential adaptive
    loop therefore collapses into a prefix scan: cumulative (T+1, B, K)
    belief tables (index t = "beliefs before wave t"), after which every
    wave's Prop. 4 stop decision is evaluated at once and each query's stop
    wave is the first failing prefix. The prefix accumulation and the
    K-class top-2 are unrolled over the static (T, K) axes into pure
    elementwise chains — XLA fuses them into a handful of kernels, the
    adds happen in exactly the host loop's sequential order (bit-identical
    float64 beliefs, no reassociation), and everything is wave-major so
    each step touches contiguous (B,)/(B, K) slabs. One compile per
    (T, B, K) bucket; the caller pads to buckets.

    Runs in float64 under ``jax.experimental.enable_x64``. Under
    ``use_kernel`` the prefix histories are instead aggregated by a single
    prefix-expanded ``belief_aggregate`` Pallas kernel call, so the stop
    rule sees exactly the float32 beliefs the kernel-backed reference loop
    sees (the documented ~1e-7 stop-boundary caveat).

    Returns (stop_wave (B,) int — number of waves invoked per query,
    predictions (B,) int via first-max argmax, log-beliefs (B, K) at the
    stop wave).
    """
    T, B = schedule.shape
    K = num_classes
    f_dtype = weights.dtype
    class_ids = jnp.arange(K, dtype=responses.dtype)

    if use_kernel:
        # Prefix-expanded kernel dispatch: row (b, t) holds query b's
        # response history masked to waves < t; one pallas_call aggregates
        # every prefix of every query.
        resp_bt = responses.T                               # (B, T)
        hist = jnp.where(
            jnp.arange(T + 1)[None, :, None] > jnp.arange(T)[None, None, :],
            resp_bt[:, None, :],
            -1,
        )                                                   # (B, T+1, T)
        w32 = weights.T.astype(jnp.float32)
        bel32, _ = ops.belief_aggregate(
            hist.reshape(B * (T + 1), T),
            jnp.broadcast_to(w32[:, None, :], (B, T + 1, T)).reshape(-1, T),
            jnp.broadcast_to(
                empty.astype(jnp.float32)[:, None], (B, T + 1)
            ).reshape(-1),
            K,
            tile=512,
        )
        # f32 values compared in f64, matching the reference kernel path
        bel = bel32.reshape(B, T + 1, K).astype(f_dtype).transpose(1, 0, 2)
    else:
        onehot = responses[:, :, None] == class_ids[None, None, :]  # (T,B,K)
        contrib = jnp.where(onehot, weights[:, :, None], 0.0)
        votes = [jnp.zeros((B, K), f_dtype)]
        cnts = [jnp.zeros((B, K), bool)]
        for t in range(T):
            votes.append(votes[-1] + contrib[t])
            cnts.append(cnts[-1] | onehot[t])
        cumvote = jnp.stack(votes)                          # (T+1, B, K)
        cumcnt = jnp.stack(cnts)
        bel = jnp.where(cumcnt, cumvote, empty[None, :, None])

    # online top-2 over the static K axis; ties keep h2 == h1
    h1 = jnp.full((T + 1, B), -jnp.inf, f_dtype)
    h2 = h1
    for k in range(K):
        v = bel[:, :, k]
        gt = v > h1
        h2 = jnp.where(gt, h1, jnp.maximum(h2, v))
        h1 = jnp.where(gt, v, h1)
    stop = ~((schedule >= 0) & (residual + h2[:T] > h1[:T] - stop_margin))
    s = jnp.where(stop.any(axis=0), jnp.argmax(stop, axis=0), T)  # first stop
    beliefs = jnp.take_along_axis(bel, s[None, :, None], axis=0)[0]
    # first-max argmax, identical to the host path's deterministic tie-break
    preds = jnp.argmax(beliefs, axis=-1)
    return s, preds, beliefs


class ThriftRouter:
    """Batched ThriftLLM serving router.

    Args:
      engine: arm pool executor.
      estimator: cluster -> p-hat success-probability estimator.
      num_classes: label-space size K.
      eps, delta, seed: SurGreedy Monte-Carlo parameters (paper Sec. 5).
      use_kernel: route belief aggregation through the ``belief_aggregate``
        Pallas kernel (float32 accumulation, dispatched from inside the
        jitted loop).
      jit_waves: run the wave loop as one on-device ``lax.scan``
        (:meth:`route_batch`); ``False`` falls back to the compacting
        host loop (:meth:`route_batch_reference`) which never invokes arms
        speculatively.
      plan_service: optionally share a :class:`PlanService` across routers
        bound to the same pool; by default each router owns one.
    """

    def __init__(
        self,
        engine: PoolEngine,
        estimator: SuccessProbEstimator,
        num_classes: int,
        eps: float = 0.1,
        delta: float = 0.01,
        seed: int = 0,
        use_kernel: bool = False,
        jit_waves: bool = True,
        plan_service: Optional[PlanService] = None,
    ):
        self.engine = engine
        self.estimator = estimator
        self.num_classes = int(num_classes)
        self.use_kernel = bool(use_kernel)
        self.jit_waves = bool(jit_waves)
        self.selector = ThriftLLM(
            engine.costs, eps=eps, delta=delta, seed=seed, use_kernel=use_kernel
        )
        self.plans = plan_service or PlanService(
            self.selector, estimator, engine, self.num_classes
        )

    # ------------------------------------------------------------------
    # Planning: (cluster, budget) groups -> one cross-group wave schedule
    # ------------------------------------------------------------------
    def _group_plan(self, cid: int, budget: float) -> GroupPlan:
        return self.plans.plan(cid, budget)

    def _batch_plan(self, cluster_ids: np.ndarray, budgets: np.ndarray):
        """Merge per-group plans into batch-wide *wave-major* matrices.

        Groups are the unique (cluster, budget) pairs; the per-group plan
        rows are stacked once into (G, T) tables and expanded to the batch
        by a single gather on the group-inverse index. Returns
        ``(schedule (T, B), weights (T, B), residual (T, B),
        wave_costs (T, B), empty (B,), planned (B,))`` — wave-major so the
        hot paths touch contiguous (B,) rows per wave with no transposes.

        Heterogeneous-budget batches only; uniform budgets take the
        ``BatchTables`` fast path in :meth:`_plan_batch`."""
        b_vals, b_inv = np.unique(budgets, return_inverse=True)
        c_vals, c_inv = np.unique(cluster_ids, return_inverse=True)
        combo_vals, inverse = np.unique(
            c_inv * b_vals.size + b_inv, return_inverse=True
        )
        group_keys = [
            (int(c_vals[v // b_vals.size]), float(b_vals[v % b_vals.size]))
            for v in combo_vals
        ]
        plans = [self.plans.plan(c, b) for c, b in group_keys]
        order_m, fp_m, empty_v, planned_v = stack_plans(plans)
        fp_b = fp_m[:, :, inverse]                 # one gather for all floats
        return (
            order_m[:, inverse],
            fp_b[0],
            fp_b[1],
            fp_b[2],
            empty_v[inverse],
            planned_v[inverse],
        )

    def _plan_batch(self, embeddings: np.ndarray, budgets: np.ndarray):
        """Shared planning prologue of both batched paths.

        Uniform-budget batches (the common serving case) take the dense
        fast path: one nearest-centroid index lookup, one gather from the
        PlanService's cached :class:`~repro.serving.plans.BatchTables` —
        no ``np.unique``, no per-group Python. Heterogeneous budgets fall
        back to the generic group merge in :meth:`_batch_plan`.

        Returns ``(cluster_ids (B,), schedule (T, B), weights (T, B),
        residual (T, B), wave_costs (T, B), empty (B,), planned (B,))``.
        """
        if budgets[0] == budgets[-1] and (budgets == budgets[0]).all():
            idx = self.estimator.lookup_batch_indices(embeddings)
            cluster_ids = self.estimator.cluster_order[idx]
            tabs = self.plans.batch_tables(float(budgets[0]), idx=idx)
            fp = tabs.floats[:, :, idx]
            return (
                cluster_ids, tabs.order[:, idx], fp[0], fp[1], fp[2],
                tabs.empty[idx], tabs.planned[idx],
            )
        cluster_ids = self.estimator.lookup_batch(embeddings)
        return (cluster_ids,) + self._batch_plan(cluster_ids, budgets)

    def _empty_result(self, budgets: np.ndarray) -> RouteResult:
        return RouteResult(
            predictions=np.zeros(0, np.int64),
            costs=np.zeros(0, np.float64),
            planned_costs=np.zeros(0, np.float64),
            clusters=np.zeros(0, np.int64),
            budgets=np.asarray(budgets),
            schedule=np.full((0, 1), -1, np.int64),
            responses=np.full((0, 1), -1, np.int64),
            invoked=np.zeros((0, 1), bool),
            arm_query_counts=np.zeros(len(self.engine.arms), np.int64),
            waves=0,
        )

    # ------------------------------------------------------------------
    # Belief backend: float64 scatter tables or the Pallas kernel
    # ------------------------------------------------------------------
    def _kernel_beliefs(
        self, responses: np.ndarray, weights: np.ndarray, empty: np.ndarray
    ) -> np.ndarray:
        bel, _ = ops.belief_aggregate(
            jnp.asarray(responses, jnp.int32),
            jnp.asarray(weights, jnp.float32),
            jnp.asarray(empty, jnp.float32),
            self.num_classes,
        )
        return np.asarray(bel, np.float64)

    # ------------------------------------------------------------------
    def route_batch(
        self,
        queries: Any,                    # arm-payloads, len B (array or list)
        embeddings: np.ndarray,          # (B, d)
        budget: Any,                     # scalar or (B,) per-query budgets
        stop_margin: float = STOP_MARGIN,
        rng: Optional[np.random.Generator] = None,
    ) -> RouteResult:
        """Route a batch end to end: cluster lookup, plan-cache gather, one
        on-device wave loop, host-side finalization.

        With ``jit_waves=True`` (default) every scheduled (query, wave)
        response is fetched in a single heterogeneous engine call and the
        whole adaptive loop runs as one jitted ``lax.scan``; with
        ``jit_waves=False`` this delegates to the compacting
        :meth:`route_batch_reference`. Both return identical
        predictions/costs/arms-used for deterministic arm pools.

        Args:
          queries: per-arm payloads (tokens, (cluster, label) pairs, ...).
          embeddings: (B, d) query embeddings for cluster lookup.
          budget: scalar or (B,) per-query USD budgets.
          stop_margin: Prop. 4 slack; keep the default for paper semantics.
          rng: optional generator for belief-tie breaking (None = argmax).
        """
        if not self.jit_waves:
            return self.route_batch_reference(
                queries, embeddings, budget, stop_margin=stop_margin, rng=rng
            )
        B = len(queries)
        K = self.num_classes
        budgets = np.broadcast_to(np.asarray(budget, np.float64), (B,))
        if B == 0:
            return self._empty_result(budgets)
        self.plans.refresh()
        cluster_ids, sched_T, w_T, res_T, wc_T, empty, planned = self._plan_batch(
            embeddings, budgets
        )
        T = sched_T.shape[0]
        L = len(self.engine.arms)
        payloads = self.engine.prepare_payloads(queries)

        # Speculative response gather: one heterogeneous-arm engine call for
        # every scheduled (query, wave) cell. The device program then
        # decides which cells the adaptive loop actually uses.
        if self.engine.pooled:
            # all-cells fast path: responses for unscheduled (-1) cells are
            # drawn on arm 0 and never read — the stop rule fires on the
            # schedule itself before any such prefix is gathered — which
            # avoids the nonzero/compact/scatter round-trip entirely.
            resp_T = self.engine.invoke_grid(sched_T, payloads)
        else:
            mask = sched_T >= 0
            _, rows_b = np.nonzero(mask)
            resp_T = np.full((T, B), -1, np.int64)
            if rows_b.size:
                resp_T[mask] = self.engine.invoke_rows(
                    sched_T[mask], payloads, rows_b
                )

        # Pad to compile buckets so serving traffic with drifting batch
        # sizes / plan depths reuses a handful of compiled programs; the
        # whole pipeline is wave-major, so padding never transposes.
        Bp, Tp = _bucket(B, base=8), _bucket(T, base=4)
        sched_p = np.full((Tp, Bp), -1, np.int32)
        sched_p[:T, :B] = sched_T
        resp_p = np.full((Tp, Bp), -1, np.int32)
        resp_p[:T, :B] = resp_T
        w_p = np.zeros((Tp, Bp), np.float64)
        w_p[:T, :B] = w_T
        res_p = np.full((Tp, Bp), -np.inf, np.float64)
        res_p[:T, :B] = res_T
        empty_p = np.zeros(Bp, np.float64)
        empty_p[:B] = empty

        with enable_x64():
            s_d, pred_d, beliefs_d = _wave_scan(
                sched_p, resp_p, w_p, res_p, empty_p, float(stop_margin),
                num_classes=K, use_kernel=self.use_kernel,
            )
            stop_wave = np.asarray(s_d)[:B]      # waves invoked per query
            if rng is None:
                predictions = np.asarray(pred_d, np.int64)[:B]
            else:
                beliefs = np.asarray(beliefs_d, np.float64)[:B]

        invoked_T = np.arange(T)[:, None] < stop_wave[None, :]
        costs = np.where(invoked_T, wc_T, 0.0).sum(axis=0)
        responses_T = np.where(invoked_T, resp_T, -1)
        arm_query_counts = np.bincount(sched_T[invoked_T], minlength=L)
        if rng is not None:
            predictions, _ = tie_break_argmax(beliefs, rng)
        return RouteResult(
            predictions=predictions,
            costs=costs,
            planned_costs=planned,
            clusters=cluster_ids,
            budgets=np.asarray(budgets),
            schedule=sched_T.T,
            responses=responses_T.T,
            invoked=invoked_T.T,
            arm_query_counts=arm_query_counts,
            waves=int(invoked_T.any(axis=1).sum()),
        )

    # ------------------------------------------------------------------
    def route_batch_reference(
        self,
        queries: Any,
        embeddings: np.ndarray,
        budget: Any,
        stop_margin: float = STOP_MARGIN,
        rng: Optional[np.random.Generator] = None,
    ) -> RouteResult:
        """Compacting host-side wavefront (the PR 1 engine) — the semantics
        reference the jitted :meth:`route_batch` is equivalence-tested
        against, and the production path for pools where speculative
        invocation costs real money.

        Stopped queries are dropped from the in-flight index set each wave,
        so wave t only touches (and only *invokes*) the queries still in
        flight; belief state is a float64 (B, K) scatter table (or the
        Pallas kernel under ``use_kernel=True``).
        """
        B = len(queries)
        K = self.num_classes
        budgets = np.broadcast_to(np.asarray(budget, np.float64), (B,))
        if B == 0:
            return self._empty_result(budgets)
        self.plans.refresh()
        # wave-major plan matrices: contiguous (B,) row per wave in the loop
        cluster_ids, sched_T, w_T, res_T, wc_T, empty, planned = self._plan_batch(
            embeddings, budgets
        )
        T = sched_T.shape[0]
        L = len(self.engine.arms)
        payloads = self.engine.prepare_payloads(queries)
        weights = w_T.T                          # (B, T) view for the kernel
        resp_T = np.full((T, B), -1, np.int64)

        vote = np.zeros((B, K), np.float64)      # scatter-add log-weight table
        voted = np.zeros((B, K), bool)           # any vote -> real belief
        costs = np.zeros(B, np.float64)
        arm_query_counts = np.zeros(L, np.int64)
        cur = np.arange(B)                       # queries still in flight
        waves = 0

        for t in range(T):
            # Prop. 4 early-stop on the in-flight set, one mask per wave
            if self.use_kernel:
                # per-row independent contraction: feeding only in-flight rows
                # gives identical beliefs at a fraction of the kernel work
                bel = self._kernel_beliefs(
                    np.ascontiguousarray(resp_T.T[cur]), weights[cur], empty[cur]
                )
            else:
                bel = np.where(voted[cur], vote[cur], empty[cur][:, None])
            if K >= 2:
                part = np.partition(bel, K - 2, axis=1)
                h1, h2 = part[:, K - 1], part[:, K - 2]
            else:
                h1, h2 = bel[:, 0], np.full(cur.size, -np.inf)
            sched_t = sched_T[t]
            keep = (sched_t[cur] >= 0) & (res_T[t][cur] + h2 > h1 - stop_margin)
            cur = cur[keep]
            if cur.size == 0:
                break
            waves += 1
            arms_t = sched_t[cur]
            votes = self.engine.invoke_rows(arms_t, payloads, cur)
            arm_query_counts += np.bincount(arms_t, minlength=L)
            vote[cur, votes] += w_T[t][cur]
            voted[cur, votes] = True
            costs[cur] += wc_T[t][cur]
            resp_T[t][cur] = votes

        responses = np.ascontiguousarray(resp_T.T)
        if self.use_kernel:
            beliefs = self._kernel_beliefs(responses, weights, empty)
        else:
            beliefs = np.where(voted, vote, empty[:, None])
        predictions, _ = tie_break_argmax(beliefs, rng)
        invoked = responses >= 0
        return RouteResult(
            predictions=predictions,
            costs=costs,
            planned_costs=planned,
            clusters=cluster_ids,
            budgets=np.asarray(budgets),
            schedule=sched_T.T,
            responses=responses,
            invoked=invoked,
            arm_query_counts=arm_query_counts,
            waves=waves,
        )

    # ------------------------------------------------------------------
    def route_batch_sequential(
        self,
        queries: Any,
        embeddings: np.ndarray,
        budget: Any,
        rng: Optional[np.random.Generator] = None,
    ) -> RouteResult:
        """Sequential oracle: one ``adaptive_invoke`` per query.

        The per-query semantics source both batched paths are
        equivalence-tested against (``tests/test_router_batched.py``) and
        the baseline of the serving throughput benchmark. Shares the plan
        service's selection cache, so all paths route the same selected
        sets.

        Exact output equality with :meth:`route_batch` holds for
        *deterministic* arms (responses a pure function of (arm, query),
        e.g. the test TabularArm or LMArm). Stochastic ``OracleArm`` pools
        consume different rng streams on the batched paths (pooled
        ``invoke_rows`` draws vs per-arm draws here), so per-seed
        realizations differ even though the distributions match.
        """
        B = len(queries)
        K = self.num_classes
        budgets = np.broadcast_to(np.asarray(budget, np.float64), (B,))
        self.plans.refresh()
        cluster_ids = self.estimator.lookup_batch(embeddings)
        L = len(self.engine.arms)

        predictions = np.zeros(B, np.int64)
        costs = np.zeros(B, np.float64)
        planned = np.zeros(B, np.float64)
        arms_used: List[List[int]] = []
        resp_rows: List[np.ndarray] = []
        arm_query_counts = np.zeros(L, np.int64)
        for j in range(B):
            p = self.estimator.clusters[int(cluster_ids[j])].p_hat
            sel = self.selector.select(p, K, float(budgets[j]))

            def invoke_one(arm: int) -> int:
                mask = np.zeros(B, bool)
                mask[j] = True
                return int(self.engine.invoke_arm(int(arm), queries, mask)[j])

            inv = adaptive_invoke(
                list(sel.chosen), p, K, invoke_one, rng=rng, costs=self.engine.costs
            )
            predictions[j] = inv.prediction
            costs[j] = inv.cost
            planned[j] = inv.planned_cost
            arms_used.append([int(a) for a in inv.used])
            resp_rows.append(np.asarray(inv.responses, np.int64))
            arm_query_counts[inv.used] += 1
        T = max(1, max((len(a) for a in arms_used), default=1))
        schedule = np.full((B, T), -1, np.int64)
        responses = np.full((B, T), -1, np.int64)
        invoked = np.zeros((B, T), bool)
        for j, used in enumerate(arms_used):
            schedule[j, : len(used)] = used
            responses[j, : len(used)] = resp_rows[j]
            invoked[j, : len(used)] = True
        res = RouteResult(
            predictions=predictions,
            costs=costs,
            planned_costs=planned,
            clusters=cluster_ids,
            budgets=np.asarray(budgets),
            schedule=schedule,
            responses=responses,
            invoked=invoked,
            arm_query_counts=arm_query_counts,
            waves=T,
        )
        res._arms_used = arms_used
        return res
