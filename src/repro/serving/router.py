"""ThriftLLM router: per-query-class selection + batched wavefront invocation.

Serving pipeline per batch (Figure 1 of the paper, batched for TPU):
  1. embed queries, map to historical clusters -> p-hat vector per query
  2. group queries by (cluster, budget); SurGreedyLLM selection per group is
     memoized by the :class:`~repro.serving.plans.PlanService` — selection
     depends only on (cluster, budget, pool fingerprint) — and the derived
     wave plan (arm order, log-weights, Prop. 4 residuals) is what the hot
     path consumes. Hot pairs can be precomputed ahead of traffic; plan
     keys carry estimator *versions*, so a cost change or a drifting
     online-feedback fold (``serving/feedback.py``) invalidates exactly
     the plans it obsoletes — lazily, with no scan on the hot path.
  3. *wavefront* adaptive invocation across the WHOLE batch. Two data-plane
     implementations with identical semantics for deterministic arms:

     * :meth:`route_batch` (default, ``jit_waves=True``) — the **jitted
       wave loop**. The per-group plans are padded to one fixed
       (B, max_waves) layout (bucketed to limit recompilation), every
       scheduled (query, wave) response is gathered up front in a single
       heterogeneous-arm engine call, and the entire wave loop — Prop. 4
       early-stop mask, belief accumulation, in-flight carry — runs as one
       jitted on-device program in float64. Because responses are
       pre-gathered, the sequential recurrence collapses into a parallel
       prefix scan (see :func:`_wave_scan`); Python never touches the
       loop and there is one dispatch per batch.
     * :meth:`route_batch_reference` — the compacting host-side wavefront
       (PR 1). Stopped queries are dropped from the index set each wave and
       each wave issues one engine call for the rows still in flight, so
       arms are only ever invoked for queries that need them. This is the
       fallback for pools where speculative invocation costs real money
       (live LLM APIs), and the semantics pin for equivalence tests.

     The trade: the jitted loop invokes every *scheduled* (query, wave)
     cell — including waves the stop rule later masks out — so realized
     **reported** costs still count only invoked waves, but the engine does
     speculative work. For oracle/tabular/self-hosted pools that is pure
     throughput; for metered upstream APIs use ``jit_waves=False``.
  4. belief aggregation: float64 scatter tables by default, or the
     ``belief_aggregate`` Pallas kernel (``use_kernel=True``), dispatched
     from *inside* the jitted scan — identical masking semantics, float32
     accumulation on TPU. Caveat: the kernel backend evaluates the Prop. 4
     stop rule on float32 beliefs, so a query whose margin lands within
     float32 resolution (~1e-7) of the STOP_MARGIN boundary may take one
     wave more or fewer than the float64 path; everywhere else the two
     backends are identical.
"""
from __future__ import annotations

import contextlib
import functools
import warnings
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.belief import tie_break_argmax
from repro.core.estimation import SuccessProbEstimator
from repro.core.selection import STOP_MARGIN, ThriftLLM, adaptive_invoke
from repro.distributed.fault import (
    FAULT_DEGRADE,
    FAULT_ERROR,
    FAULT_TIMEOUT,
    failover_gather,
    observed_faults,
)
from repro.kernels import ops

from .compile_cache import configure_compile_cache
from .engine import PoolEngine
from .plans import GroupPlan, PlanService, stack_plans

# retained name for PR 1 call sites / pickles
_GroupPlan = GroupPlan


class RouteResult:
    """Batched routing output.

    One instance summarizes a whole ``route_batch`` call. Fields:

    Attributes:
      predictions: (B,) aggregated class id per query (Eq. 4 argmax with
        shared tie-breaking).
      costs: (B,) realized USD per query — only waves actually invoked.
      planned_costs: (B,) USD of each query's full selected set (the spend
        ceiling if no early stop fires; ``costs <= planned_costs`` always).
      clusters: (B,) historical cluster each query mapped to.
      budgets: (B,) per-query budget applied.
      schedule: (B, T) arm id scheduled at wave t, ``-1`` = no arm (plan
        shorter than T).
      responses: (B, T) class id returned at wave t, ``-1`` = wave not run.
      invoked: (B, T) bool — wave t really ran for this query (the Prop. 4
        stop rule had not fired and an arm was scheduled).
      arm_query_counts: (L,) number of queries each pool arm actually
        served — the scheduler's latency accounting input.
      waves: number of waves the batch executed before every query stopped.

    When the engine carries an active fault policy, ``schedule`` /
    ``responses`` / ``invoked`` / ``costs`` describe the *effective* route
    (what was actually served after in-wave failover re-routed failed
    slots), so downstream feedback/latency/ledger accounting needs no fault
    awareness, and three keyword-only fields carry the failure evidence
    (all ``None`` on fault-free routes — the common case allocates nothing):

      fault_schedule: (B, T) the original plan-order schedule.
      fault_codes: (B, T) int8 observed fault per original plan cell
        (``FAULT_TIMEOUT``/``FAULT_ERROR`` at failures the wavefront
        actually attempted, ``FAULT_DEGRADE`` at silently-degraded cells it
        actually served, 0 everywhere else — injected faults past the stop
        wave were never observed and do not count as evidence).
      arm_fault_counts: (L,) attempted timeout/error failures per arm.

    ``arms_used`` is derived lazily from the (schedule, invoked) matrices so
    the hot path never builds Python lists.
    """

    def __init__(
        self,
        predictions: np.ndarray,         # (B,)
        costs: np.ndarray,               # (B,) realized USD
        planned_costs: np.ndarray,       # (B,) full-ensemble USD
        clusters: np.ndarray,            # (B,)
        budgets: np.ndarray,             # (B,) per-query budget applied
        schedule: np.ndarray,            # (B, T) arm id per wave, -1 = none
        responses: np.ndarray,           # (B, T) class id per wave, -1 = not run
        invoked: np.ndarray,             # (B, T) bool, wave actually ran
        arm_query_counts: np.ndarray,    # (L,) queries served per arm
        waves: int,
        *,
        fault_schedule: Optional[np.ndarray] = None,   # (B, T) original plan
        fault_codes: Optional[np.ndarray] = None,      # (B, T) observed faults
        arm_fault_counts: Optional[np.ndarray] = None,  # (L,) failures per arm
    ):
        self.predictions = predictions
        self.costs = costs
        self.planned_costs = planned_costs
        self.clusters = clusters
        self.budgets = budgets
        self.schedule = schedule
        self.responses = responses
        self.invoked = invoked
        self.arm_query_counts = arm_query_counts
        self.waves = waves
        self.fault_schedule = fault_schedule
        self.fault_codes = fault_codes
        self.arm_fault_counts = arm_fault_counts
        self._arms_used: Optional[List[List[int]]] = None

    @property
    def arms_used(self) -> List[List[int]]:
        """Per query, arms actually invoked in invocation order."""
        if self._arms_used is None:
            self._arms_used = [
                self.schedule[b, self.invoked[b]].tolist()
                for b in range(self.schedule.shape[0])
            ]
        return self._arms_used

    @property
    def stop_waves(self) -> np.ndarray:
        """(B,) number of waves each query invoked before its Prop. 4 stop
        fired (== the wave index at which its result became final)."""
        return self.invoked.sum(axis=1)


# ---------------------------------------------------------------------------
# The on-device wave loop
# ---------------------------------------------------------------------------


def _bucket(n: int, *, base: int) -> int:
    """Round ``n`` up so the jitted loop compiles once per bucket instead
    of once per exact (B, T): multiples of ``base`` up to 4x base (tight —
    padded waves/rows cost real device work), powers of two beyond. One
    policy repo-wide: delegates to the planner's ``bucket_size``."""
    from repro.core.mc import bucket_size

    return bucket_size(n, base)


def _wave_scan_core(
    schedule: jnp.ndarray,    # (T, B) int32 arm ids, -1 = none (wave-major)
    responses: jnp.ndarray,   # (T, B) int32 precomputed responses, -1 = none
    weights: jnp.ndarray,     # (T, B) f64 log belief weight per wave
    residual: jnp.ndarray,    # (T, B) f64 Prop. 4 log F residuals
    src: jnp.ndarray,         # (T, B) i32 failover gather: original wave
                              #   index serving slot t (identity = no fault)
    valid: jnp.ndarray,       # (T, B) bool slot t has an available arm
    empty: jnp.ndarray,       # (B,)  f64 empty-class log belief
    stop_margin,
    *,
    num_classes: int,
    use_kernel: bool,
):
    """Entire wavefront loop as one fused on-device program.

    Because the per-wave responses are gathered up front, each query's
    trajectory is a pure *prefix* of its schedule: if it is still in flight
    at wave t it has invoked exactly waves 0..t-1. The sequential adaptive
    loop therefore collapses into a prefix scan: cumulative (T+1, B, K)
    belief tables (index t = "beliefs before wave t"), after which every
    wave's Prop. 4 stop decision is evaluated at once and each query's stop
    wave is the first failing prefix. The prefix accumulation and the
    K-class top-2 are unrolled over the static (T, K) axes into pure
    elementwise chains — XLA fuses them into a handful of kernels, the
    adds happen in exactly the host loop's sequential order (bit-identical
    float64 beliefs, no reassociation), and everything is wave-major so
    each step touches contiguous (B,)/(B, K) slabs. One compile per
    (T, B, K) bucket; the caller pads to buckets.

    Runs in float64 under ``jax.experimental.enable_x64``. Under
    ``use_kernel`` the prefix histories are instead aggregated by a single
    prefix-expanded ``belief_aggregate`` Pallas kernel call, so the stop
    rule sees exactly the float32 beliefs the kernel-backed reference loop
    sees (the documented ~1e-7 stop-boundary caveat).

    **In-wave failover** (``src``/``valid``): slot t of each query's wave
    program serves the plan's t-th *available* arm. The gather is computed
    host-side from the fault grid (see ``repro.distributed.fault``) and fed
    as plain data — not statics — so flipping injected faults between
    batches reuses the compiled program, and on fault-free traffic the
    identity gather is a bit-exact no-op (invalid cells read the same pad
    values — schedule -1, weight 0, residual -inf — the tables already hold
    there). The stop rule, belief prefixes and residuals all operate on the
    post-gather *effective* arrays, so a failed arm's slot re-routes to the
    plan's next-best affordable arm and the belief update is masked to
    responses actually obtained. The gathered residual is the original
    plan's suffix value at the source position — an upper bound on the
    post-failover remaining evidence, so Prop. 4 never stops earlier than a
    fault-free run would.

    Returns (stop_wave (B,) int — number of waves invoked per query,
    predictions (B,) int via first-max argmax, log-beliefs (B, K) at the
    stop wave).
    """
    T, B = schedule.shape
    K = num_classes
    f_dtype = weights.dtype
    class_ids = jnp.arange(K, dtype=responses.dtype)

    pad_i = jnp.asarray(-1, schedule.dtype)
    schedule = jnp.where(valid, jnp.take_along_axis(schedule, src, axis=0), pad_i)
    responses = jnp.where(valid, jnp.take_along_axis(responses, src, axis=0), pad_i)
    weights = jnp.where(valid, jnp.take_along_axis(weights, src, axis=0), 0.0)
    residual = jnp.where(
        valid, jnp.take_along_axis(residual, src, axis=0), -jnp.inf
    )

    if use_kernel:
        # Prefix-expanded kernel dispatch: row (b, t) holds query b's
        # response history masked to waves < t; one pallas_call aggregates
        # every prefix of every query.
        resp_bt = responses.T                               # (B, T)
        hist = jnp.where(
            jnp.arange(T + 1)[None, :, None] > jnp.arange(T)[None, None, :],
            resp_bt[:, None, :],
            -1,
        )                                                   # (B, T+1, T)
        w32 = weights.T.astype(jnp.float32)
        bel32, _ = ops.belief_aggregate(
            hist.reshape(B * (T + 1), T),
            jnp.broadcast_to(w32[:, None, :], (B, T + 1, T)).reshape(-1, T),
            jnp.broadcast_to(
                empty.astype(jnp.float32)[:, None], (B, T + 1)
            ).reshape(-1),
            K,
            tile=512,
        )
        # f32 values compared in f64, matching the reference kernel path
        bel = bel32.reshape(B, T + 1, K).astype(f_dtype).transpose(1, 0, 2)
    else:
        onehot = responses[:, :, None] == class_ids[None, None, :]  # (T,B,K)
        contrib = jnp.where(onehot, weights[:, :, None], 0.0)
        votes = [jnp.zeros((B, K), f_dtype)]
        cnts = [jnp.zeros((B, K), bool)]
        for t in range(T):
            votes.append(votes[-1] + contrib[t])
            cnts.append(cnts[-1] | onehot[t])
        cumvote = jnp.stack(votes)                          # (T+1, B, K)
        cumcnt = jnp.stack(cnts)
        bel = jnp.where(cumcnt, cumvote, empty[None, :, None])

    # online top-2 over the static K axis; ties keep h2 == h1
    h1 = jnp.full((T + 1, B), -jnp.inf, f_dtype)
    h2 = h1
    for k in range(K):
        v = bel[:, :, k]
        gt = v > h1
        h2 = jnp.where(gt, h1, jnp.maximum(h2, v))
        h1 = jnp.where(gt, v, h1)
    stop = ~((schedule >= 0) & (residual + h2[:T] > h1[:T] - stop_margin))
    s = jnp.where(stop.any(axis=0), jnp.argmax(stop, axis=0), T)  # first stop
    beliefs = jnp.take_along_axis(bel, s[None, :, None], axis=0)[0]
    # first-max argmax, identical to the host path's deterministic tie-break
    preds = jnp.argmax(beliefs, axis=-1)
    return s, preds, beliefs


@contextlib.contextmanager
def _quiet_donation():
    """Donation is declarative — XLA aliases the donated inputs it can use
    and warns once at compile time about the rest; the caller-side contract
    ("the staged tables are dead after dispatch") is what the wrappers and
    the `donation-contract` lint rule enforce, so the partial-use warning
    is expected noise at the dispatch seams."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield

# The serving default donates the staged response/weight/residual wave
# tables: `_dispatch_jit` builds them as throwaway locals (host numpy —
# the jit transfers a fresh device copy per call and donates that copy),
# re-reads nothing after the call, and `prewarm_compile` passes dummies.
# `_wave_scan_nodonate` is the bit-identical twin for callers that keep
# the staged device buffers alive (`ThriftRouter(donate_buffers=False)`).
# Each wrapper owns one compile per (T, B, K) bucket.
_wave_scan = functools.partial(
    jax.jit, static_argnames=("num_classes", "use_kernel"),
    donate_argnums=(1, 2, 3),
)(_wave_scan_core)

_wave_scan_nodonate = functools.partial(
    jax.jit, static_argnames=("num_classes", "use_kernel"),
)(_wave_scan_core)


class PendingRoute:
    """One in-flight batched route, created by :meth:`ThriftRouter.begin_route`.

    Three kinds:

    * ``"jit"`` — the speculative jitted wave loop. Planning, the
      speculative response gather and the device dispatch already happened
      in ``begin_route``; the device program may still be running when this
      handle is returned (JAX dispatch is asynchronous), so a front-end can
      overlap the next group's host-side planning/gather with this one's
      device compute. ``result()`` blocks on the device values and
      finalizes.
    * ``"reference"`` — the compacting host wavefront, exposed wave by
      wave: each ``step()`` call evaluates the Prop. 4 stop rule, retires
      the queries whose stop fired (returning their rows — and, in
      deterministic mode, their final predictions, which can never change
      once a query stops voting), then invokes one wave of arms for the
      queries still in flight. ``result()`` steps to exhaustion and
      finalizes; outputs are bit-identical to the PR 1 loop.
    * ``"empty"`` — a zero-query batch; ``result()`` is immediate.

    The handle is single-use: ``result()`` caches and re-returns.
    """

    def __init__(self, router: "ThriftRouter", kind: str, result=None, **state):
        self.router = router
        self.kind = kind
        self.spec_cost = state.pop("spec_cost", 0.0)
        # estimator plan-version the group's plans were gathered at —
        # observability for the online-feedback loop (a served group can be
        # attributed to the estimate generation that planned it)
        self.plan_version = state.pop("plan_version", 0)
        self._result: Optional[RouteResult] = result
        if result is not None:
            return
        self.budgets = state.pop("budgets")
        self.cluster_ids = state.pop("cluster_ids")
        self.sched_T = state.pop("sched_T")
        self.w_T = state.pop("w_T")
        self.res_T = state.pop("res_T")
        self.wc_T = state.pop("wc_T")
        self.empty = state.pop("empty")
        self.planned = state.pop("planned")
        self.payloads = state.pop("payloads")
        self.stop_margin = state.pop("stop_margin")
        self.rng = state.pop("rng")
        # batch-row offset of this group inside a logically fused batch —
        # keeps per-worker fault draws identical to the fused dispatch's
        self.fault_row_offset = int(state.pop("fault_row_offset", 0))
        assert not state, f"unknown PendingRoute state {sorted(state)}"
        self.B = int(self.budgets.shape[0])
        self.T = int(self.sched_T.shape[0])
        self.L = len(router.engine.arms)
        if kind == "reference":
            self._prepare_reference_faults()
            self._init_reference()

    # ------------------------------------------------------------------
    # jit kind: speculative gather + async device dispatch
    # ------------------------------------------------------------------
    def _dispatch_jit(self):
        router, T, B = self.router, self.T, self.B
        sched_T, payloads = self.sched_T, self.payloads
        engine = router.engine
        codes, failed = engine.fault_grid(
            sched_T, row_offset=self.fault_row_offset
        )
        self._orig_sched_T = sched_T
        self._codes, self._failed = codes, failed
        # Speculative response gather: one heterogeneous-arm engine call for
        # every scheduled (query, wave) cell. The device program then
        # decides which cells the adaptive loop actually uses.
        if engine.pooled:
            # all-cells fast path: responses for unscheduled (-1) cells are
            # drawn on arm 0 and never read — the stop rule fires on the
            # schedule itself before any such prefix is gathered — which
            # avoids the nonzero/compact/scatter round-trip entirely.
            resp_T = engine.invoke_grid(sched_T, payloads)
        else:
            mask = sched_T >= 0
            if failed is not None:
                mask &= ~failed          # a failed arm yields no response
            _, rows_b = np.nonzero(mask)
            resp_T = np.full((T, B), -1, np.int64)
            if rows_b.size:
                resp_T[mask] = engine.invoke_rows(sched_T[mask], payloads, rows_b)
        if codes is not None:
            resp_T = np.where(failed, -1, resp_T)
            degr = codes == FAULT_DEGRADE
            if degr.any():
                # silent degradation: the arm answers (and bills), but with a
                # hash-drawn class — response-independent, so the reference
                # plane corrupts the same cells to the same classes
                resp_T = np.where(
                    degr,
                    engine.fault_policy.corrupt_grid(
                        sched_T, row_offset=self.fault_row_offset
                    ),
                    resp_T,
                )
        self.resp_T = resp_T

        # In-wave failover gather: identity on fault-free traffic. Data
        # inputs, never statics — flipping injected faults between batches
        # rides the same compiled wave program (CompileSentinel-pinned).
        if failed is not None and router.failover:
            src, valid, self._rank, self._navail = failover_gather(
                sched_T, failed
            )
        else:
            src = np.broadcast_to(np.arange(T, dtype=np.int32)[:, None], (T, B))
            valid = sched_T >= 0
            self._rank = self._navail = None
        self._src, self._valid = src, valid

        # Pad to compile buckets so serving traffic with drifting batch
        # sizes / plan depths reuses a handful of compiled programs; the
        # whole pipeline is wave-major, so padding never transposes.
        Bp, Tp = _bucket(B, base=8), _bucket(T, base=4)
        sched_p = np.full((Tp, Bp), -1, np.int32)
        sched_p[:T, :B] = sched_T
        resp_p = np.full((Tp, Bp), -1, np.int32)
        resp_p[:T, :B] = resp_T
        w_p = np.zeros((Tp, Bp), np.float64)
        w_p[:T, :B] = self.w_T
        res_p = np.full((Tp, Bp), -np.inf, np.float64)
        res_p[:T, :B] = self.res_T
        src_p = np.broadcast_to(
            np.arange(Tp, dtype=np.int32)[:, None], (Tp, Bp)
        ).copy()
        src_p[:T, :B] = src
        valid_p = np.zeros((Tp, Bp), bool)
        valid_p[:T, :B] = valid
        empty_p = np.zeros(Bp, np.float64)
        empty_p[:B] = self.empty

        # Device pinning rides jax.default_device, not an explicit
        # jax.device_put: committing the seven padded tables per dispatch
        # measures ~5x the whole dispatch cost on the CPU backend, while
        # the context manager just steers where jit places the uncommitted
        # numpy args (~free) and still caches one executable per (bucket,
        # device). Placement stays inside the x64 context — materializing
        # f64 arrays outside it would silently downcast to f32 and change
        # the wave program's numerics. No host references to the staged
        # buffers are retained (args are locals), so the carry is
        # donation-safe — XLA may alias the input buffers freely.
        ctx = (
            jax.default_device(router.device)
            if router.device is not None else contextlib.nullcontext()
        )
        scan_fn = _wave_scan if router.donate_buffers else _wave_scan_nodonate
        with enable_x64(), ctx, _quiet_donation():
            self._dev = scan_fn(
                sched_p, resp_p, w_p, res_p, src_p, valid_p, empty_p,
                self.stop_margin,
                num_classes=router.num_classes, use_kernel=router.use_kernel,
            )

    def ready(self) -> bool:
        """Non-blocking: has the dispatched device program finished? Host-
        driven kinds (reference/empty) are always ready."""
        if self.kind != "jit" or self._result is not None:
            return True
        probe = getattr(self._dev[0], "is_ready", None)
        return bool(probe()) if probe is not None else True

    def _fault_kwargs(self, stop_wave: np.ndarray) -> dict:
        """Fault-evidence fields for RouteResult; {} on fault-free routes."""
        codes = getattr(self, "_codes", None)
        if codes is None:
            return {}
        obs = observed_faults(
            codes, self._orig_sched_T, stop_wave, self._rank, self._navail
        )
        hit = (obs == FAULT_TIMEOUT) | (obs == FAULT_ERROR)
        return dict(
            fault_schedule=self._orig_sched_T.T,
            fault_codes=obs.T,
            arm_fault_counts=np.bincount(
                self._orig_sched_T[hit], minlength=self.L
            ),
        )

    def _finalize_jit(self) -> RouteResult:
        s_d, pred_d, beliefs_d = self._dev
        B, T, L = self.B, self.T, self.L
        stop_wave = np.asarray(s_d)[:B]          # waves invoked per query
        if self.rng is None:
            predictions = np.asarray(pred_d, np.int64)[:B]
        else:
            beliefs = np.asarray(beliefs_d, np.float64)[:B]
            predictions, _ = tie_break_argmax(beliefs, self.rng)
        if self._failed is None:
            # fault-free fast path: unchanged pre-failover accounting
            sched_T = self.sched_T
            invoked_T = np.arange(T)[:, None] < stop_wave[None, :]
            costs = np.where(invoked_T, self.wc_T, 0.0).sum(axis=0)
            responses_T = np.where(invoked_T, self.resp_T, -1)
        else:
            # report the *effective* route — post-failover schedule, the
            # responses actually obtained, spend charged for the arms
            # actually invoked — so downstream accounting stays fault-blind
            src, valid = self._src, self._valid
            bb = np.broadcast_to(np.arange(B)[None, :], (T, B))
            sched_T = np.where(valid, self.sched_T[src, bb], -1)
            resp_eff = np.where(valid, self.resp_T[src, bb], -1)
            wc_eff = np.where(valid, self.wc_T[src, bb], 0.0)
            invoked_T = (
                np.arange(T)[:, None] < stop_wave[None, :]
            ) & (sched_T >= 0)
            if not self.router.failover:
                # frozen plans: a failed slot's wave still elapses, but the
                # arm never answered — not served, not charged
                invoked_T &= ~self._failed
            costs = np.where(invoked_T, wc_eff, 0.0).sum(axis=0)
            responses_T = np.where(invoked_T, resp_eff, -1)
        arm_query_counts = np.bincount(sched_T[invoked_T], minlength=L)
        return RouteResult(
            predictions=predictions,
            costs=costs,
            planned_costs=self.planned,
            clusters=self.cluster_ids,
            budgets=np.asarray(self.budgets),
            schedule=sched_T.T,
            responses=responses_T.T,
            invoked=invoked_T.T,
            arm_query_counts=arm_query_counts,
            waves=int(invoked_T.any(axis=1).sum()),
            **self._fault_kwargs(stop_wave),
        )

    # ------------------------------------------------------------------
    # reference kind: compacting wavefront, one step() per wave
    # ------------------------------------------------------------------
    def _prepare_reference_faults(self):
        """Mirror the jit plane's fault handling on the host wavefront.

        Same single host-side fault grid, same failover gather — but
        materialized into the plan tables up front (the compacting loop
        then runs unchanged over the *effective* plan), instead of gathered
        inside the device program. Computing the grid once on the original
        schedule is what keeps the two planes bit-identical under faults.
        """
        engine = self.router.engine
        codes, failed = engine.fault_grid(
            self.sched_T, row_offset=self.fault_row_offset
        )
        self._orig_sched_T = self.sched_T
        self._codes, self._failed = codes, failed
        self._rank = self._navail = None
        self._degrade_T = None
        if codes is None:
            return
        T, B = self.sched_T.shape
        degr = codes == FAULT_DEGRADE
        corrupt = None
        if degr.any():
            corrupt = np.where(
                degr,
                engine.fault_policy.corrupt_grid(
                    self.sched_T, row_offset=self.fault_row_offset
                ),
                -1,
            )
        if self.router.failover:
            src, valid, self._rank, self._navail = failover_gather(
                self.sched_T, failed
            )
            bb = np.broadcast_to(np.arange(B)[None, :], (T, B))
            self.sched_T = np.where(valid, self.sched_T[src, bb], -1)
            self.w_T = np.where(valid, self.w_T[src, bb], 0.0)
            self.res_T = np.where(valid, self.res_T[src, bb], -np.inf)
            self.wc_T = np.where(valid, self.wc_T[src, bb], 0.0)
            if corrupt is not None:
                self._degrade_T = np.where(valid, corrupt[src, bb], -1)
        else:
            self._degrade_T = corrupt

    def _init_reference(self):
        B, K = self.B, self.router.num_classes
        self.weights = self.w_T.T                # (B, T) view for the kernel
        self.resp_T = np.full((self.T, B), -1, np.int64)
        self.vote = np.zeros((B, K), np.float64)  # scatter-add log-weight table
        self.voted = np.zeros((B, K), bool)       # any vote -> real belief
        self.costs = np.zeros(B, np.float64)
        self.arm_query_counts = np.zeros(self.L, np.int64)
        self.cur = np.arange(B)                   # queries still in flight
        self.stop_at = np.full(B, self.T, np.int64)  # wave each query stopped
        self.waves = 0
        self._t = 0
        self._exhausted = False

    def _beliefs_rows(self, rows: np.ndarray) -> np.ndarray:
        router = self.router
        if router.use_kernel:
            # per-row independent contraction: feeding only in-flight rows
            # gives identical beliefs at a fraction of the kernel work
            return router._kernel_beliefs(
                np.ascontiguousarray(self.resp_T.T[rows]),
                self.weights[rows], self.empty[rows],
            )
        return np.where(
            self.voted[rows], self.vote[rows], self.empty[rows][:, None]
        )

    @property
    def exhausted(self) -> bool:
        """True once every query has left the wavefront (reference kind)."""
        return self.kind != "reference" or self._exhausted

    def step(self):
        """Advance the compacting wavefront one wave (reference kind only).

        Returns ``(rows, predictions)`` for the queries that *completed*
        this wave — their Prop. 4 stop fired, or the schedule ran out.
        ``predictions`` carries their final class ids when no tie-break rng
        is in play (a stopped query receives no further votes, so its
        argmax is already final); with an rng it is None and every
        prediction is drawn at finalization, preserving the one-shot path's
        rng stream. After exhaustion returns empty rows.
        """
        assert self.kind == "reference", "step() is for reference routes"
        K = self.router.num_classes
        if self._exhausted:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        cur, t = self.cur, self._t
        bel = self._beliefs_rows(cur)
        if t >= self.T:
            # schedule exhausted: everything still in flight completes now
            self._exhausted = True
            self.cur = np.zeros(0, np.int64)
            preds = tie_break_argmax(bel)[0] if self.rng is None else None
            return cur, preds
        # Prop. 4 early-stop on the in-flight set, one mask per wave
        if K >= 2:
            part = np.partition(bel, K - 2, axis=1)
            h1, h2 = part[:, K - 1], part[:, K - 2]
        else:
            h1, h2 = bel[:, 0], np.full(cur.size, -np.inf)
        sched_t = self.sched_T[t]
        keep = (sched_t[cur] >= 0) & (
            self.res_T[t][cur] + h2 > h1 - self.stop_margin
        )
        stopped = cur[~keep]
        self.stop_at[stopped] = t
        preds = None
        if self.rng is None and stopped.size:
            preds = tie_break_argmax(bel[~keep])[0]
        elif self.rng is None:
            preds = np.zeros(0, np.int64)
        self.cur = cur = cur[keep]
        self._t = t + 1
        if cur.size == 0:
            self._exhausted = True
            return stopped, preds
        live = cur
        if self._failed is not None and not self.router.failover:
            # frozen plans under faults: the wave elapses for every in-flight
            # query, but failed arms are never invoked, charged, or counted
            live = cur[~self._failed[t][cur]]
        if live.size:
            self.waves += 1
            arms_t = sched_t[live]
            votes = self.router.engine.invoke_rows(arms_t, self.payloads, live)
            if self._degrade_T is not None:
                ov = self._degrade_T[t][live]
                votes = np.where(ov >= 0, ov, votes)
            self.arm_query_counts += np.bincount(arms_t, minlength=self.L)
            self.vote[live, votes] += self.w_T[t][live]
            self.voted[live, votes] = True
            self.costs[live] += self.wc_T[t][live]
            self.resp_T[t][live] = votes
        return stopped, preds

    def _finalize_reference(self) -> RouteResult:
        while not self._exhausted:
            self.step()
        responses = np.ascontiguousarray(self.resp_T.T)
        if self.router.use_kernel:
            beliefs = self.router._kernel_beliefs(
                responses, self.weights, self.empty
            )
        else:
            beliefs = np.where(self.voted, self.vote, self.empty[:, None])
        predictions, _ = tie_break_argmax(beliefs, self.rng)
        invoked = responses >= 0
        return RouteResult(
            predictions=predictions,
            costs=self.costs,
            planned_costs=self.planned,
            clusters=self.cluster_ids,
            budgets=np.asarray(self.budgets),
            schedule=self.sched_T.T,
            responses=responses,
            invoked=invoked,
            arm_query_counts=self.arm_query_counts,
            waves=self.waves,
            **self._fault_kwargs(self.stop_at),
        )

    # ------------------------------------------------------------------
    def result(self) -> RouteResult:
        """Block until the route completes and return its RouteResult
        (cached — safe to call repeatedly)."""
        if self._result is None:
            self._result = (
                self._finalize_jit() if self.kind == "jit"
                else self._finalize_reference()
            )
        return self._result


class ThriftRouter:
    """Batched ThriftLLM serving router.

    Args:
      engine: arm pool executor.
      estimator: cluster -> p-hat success-probability estimator.
      num_classes: label-space size K.
      eps, delta, seed: SurGreedy Monte-Carlo parameters (paper Sec. 5).
      use_kernel: route belief aggregation through the ``belief_aggregate``
        Pallas kernel (float32 accumulation, dispatched from inside the
        jitted loop).
      jit_waves: run the wave loop as one on-device ``lax.scan``
        (:meth:`route_batch`); ``False`` falls back to the compacting
        host loop (:meth:`route_batch_reference`) which never invokes arms
        speculatively.
      failover: with an active engine fault policy, re-route a failed arm's
        wave slot to the plan's next-best affordable arm *inside* the wave
        program (both planes, identical semantics); ``False`` freezes the
        plan — failed slots simply lose their vote (and are not charged).
        Irrelevant (zero-cost identity) without injected faults.
      plan_service: optionally share a :class:`PlanService` across routers
        bound to the same pool; by default each router owns one.
    """

    def __init__(
        self,
        engine: PoolEngine,
        estimator: SuccessProbEstimator,
        num_classes: int,
        eps: float = 0.1,
        delta: float = 0.01,
        seed: int = 0,
        use_kernel: bool = False,
        jit_waves: bool = True,
        failover: bool = True,
        plan_service: Optional[PlanService] = None,
        donate_buffers: bool = True,
    ):
        self.engine = engine
        self.estimator = estimator
        self.num_classes = int(num_classes)
        self.use_kernel = bool(use_kernel)
        self.jit_waves = bool(jit_waves)
        self.failover = bool(failover)
        # Donate the staged wave tables to XLA (`_wave_scan` vs its
        # `_nodonate` twin): bit-identical either way; off keeps the
        # transferred device buffers readable after dispatch (debugging).
        self.donate_buffers = bool(donate_buffers)
        # Optional device pin for the wave program. None (default) leaves
        # placement to JAX (the process default device). A ReplicaSet in
        # overlapped placement sets this per worker so each worker's wave
        # dispatches land on its own device and run concurrently; jit then
        # holds one executable per (bucket, device) pair, so prewarming
        # happens per pinned device (see ReplicaSet.prewarm_compile).
        self.device = None
        self.selector = ThriftLLM(
            engine.costs, eps=eps, delta=delta, seed=seed, use_kernel=use_kernel
        )
        self.plans = plan_service or PlanService(
            self.selector, estimator, engine, self.num_classes
        )

    # ------------------------------------------------------------------
    # Planning: (cluster, budget) groups -> one cross-group wave schedule
    # ------------------------------------------------------------------
    def _group_plan(self, cid: int, budget: float) -> GroupPlan:
        return self.plans.plan(cid, budget)

    def _batch_plan(self, cluster_ids: np.ndarray, budgets: np.ndarray):
        """Merge per-group plans into batch-wide *wave-major* matrices.

        Groups are the unique (cluster, budget) pairs; the per-group plan
        rows are stacked once into (G, T) tables and expanded to the batch
        by a single gather on the group-inverse index. Returns
        ``(schedule (T, B), weights (T, B), residual (T, B),
        wave_costs (T, B), empty (B,), planned (B,))`` — wave-major so the
        hot paths touch contiguous (B,) rows per wave with no transposes.

        Heterogeneous-budget batches only; uniform budgets take the
        ``BatchTables`` fast path in :meth:`_plan_batch`."""
        b_vals, b_inv = np.unique(budgets, return_inverse=True)
        c_vals, c_inv = np.unique(cluster_ids, return_inverse=True)
        combo_vals, inverse = np.unique(
            c_inv * b_vals.size + b_inv, return_inverse=True
        )
        group_keys = [
            (int(c_vals[v // b_vals.size]), float(b_vals[v % b_vals.size]))
            for v in combo_vals
        ]
        plans = [self.plans.plan(c, b) for c, b in group_keys]
        order_m, fp_m, empty_v, planned_v = stack_plans(plans)
        fp_b = fp_m[:, :, inverse]                 # one gather for all floats
        return (
            order_m[:, inverse],
            fp_b[0],
            fp_b[1],
            fp_b[2],
            empty_v[inverse],
            planned_v[inverse],
        )

    def _plan_batch(self, embeddings: np.ndarray, budgets: np.ndarray):
        """Shared planning prologue of both batched paths.

        Uniform-budget batches (the common serving case) take the dense
        fast path: one nearest-centroid index lookup, one gather from the
        PlanService's cached :class:`~repro.serving.plans.BatchTables` —
        no ``np.unique``, no per-group Python. Heterogeneous budgets fall
        back to the generic group merge in :meth:`_batch_plan`.

        Returns ``(cluster_ids (B,), schedule (T, B), weights (T, B),
        residual (T, B), wave_costs (T, B), empty (B,), planned (B,))``.
        """
        if budgets[0] == budgets[-1] and (budgets == budgets[0]).all():
            idx = self.estimator.lookup_batch_indices(embeddings)
            cluster_ids = self.estimator.cluster_order[idx]
            tabs = self.plans.batch_tables(float(budgets[0]), idx=idx)
            fp = tabs.floats[:, :, idx]
            return (
                cluster_ids, tabs.order[:, idx], fp[0], fp[1], fp[2],
                tabs.empty[idx], tabs.planned[idx],
            )
        cluster_ids = self.estimator.lookup_batch(embeddings)
        return (cluster_ids,) + self._batch_plan(cluster_ids, budgets)

    def _empty_result(self, budgets: np.ndarray) -> RouteResult:
        return RouteResult(
            predictions=np.zeros(0, np.int64),
            costs=np.zeros(0, np.float64),
            planned_costs=np.zeros(0, np.float64),
            clusters=np.zeros(0, np.int64),
            budgets=np.asarray(budgets),
            schedule=np.full((0, 1), -1, np.int64),
            responses=np.full((0, 1), -1, np.int64),
            invoked=np.zeros((0, 1), bool),
            arm_query_counts=np.zeros(len(self.engine.arms), np.int64),
            waves=0,
        )

    # ------------------------------------------------------------------
    # Belief backend: float64 scatter tables or the Pallas kernel
    # ------------------------------------------------------------------
    def _kernel_beliefs(
        self, responses: np.ndarray, weights: np.ndarray, empty: np.ndarray
    ) -> np.ndarray:
        bel, _ = ops.belief_aggregate(
            jnp.asarray(responses, jnp.int32),
            jnp.asarray(weights, jnp.float32),
            jnp.asarray(empty, jnp.float32),
            self.num_classes,
        )
        return np.asarray(bel, np.float64)

    # ------------------------------------------------------------------
    # Cost metadata for the speculation switch
    # ------------------------------------------------------------------
    def speculation_cost(self, sched_T: np.ndarray, wc_T: np.ndarray) -> float:
        """Mean per-query USD the speculative all-cells gather would bill to
        *metered* arms over and above what any query could ever realize.

        The jitted path invokes every scheduled (query, wave) cell up front;
        the compacting reference only invokes waves the Prop. 4 stop rule
        lets run. The worst-case marginal exposure of speculating is
        therefore the full scheduled spend on metered arms (the realized
        part is paid either way; everything else is at risk of being pure
        waste). Unmetered arms (oracle / tabular / self-hosted) bill
        nothing real, so their speculative work is free throughput and
        contributes zero.
        """
        metered = self.engine.metered_mask
        if not metered.any():
            return 0.0
        billed = (sched_T >= 0) & metered[np.maximum(sched_T, 0)]
        return float(np.where(billed, wc_T, 0.0).sum() / max(sched_T.shape[1], 1))

    # ------------------------------------------------------------------
    # begin/step/finalize routing: the serving front-end's data plane
    # ------------------------------------------------------------------
    def begin_route(
        self,
        queries: Any,                    # arm-payloads, len B (array or list)
        embeddings: np.ndarray,          # (B, d)
        budget: Any,                     # scalar or (B,) per-query budgets
        stop_margin: float = STOP_MARGIN,
        rng: Optional[np.random.Generator] = None,
        mode: str = "auto",
        speculation_threshold: float = 0.0,
        fault_row_offset: int = 0,
    ) -> "PendingRoute":
        """Start routing a batch and return a :class:`PendingRoute` handle.

        This is the non-blocking half of :meth:`route_batch`: planning, the
        speculation-mode decision and (for the jitted mode) the speculative
        response gather + device dispatch all happen here; blocking
        finalization is deferred to ``PendingRoute.result()``. A serving
        front-end can therefore dispatch group *t+1* while group *t*'s
        jitted program is still running on device (double-buffered wave
        pipelining), or advance a reference-mode group wave by wave via
        ``PendingRoute.step()`` and complete per-query futures as each
        query's stop wave fires.

        Args:
          mode: ``"jit"`` forces the speculative jitted wave loop,
            ``"reference"`` the compacting host wavefront, and ``"auto"``
            — the cost-aware speculation switch — picks ``jit`` when
            :meth:`speculation_cost` (mean per-query USD at risk on metered
            arms) is at most ``speculation_threshold`` and falls back to
            ``reference`` for metered/expensive pools.
          speculation_threshold: USD per query the switch may gamble on
            speculative metered invocations. The default 0.0 speculates
            only when speculation is entirely free (no metered arm is
            scheduled).
          fault_row_offset: this batch's starting row inside a logically
            fused batch. A ReplicaSet dispatching the same admission wave
            as R overlapped per-device programs passes each worker's
            concatenation offset so fault draws (keyed on batch row) are
            bit-identical to the single fused dispatch.
        """
        B = len(queries)
        budgets = np.broadcast_to(np.asarray(budget, np.float64), (B,))
        if B == 0:
            return PendingRoute(self, "empty", result=self._empty_result(budgets))
        self.plans.refresh()
        cluster_ids, sched_T, w_T, res_T, wc_T, empty, planned = self._plan_batch(
            embeddings, budgets
        )
        spec_cost = self.speculation_cost(sched_T, wc_T)
        if mode == "auto":
            # a router pinned to the reference plane (jit_waves=False — the
            # pre-metered-flag way to forbid speculation) keeps it under
            # auto, regardless of per-arm flags
            if not self.jit_waves or spec_cost > speculation_threshold:
                kind = "reference"
            else:
                kind = "jit"
        elif mode in ("jit", "reference"):
            kind = mode
        else:
            raise ValueError(f"unknown route mode {mode!r}")
        pending = PendingRoute(
            self, kind,
            budgets=budgets, cluster_ids=cluster_ids, sched_T=sched_T,
            w_T=w_T, res_T=res_T, wc_T=wc_T, empty=empty, planned=planned,
            payloads=self.engine.prepare_payloads(queries),
            stop_margin=float(stop_margin), rng=rng, spec_cost=spec_cost,
            plan_version=getattr(self.estimator, "plan_version", 0),
            fault_row_offset=fault_row_offset,
        )
        if kind == "jit":
            pending._dispatch_jit()
        return pending

    # ------------------------------------------------------------------
    def prewarm_compile(
        self,
        max_batch: int,
        max_waves: Optional[int] = None,
        all_batch_buckets: bool = False,
    ) -> int:
        """Pre-compile the jitted wave program ahead of traffic.

        Compiles every *wave-depth* bucket a plan could schedule (plans
        re-selected by online feedback may deepen across a bucket), at the
        batch bucket of ``max_batch`` — the bucket full admissions land in.
        Partial flushes and split budget groups land in *smaller* batch
        buckets; pass ``all_batch_buckets=True`` to compile those too (one
        program per (B, T) bucket pair — thorough, proportionally slower),
        as a serving replica taking ragged traffic should. ``max_waves``
        defaults to the pool size (no plan can schedule more arms than
        exist). Returns the number of bucket programs visited; no-op for
        routers pinned to the reference plane.

        When ``REPRO_COMPILE_CACHE_DIR`` is set (see
        :func:`repro.serving.compile_cache.configure_compile_cache`) the
        executables compiled here are written to the persistent cache, so
        the *next* process's prewarm loads them instead of re-lowering —
        cold-start latency survives restarts."""
        if not self.jit_waves:
            return 0
        configure_compile_cache()    # no-op unless the env var opts in
        if all_batch_buckets:
            b_buckets = sorted({
                _bucket(b, base=8) for b in range(1, max(1, int(max_batch)) + 1)
            })
        else:
            b_buckets = [_bucket(int(max_batch), base=8)]
        waves = int(max_waves) if max_waves is not None else len(self.engine.arms)
        t_buckets = sorted({_bucket(t, base=4) for t in range(1, max(1, waves) + 1)})
        # jit caches one executable per (bucket, device): a router pinned
        # to a device must warm that device's cache entries, not the
        # default device's — same jax.default_device placement as the
        # dispatch seam (_dispatch_jit), so the warmed entry is exactly
        # the one traffic hits (the context is single-use: built per
        # bucket pair)
        for Bp in b_buckets:
            for Tp in t_buckets:
                ctx = (
                    jax.default_device(self.device)
                    if self.device is not None
                    else contextlib.nullcontext()
                )
                scan_fn = (
                    _wave_scan if self.donate_buffers else _wave_scan_nodonate
                )
                with enable_x64(), ctx, _quiet_donation():
                    scan_fn(
                        np.full((Tp, Bp), -1, np.int32),
                        np.full((Tp, Bp), -1, np.int32),
                        np.zeros((Tp, Bp), np.float64),
                        np.full((Tp, Bp), -np.inf, np.float64),
                        np.broadcast_to(
                            np.arange(Tp, dtype=np.int32)[:, None], (Tp, Bp)
                        ).copy(),
                        np.zeros((Tp, Bp), bool),
                        np.zeros(Bp, np.float64),
                        STOP_MARGIN,
                        num_classes=self.num_classes,
                        use_kernel=self.use_kernel,
                    )
        return len(b_buckets) * len(t_buckets)

    # ------------------------------------------------------------------
    def route_batch(
        self,
        queries: Any,                    # arm-payloads, len B (array or list)
        embeddings: np.ndarray,          # (B, d)
        budget: Any,                     # scalar or (B,) per-query budgets
        stop_margin: float = STOP_MARGIN,
        rng: Optional[np.random.Generator] = None,
    ) -> RouteResult:
        """Route a batch end to end: cluster lookup, plan-cache gather, one
        on-device wave loop, host-side finalization.

        With ``jit_waves=True`` (default) every scheduled (query, wave)
        response is fetched in a single heterogeneous engine call and the
        whole adaptive loop runs as one jitted program; with
        ``jit_waves=False`` this delegates to the compacting
        :meth:`route_batch_reference`. Both return identical
        predictions/costs/arms-used for deterministic arm pools. The
        synchronous convenience wrapper over :meth:`begin_route` +
        ``PendingRoute.result()``.

        Args:
          queries: per-arm payloads (tokens, (cluster, label) pairs, ...).
          embeddings: (B, d) query embeddings for cluster lookup.
          budget: scalar or (B,) per-query USD budgets.
          stop_margin: Prop. 4 slack; keep the default for paper semantics.
          rng: optional generator for belief-tie breaking (None = argmax).
        """
        mode = "jit" if self.jit_waves else "reference"
        return self.begin_route(
            queries, embeddings, budget, stop_margin=stop_margin, rng=rng,
            mode=mode,
        ).result()

    # ------------------------------------------------------------------
    def route_batch_reference(
        self,
        queries: Any,
        embeddings: np.ndarray,
        budget: Any,
        stop_margin: float = STOP_MARGIN,
        rng: Optional[np.random.Generator] = None,
    ) -> RouteResult:
        """Compacting host-side wavefront (the PR 1 engine) — the semantics
        reference the jitted :meth:`route_batch` is equivalence-tested
        against, and the production path for pools where speculative
        invocation costs real money.

        Stopped queries are dropped from the in-flight index set each wave,
        so wave t only touches (and only *invokes*) the queries still in
        flight; belief state is a float64 (B, K) scatter table (or the
        Pallas kernel under ``use_kernel=True``). Implemented as
        :meth:`begin_route` with ``mode="reference"`` stepped to
        completion.
        """
        return self.begin_route(
            queries, embeddings, budget, stop_margin=stop_margin, rng=rng,
            mode="reference",
        ).result()

    # ------------------------------------------------------------------
    def route_batch_sequential(
        self,
        queries: Any,
        embeddings: np.ndarray,
        budget: Any,
        rng: Optional[np.random.Generator] = None,
    ) -> RouteResult:
        """Sequential oracle: one ``adaptive_invoke`` per query.

        The per-query semantics source both batched paths are
        equivalence-tested against (``tests/test_router_batched.py``) and
        the baseline of the serving throughput benchmark. Shares the plan
        service's selection cache, so all paths route the same selected
        sets.

        Exact output equality with :meth:`route_batch` holds for
        *deterministic* arms (responses a pure function of (arm, query),
        e.g. the test TabularArm or LMArm). Stochastic ``OracleArm`` pools
        consume different rng streams on the batched paths (pooled
        ``invoke_rows`` draws vs per-arm draws here), so per-seed
        realizations differ even though the distributions match.
        """
        B = len(queries)
        K = self.num_classes
        budgets = np.broadcast_to(np.asarray(budget, np.float64), (B,))
        self.plans.refresh()
        cluster_ids = self.estimator.lookup_batch(embeddings)
        L = len(self.engine.arms)

        predictions = np.zeros(B, np.int64)
        costs = np.zeros(B, np.float64)
        planned = np.zeros(B, np.float64)
        arms_used: List[List[int]] = []
        resp_rows: List[np.ndarray] = []
        arm_query_counts = np.zeros(L, np.int64)
        for j in range(B):
            p = self.estimator.clusters[int(cluster_ids[j])].p_hat
            sel = self.selector.select(p, K, float(budgets[j]))

            def invoke_one(arm: int) -> int:
                mask = np.zeros(B, bool)
                mask[j] = True
                return int(self.engine.invoke_arm(int(arm), queries, mask)[j])

            inv = adaptive_invoke(
                list(sel.chosen), p, K, invoke_one, rng=rng, costs=self.engine.costs
            )
            predictions[j] = inv.prediction
            costs[j] = inv.cost
            planned[j] = inv.planned_cost
            arms_used.append([int(a) for a in inv.used])
            resp_rows.append(np.asarray(inv.responses, np.int64))
            arm_query_counts[inv.used] += 1
        T = max(1, max((len(a) for a in arms_used), default=1))
        schedule = np.full((B, T), -1, np.int64)
        responses = np.full((B, T), -1, np.int64)
        invoked = np.zeros((B, T), bool)
        for j, used in enumerate(arms_used):
            schedule[j, : len(used)] = used
            responses[j, : len(used)] = resp_rows[j]
            invoked[j, : len(used)] = True
        res = RouteResult(
            predictions=predictions,
            costs=costs,
            planned_costs=planned,
            clusters=cluster_ids,
            budgets=np.asarray(budgets),
            schedule=schedule,
            responses=responses,
            invoked=invoked,
            arm_query_counts=arm_query_counts,
            waves=T,
        )
        res._arms_used = arms_used
        return res
