"""Plan-cache selection service: "plan once, route many".

Selection (SurGreedyLLM) is by far the most expensive step of routing a
query class — a Monte-Carlo greedy over the pool — yet its output depends
only on (cluster p-vector, num_classes, budget, pool costs). The
:class:`PlanService` therefore memoizes the fully derived *wave plan* of
each (cluster, budget) pair: the selected arms in invocation order, their
log belief weights, the Prop. 4 residuals, per-wave costs and the
empty-class belief. The router's hot path then reduces to a dictionary
lookup plus array gathers; this is the same structure OptLLM's
query-to-model assignment and FrugalGPT's offline-learned cascade policy
use to make cost-aware routing cheap per query.

Consistency is guarded by *versioned keys*: every plan key carries the
engine cost-vector digest plus its own cluster's plan ``version`` (the
estimator version of the cluster's last plan-visible change), and batch
tables key on the estimator's global ``plan_version``. Stale entries
therefore invalidate **lazily** — a re-estimated cluster's old plans can
never serve again because no lookup ever constructs their key — and
:meth:`PlanService.refresh` (called by the router once per batch) is
reduced to a cheap version/cost compare: on an estimate change it only
counts the invalidation and prunes the dead entries; on a cost change it
drops everything and re-snapshots the new cost vector into the selector.
Online feedback (``serving/feedback.py``) bumps cluster versions only for
clusters whose estimates actually drifted, so feedback that confirms
current estimates keeps every cache hot.

Hot-pair precomputation: the service counts how often each (cluster,
budget) pair is planned; :meth:`prewarm` builds plans ahead of traffic for
an explicit list of pairs or for the hottest pairs seen so far, so a
serving replica can warm its cache before taking load (or after an
invalidation) without paying selection latency on user queries.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.belief import empty_log_belief, log_weight
from repro.core.types import clip_probs

from .compile_cache import configure_compile_cache


@dataclasses.dataclass
class BatchTables:
    """Per-cluster wave plans stacked into gather-ready wave-major tables.

    One instance covers *every* cluster the estimator knows, at one budget,
    aligned with ``estimator.cluster_order`` — so routing a batch is a pure
    dense gather ``tables.order[:, idx]`` with no uniques, no Python loop.

    Attributes:
      order: (T, C) arm id invoked at wave t for cluster-column c, -1 pad.
      floats: (3, T, C) stacked [log-weights, Prop. 4 residuals, wave costs]
        so one fancy-index gathers all three per batch.
      empty: (C,) empty-class log beliefs.
      planned: (C,) full selected-set USD.
      cluster_ids: (C,) cluster ids aligned with the columns.
    """

    order: np.ndarray
    floats: np.ndarray
    empty: np.ndarray
    planned: np.ndarray
    cluster_ids: np.ndarray


def stack_plans(plans: Sequence["GroupPlan"]):
    """Stack :class:`GroupPlan`s into padded wave-major tables.

    The single layout authority for both the uniform-budget
    :class:`BatchTables` and the router's heterogeneous-budget group merge.
    Returns ``(order (T, G), floats (3, T, G) [weights, residual, costs],
    empty (G,), planned (G,))`` with -1 / -inf / 0 padding past each plan's
    length."""
    G = len(plans)
    T = max(1, max(p.order.size for p in plans))
    order = np.full((T, G), -1, np.int64)
    floats = np.zeros((3, T, G), np.float64)
    floats[1] = -np.inf
    empty = np.empty(G, np.float64)
    planned = np.empty(G, np.float64)
    for g, plan in enumerate(plans):
        n = plan.order.size
        order[:n, g] = plan.order
        floats[0, :n, g] = plan.weights
        floats[1, :n, g] = plan.residual
        floats[2, :n, g] = plan.wave_costs
        empty[g] = plan.empty
        planned[g] = plan.planned
    return order, floats, empty, planned


@dataclasses.dataclass
class GroupPlan:
    """Fully derived wave plan of one (cluster p-vector, budget) group.

    A plan is everything the wavefront loop needs to route a query of this
    group without consulting the selector again:

    Attributes:
      order: (n,) arm ids in decreasing-p invocation order (wave t invokes
        ``order[t]``).
      weights: (n,) log belief weight of ``order[t]`` (Eq. 4 in log space).
      residual: (n,) log F of the arms still ahead at wave t, i.e.
        ``sum(weights[t:])`` — the Prop. 4 early-stop potential.
      wave_costs: (n,) USD cost of ``order[t]``.
      empty: empty-class log belief (the paper's no-vote heuristic).
      planned: total USD of the selected set (the cost if no query of the
        group early-stops).
    """

    order: np.ndarray
    weights: np.ndarray
    residual: np.ndarray
    wave_costs: np.ndarray
    empty: float
    planned: float


# (cluster id, budget, own-cluster plan version, cost fingerprint) -> plan
PlanKey = Tuple[int, float, int, bytes]


class PlanService:
    """Memoizes :class:`GroupPlan`s keyed by (cluster, budget, pool fingerprint).

    Owned by a :class:`~repro.serving.router.ThriftRouter`; shared across
    batches (and shareable across routers bound to the same pool). All
    methods are cheap except a miss, which runs SurGreedy selection.

    Misses are **batched**: every multi-pair entry point (:meth:`plan_many`,
    :meth:`batch_tables`, :meth:`prewarm`, :meth:`prefetch_for`,
    :meth:`replan_stale`) funnels its missing (cluster, budget) pairs into
    one :meth:`~repro.core.selection.ThriftLLM.select_many` call, so a
    cache-miss storm — a cold replica warming up, a drift fold invalidating
    many clusters at once — costs one batched-planner dispatch instead of a
    serial selection per pair. ``batched=False`` pins the serial per-pair
    path (the benchmark baseline); both produce bit-identical plans under
    the planner's shared-CRN contract.
    """

    def __init__(self, selector, estimator, engine, num_classes: int,
                 batched: bool = True):
        self.selector = selector
        self.estimator = estimator
        self.engine = engine
        self.num_classes = int(num_classes)
        self.batched = bool(batched)
        self._cache: Dict[PlanKey, GroupPlan] = {}
        self._table_cache: Dict[Tuple[float, bytes, int], BatchTables] = {}
        self._pair_counts: Counter = Counter()
        # (cluster, budget) pairs whose plans the stale-prune dropped —
        # the batched drift-replan's work list (see replan_stale)
        self._replan_pairs: set = set()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        self.prefetches = 0
        self.stale_dropped = 0
        self.batch_replans = 0
        self.batch_replanned = 0
        self._cost_fp = self.engine.fingerprint()
        self._plan_version = self._estimator_version()

    # ------------------------------------------------------------------
    # Pool identity
    # ------------------------------------------------------------------
    def _estimator_version(self) -> int:
        """The estimator's global plan version — bumped whenever any
        cluster's estimate changes in a plan-visible way (a direct
        ``update`` call, or drifting feedback; confirming feedback leaves
        it put). Batch-table keys carry it; per-pair plan keys carry the
        finer per-cluster version. NOTE: assigning ``p_hat`` directly
        bypasses the version machinery — follow such edits with
        ``estimator.touch(cid)`` or the caches cannot see them."""
        return int(getattr(self.estimator, "plan_version", 0))

    def _cluster_version(self, cid: int) -> int:
        st = self.estimator.clusters.get(int(cid))
        return int(st.version) if st is not None else -1

    def refresh(self) -> bool:
        """Re-check the pool identity; returns True if anything invalidated.

        Invalidation is **lazy** for estimate changes: plan and table keys
        carry estimator versions, so a stale entry can never serve even if
        refresh is never called — this method just counts the invalidation
        and prunes the dead entries so the cache doesn't grow unboundedly
        under continuous feedback. A *cost* change (re-priced or swapped
        arms) is handled eagerly because the selector's internal cost
        snapshot must be re-pulled from the engine before the next build.
        """
        cost_fp = self.engine.fingerprint()
        plan_version = self._estimator_version()
        if cost_fp == self._cost_fp and plan_version == self._plan_version:
            return False
        if cost_fp != self._cost_fp:
            self._cache.clear()
            self._table_cache.clear()
            self._pair_counts.clear()
            self._replan_pairs.clear()   # re-priced pool: nothing to rebuild
            self.selector.rebind_costs(self.engine.costs)
            self._cost_fp = cost_fp
        else:
            self._prune_stale()
        self._plan_version = plan_version
        self.invalidations += 1
        return True

    def _prune_stale(self) -> int:
        """Drop cache entries whose version/cost key no longer matches the
        live pool (they can never be looked up again). Returns plans
        dropped; accumulated in ``stale_dropped`` — the replan counter the
        serving stats expose, since every pruned plan is one the feedback
        loop forced a re-selection of."""
        live = [k for k in self._cache if k == self._plan_key(k[0], k[1])]
        dropped = len(self._cache) - len(live)
        if dropped:
            live_set = set(live)
            self._replan_pairs.update(
                (k[0], k[1]) for k in self._cache if k not in live_set
            )
            self._cache = {k: self._cache[k] for k in live}
        version = self._estimator_version()
        self._table_cache = {
            k: v for k, v in self._table_cache.items()
            if k[1] == self._cost_fp and k[2] == version
        }
        # the selector memoizes on p-vector bytes: entries for dead
        # estimates can never hit again, so bound them too or continuous
        # drift grows the memo forever (oldest-first, live plans stay)
        self.selector.trim_cache(max(128, 4 * len(self._cache)))
        self.stale_dropped += dropped
        return dropped

    # ------------------------------------------------------------------
    # Planning
    # ------------------------------------------------------------------
    def _plan_key(self, cid: int, budget: float) -> PlanKey:
        # the cluster's live plan version is read at every lookup, so a
        # version bump makes old entries unreachable without any scan
        return (int(cid), float(budget), self._cluster_version(cid),
                self._cost_fp)

    def plan(self, cid: int, budget: float) -> GroupPlan:
        """Return the wave plan for (cluster ``cid``, ``budget``), building
        and caching it on first use."""
        key = self._plan_key(cid, budget)
        self._pair_counts[key[:2]] += 1
        plan = self._cache.get(key)
        if plan is not None:
            self.hits += 1
            return plan
        self.misses += 1
        plan = self._build(int(cid), float(budget))
        self._cache[key] = plan
        return plan

    def plan_many(self, pairs: Iterable[Tuple[int, float]]) -> List[GroupPlan]:
        """Wave plans for many (cluster, budget) pairs; one batched
        selection dispatch covers every miss.

        The multi-pair mirror of :meth:`plan` (same hit/miss accounting,
        same cache): cached pairs gather for free, the missing ones are
        selected together through the batched planner. This is the
        cache-miss-storm entry point — a cold batch table, a prewarm, a
        drift replan of many clusters — turning O(misses) serial SurGreedy
        runs into one device program. Returns plans aligned with ``pairs``.
        """
        pairs = [(int(c), float(bg)) for c, bg in pairs]
        for pr in pairs:
            self._pair_counts[pr] += 1
        missing = [
            pr for pr in dict.fromkeys(pairs)
            if self._plan_key(*pr) not in self._cache
        ]
        self.misses += len(missing)
        self.hits += len(pairs) - len(missing)
        for pr, plan in zip(missing, self._build_many(missing)):
            self._cache[self._plan_key(*pr)] = plan
        return [self._cache[self._plan_key(*pr)] for pr in pairs]

    def _build(self, cid: int, budget: float) -> GroupPlan:
        return self._build_many([(int(cid), float(budget))])[0]

    def _build_many(
        self, pairs: Sequence[Tuple[int, float]]
    ) -> List[GroupPlan]:
        """Run selection for ``pairs`` and derive their wave plans.

        With ``batched`` (default) every pair rides one
        ``selector.select_many`` call — a single jitted greedy program over
        the stacked (cluster, budget) groups; ``batched=False`` keeps the
        serial per-pair path (bit-identical results, used as the benchmark
        baseline). Does not touch the cache or the hit/miss counters —
        callers decide how builds are accounted.
        """
        if not pairs:
            return []
        K = self.num_classes
        # the batched program only pays off with groups to share; a single
        # pair takes the serial path (bit-identical under the CRN contract)
        if self.batched and len(pairs) > 1:
            ps = np.stack(
                [self.estimator.clusters[c].p_hat for c, _ in pairs]
            )
            budgets = np.asarray([bg for _, bg in pairs], np.float64)
            sels = self.selector.select_many(ps, K, budgets)
        else:
            sels = [
                self.selector.select(
                    self.estimator.clusters[c].p_hat, K, bg
                )
                for c, bg in pairs
            ]
        return [
            self._derive(self.estimator.clusters[c].p_hat, sel)
            for (c, _), sel in zip(pairs, sels)
        ]

    def _derive(self, p: np.ndarray, sel) -> GroupPlan:
        """(cluster p-vector, SelectionResult) -> the derived wave plan."""
        K = self.num_classes
        pc = clip_probs(p)
        # identical ordering to adaptive_invoke: stable sort on clipped p
        order = np.asarray(sorted(list(sel.chosen), key=lambda i: -pc[i]), np.int64)
        w_order = log_weight(pc, K)[order]
        # residual log F exactly as the sequential loop sums it each round
        residual = np.asarray(
            [np.sum(w_order[t:]) for t in range(order.size)], np.float64
        )
        wave_costs = np.asarray(self.engine.costs, np.float64)[order]
        return GroupPlan(
            order=order,
            weights=w_order,
            residual=residual,
            wave_costs=wave_costs,
            empty=empty_log_belief(pc),
            planned=float(wave_costs.sum()) if order.size else 0.0,
        )

    def replan_stale(self, clusters: Optional[Iterable[int]] = None) -> int:
        """Rebuild, as one batched dispatch, every plan the stale-prunes
        dropped — the drift-replan fast path.

        The scheduler calls this at the admission boundary right after a
        drifting feedback fold: :meth:`refresh` prunes the invalidated
        entries (recording their (cluster, budget) pairs), then all of them
        re-select through one :meth:`_build_many` call, so a fold that
        drifts G clusters costs one batched-planner dispatch instead of G
        cold selections on the next batches. ``clusters`` optionally
        restricts the rebuild; unrestricted pairs stay queued. Returns the
        number of plans rebuilt (also accumulated in ``batch_replanned``).
        """
        self.refresh()
        pending = sorted(self._replan_pairs)
        if clusters is not None:
            want = {int(c) for c in clusters}
            pending = [pr for pr in pending if pr[0] in want]
        self._replan_pairs.difference_update(pending)
        build = [
            pr for pr in pending
            if pr[0] in self.estimator.clusters
            and self._plan_key(*pr) not in self._cache
        ]
        if not build:
            return 0
        for pr, plan in zip(build, self._build_many(build)):
            self._cache[self._plan_key(*pr)] = plan
        self.batch_replans += 1
        self.batch_replanned += len(build)
        return len(build)

    def batch_tables(
        self, budget: float, idx: Optional[np.ndarray] = None
    ) -> BatchTables:
        """Stacked wave tables over all known clusters at ``budget``.

        The batch-level "plan once, route many" cache: built from the
        per-pair plans on first use (counting their hits/misses), then a
        uniform-budget batch routes via one cached table gather — zero
        selector work, zero per-group Python. Invalidates with the pool
        fingerprint like every plan.

        ``idx`` (optional (B,) dense cluster indices of the batch) feeds
        the traffic accounting: per-query (cluster, budget) counts keep
        :meth:`hot_pairs` meaningful, and a cache hit counts one plan hit
        per cluster the batch actually contains."""
        key = (float(budget), self._cost_fp, self._estimator_version())
        tables = self._table_cache.get(key)
        if tables is not None:
            if idx is None:
                self.hits += tables.order.shape[1]
            else:
                self.hits += self._note_traffic(tables, float(budget), idx)
            return tables
        cids = getattr(self.estimator, "cluster_order", None)
        if cids is None:
            cids = np.asarray(sorted(self.estimator.clusters))
        # cache-miss storm = one batched-planner dispatch (cold tables, or
        # a drift fold that invalidated many clusters at once)
        plans = self.plan_many([(int(c), float(budget)) for c in cids])
        order, floats, empty, planned = stack_plans(plans)
        tables = BatchTables(
            order=order, floats=floats, empty=empty, planned=planned,
            cluster_ids=np.asarray(cids, np.int64),
        )
        self._table_cache[key] = tables
        if idx is not None:
            self._note_traffic(tables, float(budget), idx)
        return tables

    def _note_traffic(
        self, tables: BatchTables, budget: float, idx: np.ndarray
    ) -> int:
        """Fold a batch's per-query (cluster, budget) counts into the
        hot-pair tracker; returns how many distinct clusters the batch hit."""
        counts = np.bincount(idx, minlength=tables.cluster_ids.size)
        present = 0
        for c, n in zip(tables.cluster_ids, counts):
            if n:
                self._pair_counts[(int(c), budget)] += int(n)
                present += 1
        return present

    # ------------------------------------------------------------------
    # Precomputation ahead of traffic
    # ------------------------------------------------------------------
    def hot_pairs(self, n: int = 16) -> List[Tuple[int, float]]:
        """The ``n`` most frequently planned (cluster, budget) pairs."""
        return [pair for pair, _ in self._pair_counts.most_common(n)]

    def known_budgets(self) -> List[float]:
        """Every budget observed in planned traffic, ascending — the
        default downgrade ladder for cost-ledger admission (a downgraded
        request lands on a budget that already has warm plans)."""
        return sorted({float(b) for _, b in self._pair_counts})

    def prewarm(
        self,
        pairs: Optional[Iterable[Tuple[int, float]]] = None,
        budgets: Optional[Sequence[float]] = None,
        top: int = 16,
    ) -> int:
        """Build plans ahead of traffic; returns the number of plans built.

        Three modes:
          * ``pairs`` given — plan exactly those (cluster, budget) pairs;
          * ``budgets`` given — plan the cross product of every known
            cluster with each budget (cold-start warmup);
          * neither — re-plan the ``top`` hottest pairs observed so far
            (post-invalidation warmup; the hot-pair snapshot is taken
            *before* refreshing, so it survives a cost invalidation).
        """
        # planner cold starts benefit from the same persistent compile
        # cache as the wave program: the `_sur_greedy_scan` buckets built
        # here are written to REPRO_COMPILE_CACHE_DIR when opted in
        configure_compile_cache()
        hot_before = self.hot_pairs(top) if pairs is None and budgets is None else None
        self.refresh()
        if pairs is None:
            if budgets is not None:
                pairs = [
                    (int(c), float(b))
                    for c in self.estimator.clusters
                    for b in budgets
                ]
            else:
                pairs = hot_before
        build = [
            pr for pr in dict.fromkeys(
                (int(c), float(bg)) for c, bg in pairs
            )
            if pr[0] in self.estimator.clusters
            and self._plan_key(*pr) not in self._cache
        ]
        for pr, plan in zip(build, self._build_many(build)):
            self._cache[self._plan_key(*pr)] = plan
        return len(build)

    def prefetch_for(self, embeddings: np.ndarray, budgets: np.ndarray) -> int:
        """Queue-composition plan prefetch: given the (embedding, budget)
        columns of a *pending* request queue, map them to clusters and build
        whatever (cluster, budget) plans the coming flush will need — plus
        the stacked batch tables when the composition is uniform-budget (the
        common serving case). Called by the scheduler while a batch is
        accumulating, so SurGreedy selection latency is paid before the
        flush deadline instead of on the routed batch. Returns the number
        of plans built; counts them as prefetches, not misses.
        """
        self.refresh()
        embeddings = np.asarray(embeddings, np.float64)
        if embeddings.shape[0] == 0:
            return 0
        idx = self.estimator.lookup_batch_indices(embeddings)
        cids = self.estimator.cluster_order[idx]
        budgets = np.asarray(budgets, np.float64)
        build = [
            pr for pr in sorted(
                {(int(c), float(b)) for c, b in zip(cids, budgets)}
            )
            if self._plan_key(*pr) not in self._cache
        ]
        for pr, plan in zip(build, self._build_many(build)):
            self._cache[self._plan_key(*pr)] = plan
        self.prefetches += len(build)
        if (budgets == budgets[0]).all():
            self.batch_tables(float(budgets[0]))
        return len(build)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        """Cache counters: hits/misses across lookups, invalidations, size."""
        return {
            "plan_hits": self.hits,
            "plan_misses": self.misses,
            "plan_invalidations": self.invalidations,
            "plan_prefetches": self.prefetches,
            "plan_cache_size": len(self._cache),
            "plan_stale_dropped": self.stale_dropped,
            "plan_batch_replans": self.batch_replans,
            "plan_batch_replanned": self.batch_replanned,
        }
