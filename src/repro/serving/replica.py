"""R-replica serving plane: replicated wave engines, shared control plane.

Everything before this module is one scheduler, one device, one Python
process. This module carves the serving stack into the split the ROADMAP
north-star ("heavy traffic from millions of users") demands:

* **Data plane — replicated, with three placements.** A
  :class:`ReplicaWorker` is one
  :class:`~repro.serving.scheduler.BatchScheduler` over its own
  :class:`~repro.serving.router.ThriftRouter` clone: one jitted wave
  program set and one hot per-replica plan read path each. How the
  workers' wave programs reach silicon is ``ReplicaSet(placement=...)``:

  - ``"overlapped"`` (default with >1 local device) — each worker pins to
    its own device (:func:`~repro.distributed.sharding.replica_devices`
    round-robins the device list); every drive cycle launches each
    worker's wave program asynchronously on its device
    (``jax.device_put`` of the padded tables + the per-device jit
    executable) and overlaps the dispatches — R device programs run
    concurrently while the host finalizes in arrival order. Per-worker
    fault draws carry the worker's fused-concatenation row offset, so
    overlapped routes are bit-identical to the fused dispatch of the same
    admission wave (``tests/test_replica_devices.py`` pins this, faults
    included).
  - ``"fused"`` (default with one device) — same-budget staged groups
    from several workers concatenate into ONE ``begin_route`` along the
    batch axis — the single-device degenerate of sharding the wave
    program's (T, B) tables over a batch-axis device slice (see
    :func:`~repro.distributed.sharding.replica_mesh` for the mesh a
    ``jax.shard_map`` lowering binds to) — and each worker adopts a
    :class:`_RouteView` slice of the fused route.
  - ``"inline"`` (the R=1 default) — each worker launches its own groups
    the instant they admit, exactly like a standalone scheduler; this is
    the bit-identity anchor against :class:`BatchScheduler`.
* **Admission — sharded by cluster affinity.** ``submit_many`` scatters a
  columnar block across workers by a splitmix hash of each query's
  cluster index, so one cluster's traffic keeps hitting one replica and
  its plan reads stay hot; when the hash overloads a replica (skewed
  traffic), the overflow *spills* to the least-loaded replica
  (``replica_spills`` counts it). One caller-visible
  :class:`~repro.serving.scheduler.BlockFuture` spans all shards via the
  ``submit_block`` seam.
* **Control plane — shared.** All workers route against ONE
  :class:`~repro.serving.plans.PlanService` (drifted clusters replan once,
  centrally, through the batched ``plan_many`` dispatch; new plan versions
  reach every replica by the existing lazy version-keyed invalidation),
  ONE :class:`~repro.serving.scheduler.CostLedger` (per-tenant budgets and
  QPS limits enforced at each worker's admission, settled per replica at
  retire), and ONE central :class:`~repro.serving.feedback.FeedbackLog`
  that is the request-id authority. Each worker observes outcomes into a
  replica-local log; at admission boundaries the set exports every local
  log's pending counts as a :class:`~repro.serving.feedback.FeedbackShard`,
  :func:`~repro.serving.feedback.merge_counts` adds them (exact — counts
  are monotone integer sums), and the merged shard folds through ONE
  central ``apply`` with the estimator ``version`` as the cross-replica
  epoch. Any partition of a label stream across R shards reproduces the
  single-log estimator state and replan set exactly
  (``tests/test_replica_merge.py`` pins this).

**R=1 equivalence contract.** ``ReplicaSet(router, replicas=1)`` is
bit-identical to ``BatchScheduler(router)`` on the same stream:
predictions, costs, stats counters, plan hit rates, feedback folds,
ledger settlement. Worker 0 *is* the given router; fusion is off at R=1;
the local feedback log clones the central log's parameters (same probe
rng stream); retirement order is the same FIFO. ``tests/test_replica.py``
pins the whole contract.

**Fused-dispatch caveat.** Fusing concatenates batches, which changes
each row's batch index — and injected fault draws hash on (arm, wave,
row index), so a fused route under an active
:class:`~repro.distributed.fault.FaultPolicy` draws different (equally
deterministic) faults than the same rows dispatched unfused. R=1 never
fuses, so the equivalence contract is unaffected; at R>1 the fault plane
remains deterministic given the admission layout — and the overlapped
placement passes each worker's concatenation offset as
``fault_row_offset``, so fused and overlapped placements of the same
admission wave draw the *same* faults cell for cell.

**Overlapped ≡ fused equivalence caveat.** The per-request bit-identity
between ``placement="fused"`` and ``placement="overlapped"`` holds for
deterministic (tabular / self-hosted) arms, where a row's response is a
function of the row alone. A *pooled* oracle engine draws responses from
one shared rng stream that advances per engine call, so one fused call
and R per-worker calls consume the stream differently — equally
deterministic, but not cell-identical.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.distributed.fault import FAULT_ERROR, FAULT_TIMEOUT, _mix64
from repro.distributed.sharding import replica_devices

from .feedback import FeedbackLog, FeedbackReport, FeedbackShard, merge_counts
from .router import RouteResult, ThriftRouter
from .scheduler import BatchScheduler, BlockFuture, CostLedger, _Group

__all__ = ["ReplicaSet", "ReplicaWorker"]

#: scheduler-core counters summed across workers by ``ReplicaSet.stats``
#: (everything else in a worker's stats dict mirrors a *shared* subsystem
#: — plans/ledger — or a per-worker one aggregated separately)
_CORE_STATS = (
    "batches", "requests", "flushes", "submitted", "completed",
    "spec_jit", "spec_reference", "inflight_peak",
)

#: non-None sentinel for _RouteView.rng: the retire path steps a
#: reference-kind route wave by wave only when its rng is None, and a
#: fused view must always take the blocking result() branch (its parent
#: is shared — per-slice stepping would interleave wavefronts)
_FUSED = object()


def _affinity_shard(cluster_idx: np.ndarray, replicas: int) -> np.ndarray:
    """Cluster-affinity hash: dense cluster index -> replica id, via the
    splitmix64 finalizer (stateless, well-mixed even for the small dense
    index ranges clustering produces)."""
    with np.errstate(over="ignore"):      # uint64 wraparound IS the hash
        h = _mix64(
            np.asarray(cluster_idx, np.uint64) + np.uint64(0x9E3779B97F4A7C15)
        )
    return (h % np.uint64(replicas)).astype(np.int64)


class _ShardLog(FeedbackLog):
    """Replica-local feedback log.

    Observes/records/probes exactly like a standalone log — same
    parameters as the central log, probe rng decorrelated by worker index
    (worker 0 keeps the central seed, preserving the R=1 stream) — but the
    central log stays the request-id authority (ids must be unique across
    the whole set) and this log never applies: the control plane exports
    its pending counts as a shard and folds them centrally.
    """

    def __init__(self, central: FeedbackLog, worker: int):
        super().__init__(
            central.estimator,
            delta=central.delta,
            drift_delta=central.drift_delta,
            max_watch=central.max_watch,
            probe_rate=central.probe_rate,
            probe_seed=central.probe_seed + worker,
        )
        self._central = central

    def next_ids(self, n: int) -> np.ndarray:
        return self._central.next_ids(n)


class _StagedGroup:
    """One admitted budget group a worker deferred instead of launching."""

    __slots__ = ("payloads", "emb", "budgets", "arrival", "part_sinks",
                 "part_id", "part_pos", "ids", "tenants", "reserved", "mode")

    def __init__(self, payloads, emb, budgets, arrival, part_sinks, part_id,
                 part_pos, ids, tenants, reserved, mode):
        self.payloads = payloads
        self.emb = emb
        self.budgets = budgets
        self.arrival = arrival
        self.part_sinks = part_sinks
        self.part_id = part_id
        self.part_pos = part_pos
        self.ids = ids
        self.tenants = tenants
        self.reserved = reserved
        self.mode = mode

    @property
    def n(self) -> int:
        return self.budgets.shape[0]


def _slice_result(res: RouteResult, lo: int, hi: int, L: int) -> RouteResult:
    """Row slice [lo, hi) of a fused RouteResult, with the per-batch
    aggregates (arm counts, wave depth, fault counts) recomputed for the
    slice so a worker's accounting sees only its own rows."""
    schedule = res.schedule[lo:hi]
    invoked = res.invoked[lo:hi]
    kw = {}
    if res.fault_codes is not None:
        fsched = res.fault_schedule[lo:hi]
        fcodes = res.fault_codes[lo:hi]
        hit = (fcodes == FAULT_TIMEOUT) | (fcodes == FAULT_ERROR)
        kw = dict(
            fault_schedule=fsched,
            fault_codes=fcodes,
            arm_fault_counts=np.bincount(fsched[hit], minlength=L),
        )
    return RouteResult(
        predictions=res.predictions[lo:hi],
        costs=res.costs[lo:hi],
        planned_costs=res.planned_costs[lo:hi],
        clusters=res.clusters[lo:hi],
        budgets=np.asarray(res.budgets)[lo:hi],
        schedule=schedule,
        responses=res.responses[lo:hi],
        invoked=invoked,
        arm_query_counts=np.bincount(schedule[invoked], minlength=L),
        waves=int(invoked.any(axis=0).sum()) if invoked.size else 0,
        **kw,
    )


class _RouteView:
    """A worker's slice of one fused PendingRoute.

    Quacks like the PendingRoute surface the retire path touches: ``kind``
    / ``plan_version`` / ``spec_cost`` proxy the parent, ``payloads`` is
    the worker's own row slice (the probe side channel invokes with
    group-relative rows), ``ready()`` polls the shared device program and
    ``result()`` caches a row slice of the parent's RouteResult. ``rng``
    is a non-None sentinel so the retire path never wave-steps a view.
    """

    __slots__ = ("_parent", "_lo", "_hi", "_L", "rng", "_res")

    def __init__(self, parent, lo: int, hi: int, L: int):
        self._parent = parent
        self._lo = lo
        self._hi = hi
        self._L = L
        self.rng = _FUSED
        self._res: Optional[RouteResult] = None

    @property
    def kind(self) -> str:
        return self._parent.kind

    @property
    def plan_version(self) -> int:
        return self._parent.plan_version

    @property
    def spec_cost(self) -> float:
        return self._parent.spec_cost

    @property
    def payloads(self):
        return self._parent.payloads[self._lo:self._hi]

    def ready(self) -> bool:
        return self._parent.ready()

    def result(self) -> RouteResult:
        if self._res is None:
            self._res = _slice_result(
                self._parent.result(), self._lo, self._hi, self._L
            )
        return self._res


class _WorkerScheduler(BatchScheduler):
    """Per-replica BatchScheduler with the two seams a ReplicaSet drives:
    feedback folds route through the control plane's shard merge, and the
    dispatch launch can be deferred so the set can fuse same-budget groups
    from several workers into one wave program."""

    def __init__(self, *args, **kwargs):
        self._control: Optional["ReplicaSet"] = None
        self._defer_dispatch = False
        self._staged: List[_StagedGroup] = []
        super().__init__(*args, **kwargs)

    def apply_feedback(self) -> Optional[FeedbackReport]:
        if self._control is None:
            return super().apply_feedback()
        return self._control.merge_apply()

    def _launch(self, payloads, emb, budgets, arrival, part_sinks, part_id,
                part_pos, ids, tenants, reserved, mode):
        if self._defer_dispatch:
            self._staged.append(_StagedGroup(
                payloads, emb, budgets, arrival, part_sinks, part_id,
                part_pos, ids, tenants, reserved, mode,
            ))
            return
        super()._launch(payloads, emb, budgets, arrival, part_sinks, part_id,
                        part_pos, ids, tenants, reserved, mode)

    def _adopt(self, view: _RouteView, g: _StagedGroup) -> None:
        """Take ownership of one slice of a fused dispatch (the deferred
        half of :meth:`_launch`)."""
        self._stats["spec_" + view.kind] += 1
        self._stats["batches"] += 1
        self._inflight.append(_Group(
            view, g.arrival, g.part_sinks, g.part_id, g.part_pos,
            ids=g.ids, tenants=g.tenants, reserved=g.reserved,
        ))
        self._stats["inflight_peak"] = max(
            self._stats["inflight_peak"], len(self._inflight)
        )


class ReplicaWorker:
    """One replica of the serving data plane: a router clone (sharing the
    set's PlanService/selector) driven by a :class:`_WorkerScheduler`,
    optionally pinned to a device."""

    __slots__ = ("index", "router", "sched", "device")

    def __init__(self, index: int, router: ThriftRouter,
                 sched: _WorkerScheduler, device=None):
        self.index = index
        self.router = router
        self.sched = sched
        self.device = device

    @property
    def backlog(self) -> int:
        """Queued + in-flight requests — the spill load signal."""
        return self.sched._qlen + sum(g.n for g in self.sched._inflight)


class ReplicaSet:
    """Sharded admission front-end over R replica workers.

    Drop-in for the streaming half of :class:`BatchScheduler`: ``submit``
    / ``submit_many`` / ``pump`` / ``drain`` / ``record_outcome(s)`` /
    ``apply_feedback`` / ``stats`` / ``latency_stats`` all exist with the
    same semantics (the one-shot ``flush()`` API intentionally does not —
    batch callers want a single scheduler).

    Args:
      router: the data-plane template. Worker 0 uses it as-is; workers
        1..R-1 get clones sharing its engine, estimator, selector and
        PlanService (the shared control plane).
      replicas: R. ``replicas=1`` is bit-identical to ``BatchScheduler``.
      placement: how worker wave programs reach devices —
        ``"overlapped"`` (per-device async dispatch, overlapped across
        workers), ``"fused"`` (same-budget groups concatenate into one
        single-device dispatch), or ``"inline"`` (each worker launches
        alone, the standalone-scheduler cadence). Default (None): R=1
        picks ``"inline"`` (the bit-identity anchor), R>1 picks
        ``"overlapped"`` when the process has more than one device and
        ``"fused"`` otherwise.
      fuse_waves: legacy boolean spelling of ``placement`` (True →
        ``"fused"``, False → ``"inline"``); ignored when ``placement`` is
        given. ``self.fuse_waves`` stays readable as "this set fuses".
      spill_factor: a replica may be assigned at most
        ``ceil(spill_factor * n / R)`` rows of one admitted block by
        affinity; the excess spills row by row to the least-loaded other
        replicas (never back to the over-cap home).
      feedback / ledger / remaining kwargs: as on :class:`BatchScheduler`
        (``max_batch`` etc. apply per worker; ``feedback``/``ledger``
        instances are shared set-wide).
    """

    def __init__(
        self,
        router: ThriftRouter,
        replicas: int = 2,
        *,
        max_batch: int = 64,
        max_wait_s: float = 0.02,
        max_inflight: int = 2,
        speculation: str = "auto",
        speculation_threshold: float = 0.0,
        slo_margin_s: float = 0.002,
        prefetch_plans: bool = True,
        coalesce: int = 1,
        feedback=None,
        ledger=None,
        budget_tiers=None,
        placement: Optional[str] = None,
        fuse_waves: Optional[bool] = None,
        spill_factor: float = 1.5,
    ):
        replicas = int(replicas)
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.replicas = replicas
        self.router = router
        self.estimator = router.estimator
        self.plans = router.plans
        if feedback is True:
            feedback = FeedbackLog(router.estimator)
        self.feedback: Optional[FeedbackLog] = feedback or None
        if ledger is True:
            ledger = CostLedger(num_arms=len(router.engine.arms))
        self.ledger: Optional[CostLedger] = ledger or None
        if placement is None and fuse_waves is not None:
            placement = "fused" if fuse_waves else "inline"
        if placement is None:
            if replicas == 1:
                placement = "inline"
            elif len(jax.devices()) > 1:
                placement = "overlapped"
            else:
                placement = "fused"
        if placement not in ("overlapped", "fused", "inline"):
            raise ValueError(f"unknown placement {placement!r}")
        self.placement = placement
        self.fuse_waves = placement == "fused"
        self.spill_factor = float(spill_factor)
        self.speculation_threshold = float(speculation_threshold)
        self._next_id = 0
        self.spills = 0
        self.fused_dispatches = 0
        self.fused_rows = 0
        self.overlapped_dispatches = 0
        self.overlapped_rows = 0
        devices = replica_devices(replicas)
        self.device_count = len({str(d) for d in devices if d is not None}) or 1
        self.workers: List[ReplicaWorker] = []
        for i in range(replicas):
            r = router if i == 0 else self._clone_router(router)
            # per-worker device pin: in overlapped placement the worker's
            # wave dispatches (and prewarm) land on its own device, so R
            # device programs from one drive cycle run concurrently; other
            # placements clear any pin a prior set left on a reused router
            r.device = devices[i] if placement == "overlapped" else None
            local = (
                _ShardLog(self.feedback, worker=i)
                if self.feedback is not None else None
            )
            sched = _WorkerScheduler(
                r, max_batch=max_batch, max_wait_s=max_wait_s,
                max_inflight=max_inflight, speculation=speculation,
                speculation_threshold=speculation_threshold,
                slo_margin_s=slo_margin_s, prefetch_plans=prefetch_plans,
                coalesce=coalesce, feedback=local, ledger=self.ledger,
                budget_tiers=budget_tiers,
            )
            sched._control = self
            self.workers.append(ReplicaWorker(i, r, sched, devices[i]))

    @staticmethod
    def _clone_router(router: ThriftRouter) -> ThriftRouter:
        """A data-plane clone: own begin_route entry (so per-worker wave
        dispatches interleave), shared engine/estimator/selector and —
        the control-plane contract — shared PlanService."""
        clone = ThriftRouter(
            router.engine, router.estimator, router.num_classes,
            use_kernel=router.use_kernel, jit_waves=router.jit_waves,
            failover=router.failover, plan_service=router.plans,
            donate_buffers=router.donate_buffers,
        )
        clone.selector = router.selector
        return clone

    # ------------------------------------------------------------------
    # Sharded admission
    # ------------------------------------------------------------------
    def _alloc_ids(self, n: int) -> np.ndarray:
        if self.feedback is not None:
            return self.feedback.next_ids(n)
        start = self._next_id
        self._next_id += n
        return np.arange(start, start + n, dtype=np.int64)

    def _assign(self, emb: np.ndarray, n: int) -> np.ndarray:
        """Replica id per row: cluster-affinity hash, with per-block spill
        of the overflow beyond ``spill_factor`` x fair share to the least
        loaded replicas (affinity keeps plan reads hot; spill caps skew).

        Spill membership is decided once, from the pre-spill assignment:
        each over-cap replica keeps its FIFO prefix and sheds its tail.
        Spilled rows then place one at a time on the least-loaded *other*
        replica (a row can never land back on an over-cap home, and a row
        that already spilled is never re-spilled by a later overflow — the
        double-count that used to inflate ``replica_spills`` when several
        replicas overflowed into each other)."""
        R = self.replicas
        if R == 1:
            return np.zeros(n, np.int64)
        idx = self.estimator.lookup_batch_indices(emb)
        assign = _affinity_shard(idx, R)
        cap = int(np.ceil(self.spill_factor * n / R))
        counts = np.bincount(assign, minlength=R)
        over = np.flatnonzero(counts > cap)
        if over.size == 0:
            return assign
        load = np.asarray([w.backlog for w in self.workers], np.int64)
        # spill sets fixed from the ORIGINAL assignment; homes settle at cap
        spill_sets = [(r, np.flatnonzero(assign == r)[cap:]) for r in over]
        totals = load + np.minimum(counts, cap)
        big = np.iinfo(np.int64).max
        for r, spill in spill_sets:
            masked = totals.copy()
            masked[r] = big                     # never spill to self
            for row in spill:
                tgt = int(np.argmin(masked))
                assign[row] = tgt
                masked[tgt] += 1
                totals[tgt] += 1
            self.spills += int(spill.size)
        return assign

    def submit(self, req) -> Any:
        """Route one request to its affinity replica; returns that
        worker's RequestFuture (its ``result()`` drives the owning worker,
        which is all the request needs)."""
        emb = np.asarray(req.embedding, np.float64)[None, :]
        w = self.workers[int(self._assign(emb, 1)[0])] \
            if self.replicas > 1 else self.workers[0]
        return w.sched.submit(req)

    def submit_many(
        self,
        payloads,
        embeddings: np.ndarray,
        budgets,
        slo_s: Optional[float] = None,
        arrival_s=None,
        tenant="default",
    ) -> BlockFuture:
        """Columnar block admission, sharded: one caller-visible
        BlockFuture whose rows scatter across workers by cluster
        affinity (each worker fills its rows through the ``submit_block``
        seam)."""
        emb = np.asarray(embeddings, np.float64)
        n = emb.shape[0]
        if n == 0:
            return BlockFuture(self, 0)
        budgets = np.broadcast_to(np.asarray(budgets, np.float64), (n,)).copy()
        if arrival_s is None:
            arrival = np.full(n, time.monotonic())
        else:
            arrival = np.broadcast_to(
                np.asarray(arrival_s, np.float64), (n,)
            ).copy()
        slo = np.full(n, np.nan if slo_s is None else float(slo_s))
        ids = self._alloc_ids(n)
        blk = BlockFuture(self, n, request_ids=ids)
        tenants = np.broadcast_to(np.asarray(tenant, object), (n,)).copy()
        assign = self._assign(emb, n)
        for r in range(self.replicas):
            rows = np.flatnonzero(assign == r)
            if rows.size == 0:
                continue
            self.workers[r].sched.submit_block(
                BatchScheduler._index_payloads(payloads, rows),
                emb[rows], budgets[rows], arrival[rows], slo[rows],
                blk, rows, ids[rows], tenants[rows],
            )
        return blk

    # ------------------------------------------------------------------
    # Shared control plane: merged feedback folds
    # ------------------------------------------------------------------
    def merge_apply(self) -> Optional[FeedbackReport]:
        """The set-wide admission-boundary fold: export every replica's
        pending counts, :func:`merge_counts` them, fold the merged shard
        through ONE central apply, replan drifted clusters once via the
        shared PlanService. Gated exactly like the single-scheduler fold,
        so R=1 produces the same ``applies`` trajectory."""
        central = self.feedback
        if central is None:
            return None
        locals_ = [w.sched.feedback for w in self.workers]
        if not (central.has_pending or any(l.has_pending for l in locals_)):
            return None
        shards = [l.export_shard() for l in locals_ if l.has_pending]
        if shards:
            central.absorb_shard(merge_counts(*shards))
        report = central.apply()
        if report.drifted:
            self.plans.replan_stale(report.drifted)
        return report

    apply_feedback = merge_apply

    def record_outcome(self, request_id: int, label: int) -> bool:
        return self.record_outcomes([request_id], [label]) == 1

    def record_outcomes(self, request_ids, labels) -> int:
        """Route each ground-truth label to the replica watching its
        request id; ids no replica knows land on the central log (which
        counts them unmatched). Returns how many ids matched."""
        if self.feedback is None:
            raise RuntimeError(
                "feedback is disabled; construct ReplicaSet(..., feedback=True)"
            )
        ids = np.asarray(request_ids, np.int64).ravel()
        labs = np.asarray(labels, np.int64).ravel()
        per: List[List[List[int]]] = [[[], []] for _ in self.workers]
        stray_ids: List[int] = []
        stray_labs: List[int] = []
        for rid, lab in zip(ids.tolist(), labs.tolist()):
            for w in self.workers:
                if rid in w.sched.feedback._watch:
                    per[w.index][0].append(rid)
                    per[w.index][1].append(lab)
                    break
            else:
                stray_ids.append(rid)
                stray_labs.append(lab)
        matched = 0
        for w in self.workers:
            rids, rlabs = per[w.index]
            if rids:
                matched += w.sched.feedback.record_many(rids, rlabs)
        if stray_ids:
            self.feedback.record_many(stray_ids, stray_labs)
        return matched

    # ------------------------------------------------------------------
    # Gang driving
    # ------------------------------------------------------------------
    def _dispatch(self, due: List[ReplicaWorker]) -> None:
        """Admit one batch on each due worker. Inline placement: the
        worker launches the moment it admits (bit-identical to a
        standalone scheduler). Otherwise workers stage their budget
        groups, then per budget either the staged groups concatenate into
        one ``begin_route`` along the batch axis (fused) and each worker
        adopts its row-slice view, or each worker's group launches
        asynchronously on its own device (overlapped) with its
        fused-concatenation row offset feeding the fault draws."""
        if self.placement == "inline":
            for w in due:
                w.sched._dispatch_batch()
            return
        staged: List[tuple] = []
        for w in due:
            s = w.sched
            s._defer_dispatch = True
            try:
                s._dispatch_batch()
            finally:
                s._defer_dispatch = False
            staged.extend((w, g) for g in s._staged)
            s._staged.clear()
        if not staged:
            return
        by_budget: Dict[float, List[tuple]] = {}
        for w, g in staged:
            # scheduler groups are uniform-budget by construction
            by_budget.setdefault(float(g.budgets[0]), []).append((w, g))
        for entries in by_budget.values():
            if self.placement == "overlapped":
                self._launch_overlapped(entries)
            elif len(entries) == 1:
                w, g = entries[0]
                w.sched._launch(
                    g.payloads, g.emb, g.budgets, g.arrival, g.part_sinks,
                    g.part_id, g.part_pos, g.ids, g.tenants, g.reserved,
                    g.mode,
                )
                w.sched._stats["inflight_peak"] = max(
                    w.sched._stats["inflight_peak"], len(w.sched._inflight)
                )
            else:
                self._launch_fused(entries)

    def _launch_overlapped(self, entries: List[tuple]) -> None:
        """Per-device async dispatch of one budget's staged groups.

        Walks the entries in the same order the fused placement would
        concatenate them, launching each worker's wave program through its
        *own* (device-pinned) router — all R device programs are in flight
        before any result is consumed, so their device compute overlaps
        while retirement stays in per-worker arrival order. Each launch
        carries the worker's concatenation offset as ``fault_row_offset``:
        under an active FaultPolicy the overlapped dispatch draws the same
        fault grid, cell for cell, as the fused dispatch of the same
        admission wave."""
        launched = []
        lo = 0
        for w, g in entries:
            pending = w.router.begin_route(
                g.payloads, g.emb, g.budgets, mode=g.mode,
                speculation_threshold=self.speculation_threshold,
                fault_row_offset=lo,
            )
            launched.append((w, g, pending))
            lo += g.n
        self.overlapped_dispatches += len(entries)
        self.overlapped_rows += lo
        for w, g, pending in launched:
            w.sched._adopt(pending, g)

    def _launch_fused(self, entries: List[tuple]) -> None:
        w0: ReplicaWorker = entries[0][0]
        payloads = BatchScheduler._cat_payloads([g.payloads for _, g in entries])
        emb = np.concatenate([g.emb for _, g in entries])
        budgets = np.concatenate([g.budgets for _, g in entries])
        ctx = (
            jax.default_device(w0.device)
            if w0.device is not None else contextlib.nullcontext()
        )
        with ctx:
            pending = w0.router.begin_route(
                payloads, emb, budgets, mode=entries[0][1].mode,
                speculation_threshold=self.speculation_threshold,
            )
        self.fused_dispatches += 1
        self.fused_rows += int(budgets.shape[0])
        L = len(w0.router.engine.arms)
        lo = 0
        for w, g in entries:
            hi = lo + g.n
            w.sched._adopt(_RouteView(pending, lo, hi, L), g)
            lo = hi

    def pump(self) -> int:
        """Non-blocking progress across all replicas: retire every group
        whose device work finished, gang-dispatch every due worker
        (fusing same-budget groups), prefetch plans for queued work."""
        done = 0
        while True:
            for w in self.workers:
                s = w.sched
                while s._inflight and s._inflight[0].pending.ready():
                    done += s._retire(s._inflight.popleft())
            due = [w for w in self.workers if w.sched.ready()]
            if not due:
                break
            for w in due:
                s = w.sched
                if len(s._inflight) >= s.max_inflight:
                    done += s._retire(s._inflight.popleft())
            self._dispatch(due)
        for w in self.workers:
            if w.sched._queue:
                w.sched._prefetch()
        return done

    def drain(self) -> int:
        """Run every replica's backlog dry (deadlines ignored). The fill
        pipelines / retire ONE head per worker cadence matches
        :meth:`BatchScheduler.drain` exactly — with a shared ledger, the
        interleaving of settlements between admissions is part of the R=1
        equivalence contract (each settle releases reserved headroom, so a
        different retire order admits a different row set near a cap)."""
        done = 0
        while any(w.sched._queue or w.sched._inflight for w in self.workers):
            while True:
                due = [
                    w for w in self.workers
                    if w.sched._queue
                    and len(w.sched._inflight) < w.sched.max_inflight
                ]
                if not due:
                    break
                self._dispatch(due)
            for w in self.workers:
                s = w.sched
                if s._inflight:
                    done += s._retire(s._inflight.popleft())
        return done

    def _force(self, fut) -> None:
        """BlockFuture.result() entry point for set-level blocks."""
        if not fut.done():
            self.drain()

    def reconcile_ledger(self) -> int:
        """Set-wide restart reconciliation of the shared ledger: release
        every id-tracked reservation no worker's queue or flight holds
        (see :meth:`BatchScheduler.reconcile_ledger`). One ledger pass —
        the live set is the union across workers."""
        if self.ledger is None:
            return 0
        live: List[int] = []
        for w in self.workers:
            for seg in w.sched._queue:
                if seg.ids is not None:
                    live.extend(np.asarray(seg.ids, np.int64).ravel().tolist())
            for group in w.sched._inflight:
                if group.ids is not None:
                    live.extend(np.asarray(group.ids, np.int64).ravel().tolist())
        return self.ledger.release_orphans(live)

    # ------------------------------------------------------------------
    # Aggregated observability
    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, float]:
        """Set-wide counters: scheduler-core counters summed across
        workers; shared subsystems (plan cache, ledger) counted once;
        per-worker feedback/degradation counters summed (the central log
        contributes the fold counters). With R=1 this equals
        ``BatchScheduler.stats`` key for key, plus the ``replica_*``
        group."""
        out: Dict[str, float] = {k: 0 for k in _CORE_STATS}
        for w in self.workers:
            for k in _CORE_STATS:
                out[k] += w.sched._stats[k]
        out.update(self.plans.stats())
        if self.feedback is not None:
            fb: Dict[str, float] = {}
            for log in [self.feedback] + [w.sched.feedback for w in self.workers]:
                for k, v in log.stats().items():
                    fb[k] = fb.get(k, 0) + v
            out.update(fb)
            deg: Dict[str, float] = {}
            for w in self.workers:
                for k, v in w.sched.degradation.stats().items():
                    deg[k] = deg.get(k, 0) + v
            out.update(deg)
        if self.ledger is not None:
            out.update(self.ledger.stats())
        out["replicas"] = self.replicas
        out["replica_spills"] = self.spills
        out["replica_fused"] = self.fused_dispatches
        out["replica_fused_rows"] = self.fused_rows
        out["replica_devices"] = self.device_count
        out["replica_overlapped"] = self.overlapped_dispatches
        out["replica_overlapped_rows"] = self.overlapped_rows
        return out

    @property
    def arm_query_totals(self) -> np.ndarray:
        out = np.zeros_like(self.workers[0].sched.arm_query_totals)
        for w in self.workers:
            out += w.sched.arm_query_totals
        return out

    def latency_stats(self) -> Dict[str, float]:
        """Completion-latency summary pooled across every replica."""
        arrs = []
        count = 0
        for w in self.workers:
            count += int(w.sched._stats["completed"])
            if w.sched._latencies:
                w.sched._trim_latencies()
                arrs.append(w.sched._latencies[0])
        if not arrs:
            return {"count": 0}
        lat = np.concatenate(arrs)
        return {
            "count": count,
            "p50_s": float(np.percentile(lat, 50)),
            "p99_s": float(np.percentile(lat, 99)),
            "mean_s": float(lat.mean()),
            "max_s": float(lat.max()),
        }

    def stragglers(self) -> List[int]:
        """Arms any replica's mitigator currently flags."""
        out = set()
        for w in self.workers:
            out.update(w.sched.mitigator.stragglers())
        return sorted(out)

    # ------------------------------------------------------------------
    # Warmup
    # ------------------------------------------------------------------
    def prewarm(self, budgets: Optional[List[float]] = None) -> int:
        """Build wave plans ahead of traffic (once — the PlanService is
        shared, so every replica reads the same warm cache)."""
        return self.plans.prewarm(budgets=budgets)

    def prewarm_compile(self, max_waves: Optional[int] = None,
                        all_batch_buckets: bool = False) -> int:
        """Compile the wave-program buckets serving traffic will hit: the
        per-worker admission size, plus — under fusion — the fused batch
        bucket (R workers' admissions concatenated). The jit cache holds
        one executable per (bucket, device), so overlapped placement warms
        every distinct pinned device (via each worker's own router);
        single-device placements warm each bucket once through the shared
        module-level cache. Overlapped dispatches are per (worker,
        budget-group) — raggedness is intrinsic, not a flush corner case —
        so that branch always warms every batch bucket up to the admission
        size."""
        s0 = self.workers[0].sched
        per = s0.max_batch * s0.coalesce
        if self.placement == "overlapped":
            n = 0
            seen = set()
            for w in self.workers:
                key = str(w.router.device)
                if key in seen:
                    continue
                seen.add(key)
                n += w.router.prewarm_compile(
                    per, max_waves=max_waves, all_batch_buckets=True,
                )
            return n
        n = self.router.prewarm_compile(
            per, max_waves=max_waves, all_batch_buckets=all_batch_buckets
        )
        if self.fuse_waves and self.replicas > 1:
            n += self.router.prewarm_compile(
                per * self.replicas, max_waves=max_waves,
                all_batch_buckets=False,
            )
        return n

    def next_deadline(self) -> Optional[float]:
        """Earliest admission deadline across replicas (None when idle)."""
        deadlines = [
            d for d in (w.sched.next_deadline() for w in self.workers)
            if d is not None
        ]
        return min(deadlines) if deadlines else None

    def ready(self) -> bool:
        return any(w.sched.ready() for w in self.workers)
