"""Online estimation feedback from served traffic.

The paper estimates per-(cluster, arm) success probabilities once, offline
(Sec. 3.1). In production the estimates drift — FrugalGPT and MetaLLM (see
PAPERS.md) both show router quality degrading as per-model accuracy moves
and recovering under online reward feedback. This module closes that loop
for the serving stack:

* **FeedbackLog.observe** — the scheduler registers every served request at
  completion, keyed by request id: its cluster plus the (arm, response)
  pairs of the waves that actually ran. Predictions come for free from the
  request futures; ground truth arrives later, asynchronously.
* **record / record_many** — a ground-truth label arrives for a request id.
  The label is matched against the stored responses, giving one per-arm
  correctness row for *invoked* arms only, which accumulates into
  per-(cluster, arm) success/attempt count buffers. Nothing touches the
  estimator yet — labels can arrive mid-wave without perturbing routing.
* **apply** — called by the scheduler at admission boundaries (never
  mid-wave): buffered counts fold into the estimator as one vectorized
  :meth:`~repro.core.estimation.SuccessProbEstimator.update_counts` call
  per touched cluster, bumping the strictly monotone estimator ``version``.

**Drift gating.** A fold only invalidates a cluster's cached plans when the
estimate *actually moved*: the candidate post-fold estimate is compared
per-arm against the plan-visible snapshot (the estimate the current plans
were built from) with a Wilson interval-overlap test (reusing
:func:`~repro.core.estimation.wilson_interval`). Disjoint intervals on any
observed arm ⇒ drift ⇒ the fold is plan-visible (the cluster's plan
``version`` bumps and the PlanService's version-keyed caches miss lazily).
Overlapping intervals ⇒ confirming feedback ⇒ the fold still tightens the
estimate but the plan version stays put, so hot-path plan cache hits
survive.

**Exploration probes.** Once a plan stops invoking an arm, served traffic
yields no more feedback for it, so a *recovered* arm would never re-enter
the estimates. ``FeedbackLog(probe_rate=r)`` closes that loop minimally:
the scheduler marks ~``r`` of feedback-eligible requests and invokes ONE
currently-unplanned arm (the least-observed one for the request's cluster)
as a side channel — the probe response never touches routing or the
request's prediction, but when the ground-truth label arrives it feeds the
probed arm's (cluster, arm) counts exactly like a planned wave, so a
recovered arm's estimate climbs until the drift test re-selects it. Off by
default (``probe_rate=0``), in which case the zero-label path stays
bit-identical to feedback without probing.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.estimation import (
    SuccessProbEstimator,
    fold_counts,
    wilson_interval,
)


@dataclasses.dataclass
class FeedbackReport:
    """What one admission-boundary :meth:`FeedbackLog.apply` folded in."""

    labels: int = 0                     # labeled requests folded
    clusters: Tuple[int, ...] = ()      # clusters that received feedback
    drifted: Tuple[int, ...] = ()       # subset whose plans invalidated
    version: int = 0                    # estimator version after the fold


@dataclasses.dataclass
class FeedbackShard:
    """A replica-local slice of buffered feedback counts.

    The shard is exactly the :class:`FeedbackLog` pending-buffer shape —
    ``cluster -> [successes (L,), attempts (L,), labeled queries]`` plus the
    total labeled-request count — detached from any log. Because every
    entry is a pure monotone sum of unit increments (integer-valued
    floats, exact far below 2**53), shards merge by plain addition:
    :func:`merge_counts` is associative and commutative bit-for-bit, and
    *any* partition of a label stream across R shards folds back to the
    single-log totals. That is the whole multi-replica feedback contract —
    replicas fold locally, the control plane adds shards at admission
    boundaries, and one central :meth:`FeedbackLog.apply` reproduces the
    single-log estimator state and replan set exactly.
    """

    counts: Dict[int, List]             # cid -> [succ (L,), att (L,), nq]
    labels: int = 0                     # labeled requests in the shard

    @property
    def empty(self) -> bool:
        return not self.counts

    def copy(self) -> "FeedbackShard":
        return FeedbackShard(
            {cid: [b[0].copy(), b[1].copy(), b[2]]
             for cid, b in self.counts.items()},
            self.labels,
        )


def merge_counts(*shards: FeedbackShard) -> FeedbackShard:
    """Add feedback shards: elementwise (success, attempt, query) sums per
    cluster. Exact — counts are integer-valued — hence associative,
    commutative and partition-invariant (the property suite in
    ``tests/test_replica_merge.py`` pins all three)."""
    out: Dict[int, List] = {}
    labels = 0
    for shard in shards:
        labels += shard.labels
        for cid, (succ, att, nq) in shard.counts.items():
            buf = out.get(cid)
            if buf is None:
                out[cid] = [succ.copy(), att.copy(), int(nq)]
            else:
                buf[0] += succ
                buf[1] += att
                buf[2] += int(nq)
    return FeedbackShard(out, labels)


class FeedbackLog:
    """Asynchronous ground-truth feedback, keyed by request id.

    Owned by a :class:`~repro.serving.scheduler.BatchScheduler` (pass
    ``feedback=True``) or constructed standalone and shared across
    schedulers bound to the same estimator.

    Args:
      estimator: the :class:`SuccessProbEstimator` to stream feedback into.
      delta: interval failure target for the refreshed Hoeffding CIs.
      drift_delta: failure target of the Wilson intervals in the drift
        test — smaller widens the intervals, making the detector *less*
        trigger-happy (more feedback needed before plans re-select).
      max_watch: outcome-retention window: only the newest ``max_watch``
        observed requests are retained — older unlabeled outcomes are
        evicted, and already-labeled ids age out of the bookkeeping too,
        so memory stays bounded whether or not labels ever arrive.
      probe_rate: exploration probability — the fraction of
        feedback-eligible requests for which the scheduler additionally
        invokes one currently-unplanned arm so recovered arms can re-enter
        the estimates. 0 (default) disables probing entirely (no rng is
        consumed; the zero-label path is bit-identical).
      probe_seed: seed of the probe-thinning rng.

    A :class:`DegradationTracker` may additionally stream *failure*
    evidence (timeouts/errors — attempts that can never be correct) into
    the same pending buffers, so flaky arms ride the identical
    fold → Wilson-gate → replan path that label feedback does.
    """

    def __init__(
        self,
        estimator: SuccessProbEstimator,
        delta: float = 0.01,
        drift_delta: float = 0.05,
        max_watch: int = 1 << 20,
        probe_rate: float = 0.0,
        probe_seed: int = 0,
    ):
        self.estimator = estimator
        self.delta = float(delta)
        self.drift_delta = float(drift_delta)
        self.max_watch = int(max_watch)
        self.probe_rate = float(probe_rate)
        self.probe_seed = int(probe_seed)
        self._probe_rng = np.random.default_rng(probe_seed)
        self.probes = 0          # exploration invocations registered
        # request-id authority: schedulers bound to this log draw ids here,
        # so sharing one log across schedulers can never collide keys
        self._next_id = 0
        # request id -> (block id, row); blocks hold whole retired-group
        # matrices (columnar, no per-request slicing on the retire path)
        self._watch: Dict[int, Tuple[int, int]] = {}
        self._watch_order: Deque[int] = collections.deque()
        # block id -> [clusters (B,), schedule (B,T), responses (B,T),
        #              invoked (B,T), live row refcount]
        self._blocks: Dict[int, List] = {}
        self._next_block = 0
        # cluster -> [successes (L,), attempts (L,), labeled queries]
        self._pending: Dict[int, List] = {}
        self._pending_labels = 0
        self.labels = 0          # labels matched to a watched request
        self.unmatched = 0       # labels for unknown/evicted/duplicate ids
        self.evicted = 0         # watched outcomes dropped by max_watch
        self.applies = 0         # admission-boundary folds that did work
        self.drifts = 0          # cluster-folds that invalidated plans

    def next_ids(self, n: int) -> np.ndarray:
        """Reserve ``n`` fresh request ids. The log is the id authority so
        that multiple schedulers sharing it stay collision-free."""
        start = self._next_id
        self._next_id += int(n)
        return np.arange(start, start + n, dtype=np.int64)

    # ------------------------------------------------------------------
    # Serving-side registration
    # ------------------------------------------------------------------
    def probe_rows(self, n: int) -> np.ndarray:
        """Thin a retired group of ``n`` requests down to the rows to probe.
        With ``probe_rate == 0`` returns empty without consuming the rng."""
        if self.probe_rate <= 0.0 or n == 0:
            return np.zeros(0, np.int64)
        return np.flatnonzero(self._probe_rng.random(n) < self.probe_rate)

    def probe_arms(self, clusters: np.ndarray, schedule: np.ndarray) -> np.ndarray:
        """Pick the exploration arm per probed request: the least-observed
        arm the request's plan did NOT schedule (ties to the lowest index;
        -1 when the plan already covers the whole pool). Least-observed
        targets exactly the arms whose estimates have gone blind — the
        recovered-arm case the ROADMAP left open."""
        L = self.estimator.num_arms
        out = np.full(len(clusters), -1, np.int64)
        for i, (cid, sched) in enumerate(zip(clusters, schedule)):
            planned = np.zeros(L, bool)
            planned[sched[sched >= 0]] = True
            cand = np.flatnonzero(~planned)
            if cand.size == 0:
                continue
            counts = self.estimator.clusters[int(cid)].arm_counts
            out[i] = int(cand[np.argmin(counts[cand])])
        return out

    def observe(
        self,
        ids: np.ndarray,            # (B,) request ids
        clusters: np.ndarray,       # (B,)
        schedule: np.ndarray,       # (B, T) arm id per wave, -1 = none
        responses: np.ndarray,      # (B, T) class id per wave, -1 = not run
        invoked: np.ndarray,        # (B, T) wave actually ran
        probes=None,                # optional (rows, arms, responses)
    ) -> None:
        """Register a retired group's outcomes to await ground truth.

        Columnar: the group's (schedule, responses, invoked) matrices are
        stored whole (one block, no per-request slicing on the retire
        path); a request's invoked-arm rows are extracted lazily when its
        label arrives — feedback stays masked to invoked arms, matching
        what a real deployment can observe. Never touches the estimator or
        any rng, so enabling feedback with zero labels is
        routing-identical to feedback disabled.

        ``probes`` carries the scheduler's exploration side channel: the
        probed rows' extra (arm, response) pairs land as one appended wave
        column, so a later label scores the probed arm exactly like a
        planned wave.
        """
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        if probes is not None:
            rows, arms, resp = probes
            rows = np.asarray(rows, np.int64)
            if rows.size:
                B = schedule.shape[0]
                schedule = np.concatenate(
                    [schedule, np.full((B, 1), -1, schedule.dtype)], axis=1
                )
                responses = np.concatenate(
                    [responses, np.full((B, 1), -1, responses.dtype)], axis=1
                )
                invoked = np.concatenate(
                    [invoked, np.zeros((B, 1), bool)], axis=1
                )
                schedule[rows, -1] = np.asarray(arms, np.int64)
                responses[rows, -1] = np.asarray(resp, np.int64)
                invoked[rows, -1] = True
                self.probes += int(rows.size)
        bid = self._next_block
        self._next_block += 1
        self._blocks[bid] = [
            np.asarray(clusters, np.int64), schedule, responses, invoked,
            int(ids.size),
        ]
        watch, order = self._watch, self._watch_order
        for i, rid in enumerate(ids.tolist()):
            watch[rid] = (bid, i)
            order.append(rid)
        # retention: the deque (not the dict) is the bounded object, so
        # ids whose labels already arrived are trimmed too — a healthily
        # labeled long-running server can't leak bookkeeping
        while len(order) > self.max_watch:
            self._evict(order.popleft())

    def _evict(self, rid: int) -> None:
        ent = self._watch.pop(rid, None)
        if ent is not None:
            self.evicted += 1
            self._release_block(ent[0])

    def _release_block(self, bid: int, rows: int = 1) -> None:
        blk = self._blocks[bid]
        blk[4] -= rows
        if blk[4] == 0:          # last live row gone: free the matrices
            del self._blocks[bid]

    @property
    def watching(self) -> int:
        """Completed requests currently awaiting a label."""
        return len(self._watch)

    @property
    def pending(self) -> int:
        """Labeled requests buffered for the next admission-boundary fold."""
        return self._pending_labels

    @property
    def has_pending(self) -> bool:
        """Anything buffered for the next fold — labeled requests *or*
        failure evidence from a :class:`DegradationTracker` (which carries
        attempts but no labels)."""
        return bool(self._pending)

    # ------------------------------------------------------------------
    # Label arrival
    # ------------------------------------------------------------------
    def record(self, request_id: int, label: int) -> bool:
        """Ground truth arrived for a served request; returns True if the
        id matched a watched outcome. Buffers per-(cluster, arm) counts;
        the estimator is only touched at the next :meth:`apply`."""
        return self.record_many([request_id], [label]) == 1

    def _buf(self, cid: int) -> List:
        buf = self._pending.get(cid)
        if buf is None:
            L = self.estimator.num_arms
            buf = self._pending[cid] = [
                np.zeros(L, np.float64), np.zeros(L, np.float64), 0,
            ]
        return buf

    def record_many(self, request_ids, labels) -> int:
        """Batch label ingestion; returns how many ids matched.

        Columnar like the rest of the serving stack: ids resolve to
        (block, row) via one dict pop each, then every block's matched
        rows accumulate into the per-(cluster, arm) buffers with a few
        scatter-adds — no per-request numpy work."""
        ids = np.asarray(request_ids, np.int64).ravel()
        labs = np.asarray(labels, np.int64).ravel()
        by_block: Dict[int, Tuple[List[int], List[int]]] = {}
        matched = 0
        for rid, lab in zip(ids.tolist(), labs.tolist()):
            ent = self._watch.pop(rid, None)
            if ent is None:
                self.unmatched += 1
                continue
            matched += 1
            rows, row_labs = by_block.setdefault(ent[0], ([], []))
            rows.append(ent[1])
            row_labs.append(lab)
        for bid, (rows, row_labs) in by_block.items():
            clusters, schedule, responses, invoked, _ = self._blocks[bid]
            rows = np.asarray(rows, np.int64)
            row_labs = np.asarray(row_labs, np.int64)
            mask = invoked[rows]                                  # (k, T)
            correct = (responses[rows] == row_labs[:, None]) & mask
            cl = clusters[rows]
            for cid in np.unique(cl):
                sel = cl == cid
                m = mask[sel]
                arms = schedule[rows[sel]][m]
                buf = self._buf(int(cid))
                # arms repeat across requests: scatter-add, not fancy +=
                np.add.at(buf[0], arms, correct[sel][m].astype(np.float64))
                np.add.at(buf[1], arms, 1.0)
                buf[2] += int(sel.sum())
            self._release_block(bid, rows.size)
        self._pending_labels += matched
        self.labels += matched
        return matched

    # ------------------------------------------------------------------
    # Cross-replica shard plumbing (see serving/replica.py)
    # ------------------------------------------------------------------
    def export_shard(self) -> FeedbackShard:
        """Drain the pending buffers into a detached :class:`FeedbackShard`.

        A replica-local log calls this at admission boundaries so the
        control plane can :func:`merge_counts` every replica's evidence and
        fold it through ONE central :meth:`apply`. The buffers leave empty
        (the counts now live in the shard)."""
        shard = FeedbackShard(self._pending, self._pending_labels)
        self._pending = {}
        self._pending_labels = 0
        return shard

    def absorb_shard(self, shard: FeedbackShard) -> None:
        """Add a (merged) shard's counts into this log's pending buffers —
        the inverse of :meth:`export_shard`. The next :meth:`apply` folds
        them exactly as if the labels had been recorded here."""
        for cid, (succ, att, nq) in shard.counts.items():
            buf = self._buf(int(cid))
            buf[0] += succ
            buf[1] += att
            buf[2] += int(nq)
        self._pending_labels += int(shard.labels)

    # ------------------------------------------------------------------
    # Admission-boundary fold
    # ------------------------------------------------------------------
    def _moved(self, st, cand_p: np.ndarray, cand_counts: np.ndarray,
               observed: np.ndarray) -> bool:
        """Interval-overlap drift test: did the estimate actually move?

        Compares the candidate post-fold estimate against the *plan-visible
        snapshot* (what the cached plans were built from), per arm, at each
        side's own counts. Disjoint Wilson intervals on any arm the feedback
        observed ⇒ drift. Comparing against the snapshot (not the previous
        fold) means slow drift still accumulates to a detection instead of
        hiding inside per-batch noise.
        """
        lo_old, hi_old = wilson_interval(
            st.plan_p_hat, st.plan_arm_counts, self.drift_delta
        )
        lo_new, hi_new = wilson_interval(cand_p, cand_counts, self.drift_delta)
        disjoint = (lo_new > hi_old) | (hi_new < lo_old)
        return bool((disjoint & observed).any())

    def apply(self) -> FeedbackReport:
        """Fold buffered feedback into the estimator — one vectorized
        ``update_counts`` per touched cluster, drift-gated plan visibility.

        Called by the scheduler at admission boundaries (never mid-wave),
        so every query of a batch routes against one consistent estimator
        version. A no-op (empty report) when nothing is buffered.
        """
        if not self._pending:
            return FeedbackReport(version=self.estimator.version)
        est = self.estimator
        touched, drifted = [], []
        labels = self._pending_labels
        for cid in sorted(self._pending):
            succ, att, nq = self._pending[cid]
            st = est.clusters[cid]
            observed = att > 0
            # the exact fold update_counts will commit, pre-computed (via
            # the shared fold_counts) so the drift decision sees it first
            cand_p, cand_counts = fold_counts(st.p_hat, st.arm_counts, succ, att)
            moved = self._moved(st, cand_p, cand_counts, observed)
            est.update_counts(
                cid, succ, att, queries=nq, delta=self.delta,
                plan_visible=moved,
            )
            touched.append(cid)
            if moved:
                drifted.append(cid)
        self._pending.clear()
        self._pending_labels = 0
        self.applies += 1
        self.drifts += len(drifted)
        return FeedbackReport(
            labels=labels,
            clusters=tuple(touched),
            drifted=tuple(drifted),
            version=est.version,
        )

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        """Feedback counters (mirrored into ``BatchScheduler.stats``)."""
        return {
            "feedback_labels": self.labels,
            "feedback_unmatched": self.unmatched,
            "feedback_pending": self._pending_labels,
            "feedback_watching": len(self._watch),
            "feedback_evicted": self.evicted,
            "feedback_applies": self.applies,
            "feedback_drifts": self.drifts,
            "feedback_probes": self.probes,
        }


class DegradationTracker:
    """Folds arm failure outcomes into the online estimator path.

    Timeouts and errors are invisible to the label path — a failed
    invocation yields no response to score when ground truth arrives — so a
    persistently failing arm would keep its (stale, healthy) estimate and
    keep being planned. This tracker turns each *attempted* failure from a
    :class:`~repro.serving.router.RouteResult`'s fault evidence into a
    per-(cluster, arm) zero-success attempt in the owning
    :class:`FeedbackLog`'s pending buffers. From there the evidence rides
    the existing machinery unchanged: the admission-boundary fold, the
    Wilson interval-overlap drift gate, versioned lazy plan invalidation
    and the batched replan — a flaky arm's success estimate collapses, the
    gate fires for exactly the clusters that observed the failures, plans
    route around it, and ``FeedbackLog`` probes readmit it once it
    recovers.

    Silent degradation needs no extra plumbing here: a degraded arm *does*
    answer (with a corrupted class), so its responses flow through
    ``observe``/``record_many`` and arriving labels mark them wrong — the
    same drift gate fires on the label evidence. The tracker only counts
    degraded cells for observability.
    """

    def __init__(self, feedback: FeedbackLog):
        self.feedback = feedback
        L = feedback.estimator.num_arms
        self.failures = 0        # attempted timeout/error invocations folded
        self.degraded = 0        # silently-degraded responses served
        self.routes = 0          # fault-bearing RouteResults ingested
        self.arm_failures = np.zeros(L, np.int64)

    def record_route(self, clusters: np.ndarray, fault_schedule: np.ndarray,
                     fault_codes: np.ndarray) -> int:
        """Ingest one RouteResult's fault evidence ((B, T) matrices over the
        *original* plan positions). Returns the failures folded."""
        from repro.distributed.fault import FAULT_DEGRADE, FAULT_ERROR, FAULT_TIMEOUT

        if fault_codes is None:
            return 0
        failed = (fault_codes == FAULT_TIMEOUT) | (fault_codes == FAULT_ERROR)
        ndeg = int((fault_codes == FAULT_DEGRADE).sum())
        self.degraded += ndeg
        nf = int(failed.sum())
        if nf or ndeg:
            self.routes += 1
        if nf == 0:
            return 0
        hit_rows = failed.any(axis=1)
        cl = np.asarray(clusters, np.int64)
        for cid in np.unique(cl[hit_rows]):
            sel = cl == cid
            arms = fault_schedule[sel][failed[sel]]
            # attempts with zero successes; buf[2] (labeled-query count)
            # stays put — failures are not labels
            np.add.at(self.feedback._buf(int(cid))[1], arms, 1.0)
        self.arm_failures += np.bincount(
            fault_schedule[failed], minlength=self.arm_failures.size
        )
        self.failures += nf
        return nf

    def record_failures(self, clusters: np.ndarray, arms: np.ndarray) -> int:
        """Ingest flat (cluster, arm) failure pairs — the probe side channel
        (a probe whose arm failed yields no response to watch)."""
        arms = np.asarray(arms, np.int64)
        if arms.size == 0:
            return 0
        cl = np.asarray(clusters, np.int64)
        for cid in np.unique(cl):
            np.add.at(
                self.feedback._buf(int(cid))[1], arms[cl == cid], 1.0
            )
        self.arm_failures += np.bincount(arms, minlength=self.arm_failures.size)
        self.failures += int(arms.size)
        self.routes += 1
        return int(arms.size)

    def stats(self) -> Dict[str, int]:
        return {
            "degradation_failures": self.failures,
            "degradation_degraded": self.degraded,
            "degradation_routes": self.routes,
        }
