"""Serving runtime: arm engine, ThriftLLM router, plan service, scheduler,
online estimation feedback, fault injection + degradation tracking."""
from repro.distributed.fault import ArmFaultSpec, FaultPolicy

from .compile_cache import cache_supported, configure_compile_cache
from .engine import LMArm, OracleArm, PoolEngine, USD_PER_FLOP
from .feedback import (
    DegradationTracker,
    FeedbackLog,
    FeedbackReport,
    FeedbackShard,
    merge_counts,
)
from .plans import GroupPlan, PlanService
from .replica import ReplicaSet, ReplicaWorker
from .router import PendingRoute, RouteResult, ThriftRouter
from .scheduler import (
    BatchScheduler,
    BlockFuture,
    CostLedger,
    Request,
    RequestFuture,
    RequestResult,
)

__all__ = [
    "LMArm", "OracleArm", "PoolEngine", "USD_PER_FLOP",
    "FeedbackLog", "FeedbackReport", "DegradationTracker",
    "FeedbackShard", "merge_counts",
    "GroupPlan", "PlanService",
    "ThriftRouter", "RouteResult", "PendingRoute",
    "BatchScheduler", "Request", "RequestFuture", "RequestResult",
    "BlockFuture", "CostLedger",
    "ReplicaSet", "ReplicaWorker",
    "ArmFaultSpec", "FaultPolicy",
    "configure_compile_cache", "cache_supported",
]
