"""Serving runtime: arm engine, ThriftLLM router, plan service, scheduler."""
from .engine import LMArm, OracleArm, PoolEngine, USD_PER_FLOP
from .plans import GroupPlan, PlanService
from .router import RouteResult, ThriftRouter
from .scheduler import BatchScheduler, Request

__all__ = [
    "LMArm", "OracleArm", "PoolEngine", "USD_PER_FLOP",
    "GroupPlan", "PlanService",
    "ThriftRouter", "RouteResult", "BatchScheduler", "Request",
]
