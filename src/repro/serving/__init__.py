"""Serving runtime: arm engine, ThriftLLM router, plan service, scheduler,
online estimation feedback."""
from .engine import LMArm, OracleArm, PoolEngine, USD_PER_FLOP
from .feedback import FeedbackLog, FeedbackReport
from .plans import GroupPlan, PlanService
from .router import PendingRoute, RouteResult, ThriftRouter
from .scheduler import (
    BatchScheduler,
    BlockFuture,
    Request,
    RequestFuture,
    RequestResult,
)

__all__ = [
    "LMArm", "OracleArm", "PoolEngine", "USD_PER_FLOP",
    "FeedbackLog", "FeedbackReport",
    "GroupPlan", "PlanService",
    "ThriftRouter", "RouteResult", "PendingRoute",
    "BatchScheduler", "Request", "RequestFuture", "RequestResult",
    "BlockFuture",
]
