"""Serving runtime: arm engine, ThriftLLM router, batch scheduler."""
from .engine import LMArm, OracleArm, PoolEngine, USD_PER_FLOP
from .router import RouteResult, ThriftRouter
from .scheduler import BatchScheduler, Request

__all__ = [
    "LMArm", "OracleArm", "PoolEngine", "USD_PER_FLOP",
    "ThriftRouter", "RouteResult", "BatchScheduler", "Request",
]
