"""Request batching scheduler with straggler hedging.

Requests accumulate until ``max_batch`` or ``max_wait_s``; each flushed
batch goes through the ThriftRouter. Per-arm latency estimates feed the
StragglerMitigator — slow arms are pushed to the tail of the invocation
wavefront, where Prop. 4 early-stopping most often makes them unnecessary.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.distributed.fault import StragglerMitigator


@dataclasses.dataclass
class Request:
    payload: Any
    embedding: np.ndarray
    budget: float
    arrival_s: float = dataclasses.field(default_factory=time.monotonic)


class BatchScheduler:
    def __init__(
        self,
        router,
        max_batch: int = 64,
        max_wait_s: float = 0.02,
    ):
        self.router = router
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._queue: List[Request] = []
        self.mitigator = StragglerMitigator(num_workers=len(router.engine.arms))
        self.stats: Dict[str, float] = {"batches": 0, "requests": 0}

    def submit(self, req: Request):
        self._queue.append(req)

    def ready(self) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        return time.monotonic() - self._queue[0].arrival_s >= self.max_wait_s

    def flush(self):
        """Route one batch (same-budget requests grouped together)."""
        if not self._queue:
            return []
        batch = self._queue[: self.max_batch]
        self._queue = self._queue[self.max_batch :]
        out = []
        budgets = sorted(set(r.budget for r in batch))
        for b in budgets:
            group = [r for r in batch if r.budget == b]
            payloads = [r.payload for r in group]
            embs = np.stack([r.embedding for r in group])
            res = self.router.route_batch(payloads, embs, b)
            lat = [a.latency_s(len(group)) for a in self.router.engine.arms]
            self.mitigator.record_step(lat)
            out.append((group, res))
        self.stats["batches"] += 1
        self.stats["requests"] += len(batch)
        return out
