"""Request batching scheduler with straggler hedging.

Requests accumulate until ``max_batch`` or ``max_wait_s``; each flushed
batch goes through the ThriftRouter. Per-arm latency estimates feed the
StragglerMitigator — slow arms are pushed to the tail of the invocation
wavefront, where Prop. 4 early-stopping most often makes them unnecessary.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.distributed.fault import StragglerMitigator


@dataclasses.dataclass
class Request:
    payload: Any
    embedding: np.ndarray
    budget: float
    arrival_s: float = dataclasses.field(default_factory=time.monotonic)


class BatchScheduler:
    def __init__(
        self,
        router,
        max_batch: int = 64,
        max_wait_s: float = 0.02,
    ):
        self.router = router
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._queue: List[Request] = []
        self.mitigator = StragglerMitigator(num_workers=len(router.engine.arms))
        self.stats: Dict[str, float] = {"batches": 0, "requests": 0, "flushes": 0}
        self._sync_plan_stats()

    def _sync_plan_stats(self):
        """Mirror the router's PlanService counters into ``stats`` so the
        serving control plane sees plan-cache hit/miss/invalidation rates
        without reaching into router internals."""
        plans = getattr(self.router, "plans", None)
        if plans is not None:
            self.stats.update(plans.stats())

    def prewarm(self, budgets: Optional[List[float]] = None) -> int:
        """Precompute wave plans ahead of traffic (delegates to the
        router's PlanService): with ``budgets``, plan every known cluster at
        each budget; without, re-plan the hottest observed pairs. Returns
        the number of plans built."""
        plans = getattr(self.router, "plans", None)
        if plans is None:
            return 0
        built = plans.prewarm(budgets=budgets)
        self._sync_plan_stats()
        return built

    def submit(self, req: Request):
        self._queue.append(req)

    def ready(self) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        return time.monotonic() - self._queue[0].arrival_s >= self.max_wait_s

    def flush(self):
        """Route one batch; heterogeneous budgets ride one wave schedule.

        The router handles (cluster, budget) grouping internally, so the
        whole flush is a single ``route_batch`` call. Accounting:
        ``stats["batches"]`` counts the budget groups actually routed, and
        the StragglerMitigator only sees the latency of arms the wavefront
        really invoked (``RouteResult.arm_query_counts``) — idle arms record
        zero work instead of a phantom full-batch latency.
        """
        if not self._queue:
            return []
        batch = self._queue[: self.max_batch]
        self._queue = self._queue[self.max_batch :]
        payloads = [r.payload for r in batch]
        embs = np.stack([r.embedding for r in batch])
        budgets = np.asarray([r.budget for r in batch], np.float64)
        res = self.router.route_batch(payloads, embs, budgets)
        lat = [
            arm.latency_s(int(n)) if n else 0.0
            for arm, n in zip(self.router.engine.arms, res.arm_query_counts)
        ]
        self.mitigator.record_step(lat)
        self.stats["batches"] += len(np.unique(budgets))
        self.stats["flushes"] += 1
        self.stats["requests"] += len(batch)
        self._sync_plan_stats()
        return [(batch, res)]
