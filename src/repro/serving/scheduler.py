"""Continuous-batching serving front-end with cost-aware speculation.

The PR 2 scheduler was a one-shot flush loop: requests accumulated until
``max_batch``/``max_wait_s`` and each flush blocked on one ``route_batch``
call. This module turns it into a streaming front-end shaped like the
serving systems the paper's setting implies (FrugalGPT's cascade server,
OptLLM's per-query assignment — see PAPERS.md):

* **Admission queue** — ``submit``/``submit_many`` enqueue requests (block
  submission is columnar: one segment of arrays, no per-request object
  churn on the hot path) and return completion futures. The flush policy is
  arrival-time and SLO-aware: a batch is admitted when it fills
  ``max_batch``, when the oldest request has waited ``max_wait_s``, or when
  a request's ``slo_s`` deadline (minus the dispatch margin) comes due —
  whichever is earliest.
* **Pipelined budget-group waves** — each admitted batch splits into its
  budget groups and every group is dispatched through
  :meth:`ThriftRouter.begin_route`, which returns a :class:`PendingRoute`
  *before* the device program finishes. Up to ``max_inflight`` groups ride
  in flight at once (double-buffered by default): group *t+1*'s planning
  and speculative gather run while group *t*'s jitted wave program is still
  executing, and retirement prefers groups whose device work already
  finished.
* **Per-request completion futures** — callers hold a
  :class:`RequestFuture` (or a columnar :class:`BlockFuture`) instead of
  waiting for a batch return. Reference-mode groups are stepped wave by
  wave and each query's future completes as its Prop. 4 stop wave fires;
  jitted groups complete when their single fused program lands. Results
  carry per-request latency, realized cost, stop wave and the data-plane
  mode that served them.
* **Cost-aware speculation switch** — ``speculation="auto"`` (default)
  lets every group pick its data plane: the speculative jitted wave loop
  when the scheduled arms' marginal metered invocation cost
  (:meth:`ThriftRouter.speculation_cost`) is at most
  ``speculation_threshold``, the compacting ``route_batch_reference`` plane
  otherwise. Oracle/tabular/self-hosted pools therefore always jit;
  metered API pools never pay for speculatively gathered waves the stop
  rule would have cancelled. This closes the ROADMAP's "speculate only
  when arm invocation is cheap" item.
* **Plan prefetch keyed by queue composition** — while the queue is
  filling (admission deadline not yet due), the scheduler snapshots the
  queued (cluster, budget) composition and asks the PlanService to build
  any missing wave plans (:meth:`PlanService.prefetch_for`), so selection
  latency is paid before the flush instead of on it. (A feedback fold at
  the next admission can obsolete a prefetched plan for a *drifted*
  cluster — the price of replanning, not a correctness issue.)
* **Online estimation feedback** — with ``feedback=True`` the scheduler
  registers every completed request's (cluster, invoked arms, responses)
  in a :class:`~repro.serving.feedback.FeedbackLog`; ground truth reported
  later via :meth:`BatchScheduler.record_outcome` buffers per-(cluster,
  arm) success counts, which fold into the estimator at admission
  boundaries (never mid-wave), bump the estimator version, and — only for
  clusters whose estimates actually drifted (Wilson interval-overlap
  test) — lazily invalidate the version-keyed plan caches.

The PR 2 one-shot API survives unchanged: ``flush()`` admits one batch,
routes it synchronously as a single heterogeneous-budget call and returns
``[(requests, RouteResult)]``; per-arm latency accounting still feeds the
StragglerMitigator exactly as before.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.fault import (
    FAULT_DEGRADE,
    FAULT_ERROR,
    FAULT_TIMEOUT,
    StragglerMitigator,
)

from .feedback import DegradationTracker, FeedbackLog, FeedbackReport


@dataclasses.dataclass
class Request:
    payload: Any
    embedding: np.ndarray
    budget: float
    arrival_s: float = dataclasses.field(default_factory=time.monotonic)
    slo_s: Optional[float] = None    # target completion deadline (rel. arrival)
    tenant: str = "default"          # cost-ledger accounting principal


class CostLedger:
    """Per-tenant spend accounting with hard budget enforcement.

    Reservation/settlement discipline: at *admission* the scheduler
    reserves each request's budget — the spend ceiling, since SurGreedy
    never selects past it (``planned_costs <= budgets`` by construction,
    and in-wave failover only ever re-routes to arms already inside the
    selected set). At *retire* the realized charge settles (attributed per
    arm from the effective post-failover schedule) and the reservation is
    released. ``spent + reserved <= limit`` therefore holds at every
    instant for every tenant — the hard-budget invariant the
    ``tests/test_cost_ledger.py`` property suite pins — and no admitted
    request can ever push a tenant past its limit, regardless of
    interleaving.

    Tenants materialize lazily at ``default_limit`` (infinite unless
    configured); :meth:`set_limit` tightens or relaxes a tenant any time.

    Beyond spend, each tenant may carry a **QPS rate limit**: a token
    bucket (:meth:`set_rate_limit` — ``rate_limit`` tokens/s refill, burst
    capacity, one token per admission attempt) checked at the admission
    boundary alongside the budget reservation. A rate-limited request is
    rejected exactly like a budget miss (prediction -1, zero cost,
    ``mode="rejected"``); no token, no downgrade — a downgraded request
    would still be a request. ``clock`` is injectable for deterministic
    tests; unlimited tenants (the default) never read it.

    The ledger also survives restarts: :meth:`snapshot` returns a
    JSON-serializable dict and :meth:`restore` rebuilds a ledger from it.
    Outstanding admission reservations are carried across (conservative:
    the restarted process may never settle them, but ``spent + reserved <=
    limit`` keeps holding, which is the invariant that matters); token
    buckets restart full (a restart is a quiet period). Reservations are
    tracked per request id, so the restarted scheduler then reconciles —
    :meth:`release_orphans` (or the scheduler-level
    ``reconcile_ledger()``) releases every carried reservation whose
    request is not in the live queue, restoring the tenant's headroom
    instead of holding it hostage forever.
    """

    def __init__(
        self,
        limits: Optional[Dict[str, float]] = None,
        default_limit: float = float("inf"),
        num_arms: int = 0,
        rate_limits: Optional[Dict[str, float]] = None,
        default_rate_limit: float = float("inf"),
        clock=time.monotonic,
    ):
        self.default_limit = float(default_limit)
        self.default_rate_limit = float(default_rate_limit)
        self.num_arms = int(num_arms)
        self.clock = clock
        self._t: Dict[str, Dict[str, Any]] = {}
        self.admitted = 0
        self.rejected = 0
        self.downgraded = 0
        self.rate_limited = 0
        for tenant, lim in (limits or {}).items():
            self.set_limit(tenant, lim)
        for tenant, qps in (rate_limits or {}).items():
            self.set_rate_limit(tenant, qps)

    def _tenant(self, tenant: str) -> Dict[str, Any]:
        ent = self._t.get(tenant)
        if ent is None:
            qps = self.default_rate_limit
            ent = self._t[tenant] = {
                "limit": self.default_limit,
                "reserved": 0.0,
                "reserved_n": 0,
                "spent": 0.0,
                "requests": 0,
                "rejected": 0,
                "downgraded": 0,
                "rate_limited": 0,
                "rate_limit": qps,
                "burst": self._default_burst(qps),
                "tokens": self._default_burst(qps),
                "stamp": None,
                "by_arm": np.zeros(self.num_arms, np.float64),
                # outstanding reservations by request id — what lets a
                # restarted scheduler release orphans it will never settle
                "resv": {},
            }
        return ent

    @staticmethod
    def _default_burst(qps: float) -> float:
        return max(1.0, float(qps)) if np.isfinite(qps) else float("inf")

    def set_limit(self, tenant: str, limit: float) -> None:
        self._tenant(tenant)["limit"] = float(limit)

    def set_rate_limit(self, tenant: str, qps: float,
                       burst: Optional[float] = None) -> None:
        """Configure a tenant's admission token bucket: ``qps`` tokens/s
        refill up to ``burst`` capacity (default ``max(1, qps)``); each
        admission attempt consumes one token. ``inf`` removes the limit."""
        ent = self._tenant(tenant)
        ent["rate_limit"] = float(qps)
        ent["burst"] = (
            self._default_burst(qps) if burst is None else float(burst)
        )
        ent["tokens"] = ent["burst"]   # fresh bucket starts full
        ent["stamp"] = None

    def allow_request(self, tenant: str) -> bool:
        """Admission-time QPS check: refill the tenant's token bucket from
        the clock, then take one token. True (no clock read, no state
        touched) for unlimited tenants — the default stays zero-overhead."""
        ent = self._tenant(tenant)
        rate = ent["rate_limit"]
        if not np.isfinite(rate):
            return True
        now = float(self.clock())
        if ent["stamp"] is not None:
            ent["tokens"] = min(
                ent["burst"], ent["tokens"] + (now - ent["stamp"]) * rate
            )
        ent["stamp"] = now
        if ent["tokens"] >= 1.0:
            ent["tokens"] -= 1.0
            return True
        return False

    def note_rate_limited(self, tenant: str) -> None:
        self._tenant(tenant)["rate_limited"] += 1
        self.rate_limited += 1

    def remaining(self, tenant: str) -> float:
        ent = self._tenant(tenant)
        return ent["limit"] - ent["spent"] - ent["reserved"]

    def try_reserve(self, tenant: str, amount: float,
                    request_id: Optional[int] = None) -> bool:
        """Reserve ``amount`` against the tenant's remaining headroom;
        False (nothing reserved) when it does not fit. With a
        ``request_id`` the reservation is tracked by id, so a restart can
        reconcile it against a live queue (:meth:`release_orphans`)."""
        ent = self._tenant(tenant)
        if amount > ent["limit"] - ent["spent"] - ent["reserved"]:
            return False
        ent["reserved"] += float(amount)
        ent["reserved_n"] += 1
        if request_id is not None:
            ent["resv"][int(request_id)] = float(amount)
        self.admitted += 1
        return True

    def settle(self, tenant: str, reserved: float, charged: float,
               arm_spend: Optional[np.ndarray] = None,
               requests: int = 1, request_ids=None) -> None:
        """Release an admission reservation and commit the realized charge
        (with its exact per-arm attribution). ``request_ids`` retires the
        matching id-tracked reservations (ids never tracked are ignored)."""
        ent = self._tenant(tenant)
        ent["reserved"] -= float(reserved)
        ent["reserved_n"] -= int(requests)
        if request_ids is not None and ent["resv"]:
            for rid in np.asarray(request_ids, np.int64).ravel().tolist():
                ent["resv"].pop(int(rid), None)
        if ent["reserved_n"] <= 0:
            # no reservation outstanding: snap the float residue of the
            # add-one-by-one / release-as-a-sum asymmetry to an exact zero
            ent["reserved"] = 0.0
            ent["reserved_n"] = 0
            ent["resv"].clear()
        ent["spent"] += float(charged)
        ent["requests"] += int(requests)
        if arm_spend is not None:
            if ent["by_arm"].size != np.asarray(arm_spend).size:
                ent["by_arm"] = np.zeros(np.asarray(arm_spend).size, np.float64)
            ent["by_arm"] += arm_spend
        self.admitted -= int(requests)

    def release_orphans(self, active_request_ids) -> int:
        """Release id-tracked reservations whose request is not alive.

        The restart reconciliation: :meth:`restore` conservatively carries
        the dead process's outstanding reservations (so ``spent + reserved
        <= limit`` cannot be violated by the handoff), but nothing will
        ever settle them — without reconciliation they shrink the tenant's
        budget forever. A restarted scheduler passes the request ids it
        actually holds (queued + in flight); every tracked reservation
        outside that set is released exactly (amounts were recorded per
        id, so no float residue leaks into ``reserved``). Returns the
        number of reservations released."""
        ids = list(active_request_ids)
        active = {
            int(r) for r in np.asarray(ids, np.int64).ravel().tolist()
        } if ids else set()
        released = 0
        for ent in self._t.values():
            orphans = [rid for rid in ent["resv"] if rid not in active]
            for rid in orphans:
                ent["reserved"] -= ent["resv"].pop(rid)
                ent["reserved_n"] -= 1
                self.admitted -= 1
                released += 1
            if ent["reserved_n"] <= 0:
                ent["reserved"] = 0.0
                ent["reserved_n"] = 0
                ent["resv"].clear()
        return released

    def note_rejected(self, tenant: str) -> None:
        self._tenant(tenant)["rejected"] += 1
        self.rejected += 1

    def note_downgraded(self, tenant: str) -> None:
        self._tenant(tenant)["downgraded"] += 1
        self.downgraded += 1

    def tenant(self, tenant: str) -> Dict[str, Any]:
        """Snapshot of one tenant's ledger row (copies, safe to mutate)."""
        ent = self._tenant(tenant)
        out = dict(ent)
        out["by_arm"] = ent["by_arm"].copy()
        out["resv"] = dict(ent["resv"])
        return out

    def tenants(self) -> Dict[str, Dict[str, Any]]:
        return {name: self.tenant(name) for name in self._t}

    @property
    def total_spent(self) -> float:
        return float(sum(e["spent"] for e in self._t.values()))

    @property
    def total_reserved(self) -> float:
        return float(sum(e["reserved"] for e in self._t.values()))

    def stats(self) -> Dict[str, float]:
        """Flat counters mirrored into ``BatchScheduler.stats``."""
        return {
            "ledger_tenants": len(self._t),
            "ledger_spent": self.total_spent,
            "ledger_reserved": self.total_reserved,
            "ledger_requests": int(sum(e["requests"] for e in self._t.values())),
            "ledger_rejected": self.rejected,
            "ledger_downgraded": self.downgraded,
            "ledger_rate_limited": self.rate_limited,
        }

    # ------------------------------------------------------------------
    # Persistence across restarts
    # ------------------------------------------------------------------
    @staticmethod
    def _enc(v: float):
        # strict-JSON safe: infinities (the unlimited defaults) -> None
        return None if not np.isfinite(v) else float(v)

    @staticmethod
    def _dec(v) -> float:
        return float("inf") if v is None else float(v)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable ledger state: per-tenant spend, outstanding
        reservations, limits and counters. ``json.dumps(ledger.snapshot())``
        round-trips through :meth:`restore` — the restart path the
        ``tests/test_cost_ledger.py`` suite pins."""
        enc = self._enc
        return {
            "version": 1,
            "default_limit": enc(self.default_limit),
            "default_rate_limit": enc(self.default_rate_limit),
            "num_arms": self.num_arms,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "downgraded": self.downgraded,
            "rate_limited": self.rate_limited,
            "tenants": {
                name: {
                    "limit": enc(ent["limit"]),
                    "reserved": ent["reserved"],
                    "reserved_n": ent["reserved_n"],
                    "spent": ent["spent"],
                    "requests": ent["requests"],
                    "rejected": ent["rejected"],
                    "downgraded": ent["downgraded"],
                    "rate_limited": ent["rate_limited"],
                    "rate_limit": enc(ent["rate_limit"]),
                    "burst": enc(ent["burst"]),
                    "by_arm": ent["by_arm"].tolist(),
                    # JSON object keys must be strings; restore re-ints them
                    "resv": {str(rid): amt for rid, amt in ent["resv"].items()},
                }
                for name, ent in self._t.items()
            },
        }

    @classmethod
    def restore(cls, payload: Dict[str, Any],
                clock=time.monotonic) -> "CostLedger":
        """Rebuild a ledger from a :meth:`snapshot` dict (parsed JSON).

        Spend, reservations, limits and counters come back exactly; token
        buckets restart full at their configured rate/burst (wall-clock
        bucket levels do not survive a process boundary meaningfully)."""
        dec = cls._dec
        led = cls(
            default_limit=dec(payload["default_limit"]),
            default_rate_limit=dec(payload.get("default_rate_limit")),
            num_arms=int(payload.get("num_arms", 0)),
            clock=clock,
        )
        led.admitted = int(payload.get("admitted", 0))
        led.rejected = int(payload.get("rejected", 0))
        led.downgraded = int(payload.get("downgraded", 0))
        led.rate_limited = int(payload.get("rate_limited", 0))
        for name, row in payload.get("tenants", {}).items():
            ent = led._tenant(name)
            ent["limit"] = dec(row["limit"])
            ent["reserved"] = float(row["reserved"])
            ent["reserved_n"] = int(row["reserved_n"])
            ent["spent"] = float(row["spent"])
            ent["requests"] = int(row["requests"])
            ent["rejected"] = int(row["rejected"])
            ent["downgraded"] = int(row["downgraded"])
            ent["rate_limited"] = int(row.get("rate_limited", 0))
            ent["rate_limit"] = dec(row.get("rate_limit"))
            ent["burst"] = dec(row.get("burst"))
            ent["tokens"] = ent["burst"]
            ent["stamp"] = None
            ent["resv"] = {
                int(rid): float(amt)
                for rid, amt in row.get("resv", {}).items()
            }
            by_arm = np.asarray(row.get("by_arm", []), np.float64)
            if by_arm.size:
                ent["by_arm"] = by_arm
        return led


@dataclasses.dataclass
class RequestResult:
    """Completion record delivered through a request's future."""

    prediction: int
    cost: float
    planned_cost: float
    cluster: int
    budget: float
    stop_wave: int                   # waves invoked before Prop. 4 stopped it
    mode: str                        # data plane that served it: jit | reference
    latency_s: float                 # completion time - arrival time
    request_id: int = -1             # feedback key for record_outcome()


class RequestFuture:
    """Single-request completion handle returned by :meth:`BatchScheduler.submit`.

    ``request_id`` is the scheduler-assigned key for asynchronous
    ground-truth feedback: once the future completes, the caller may report
    the true label via ``scheduler.record_outcome(fut.request_id, label)``.
    """

    __slots__ = ("_sched", "request", "request_id", "_result")

    def __init__(self, sched: "BatchScheduler", request: Request,
                 request_id: int = -1):
        self._sched = sched
        self.request = request
        self.request_id = request_id
        self._result: Optional[RequestResult] = None

    def done(self) -> bool:
        return self._result is not None

    def result(self, wait: bool = True) -> RequestResult:
        """The request's result; with ``wait`` (default) drives the
        scheduler until this request completes."""
        if self._result is None and wait:
            self._sched._force(self)
        if self._result is None:
            raise RuntimeError("request not completed; pump() or drain() first")
        return self._result

    # columnar fill interface shared with BlockFuture
    def _fill(self, pos, predictions, costs, planned, clusters, budgets,
              stop_waves, mode, latencies):
        self._result = RequestResult(
            prediction=int(predictions[0]),
            cost=float(costs[0]),
            planned_cost=float(planned[0]),
            cluster=int(clusters[0]),
            budget=float(budgets[0]),
            stop_wave=int(stop_waves[0]),
            mode=mode,
            latency_s=float(latencies[0]),
            request_id=self.request_id,
        )


class BlockFuture:
    """Columnar completion handle for a :meth:`BatchScheduler.submit_many`
    block: per-request results land in preallocated arrays as each budget
    group retires, with no per-request Python objects anywhere on the path.
    """

    __slots__ = (
        "_sched", "n", "_ndone", "predictions", "costs", "planned_costs",
        "clusters", "budgets", "stop_waves", "latencies_s", "modes",
        "request_ids",
    )

    def __init__(self, sched: "BatchScheduler", n: int,
                 request_ids: Optional[np.ndarray] = None):
        self._sched = sched
        self.n = n
        self._ndone = 0
        self.request_ids = (
            np.full(n, -1, np.int64) if request_ids is None else request_ids
        )
        self.predictions = np.full(n, -1, np.int64)
        self.costs = np.zeros(n, np.float64)
        self.planned_costs = np.zeros(n, np.float64)
        self.clusters = np.full(n, -1, np.int64)
        self.budgets = np.zeros(n, np.float64)
        self.stop_waves = np.zeros(n, np.int64)
        self.latencies_s = np.zeros(n, np.float64)
        self.modes = np.zeros(n, dtype="U9")

    def done(self) -> bool:
        return self._ndone >= self.n

    def result(self, wait: bool = True) -> "BlockFuture":
        if not self.done() and wait:
            self._sched._force(self)
        if not self.done():
            raise RuntimeError("block not completed; pump() or drain() first")
        return self

    def _fill(self, pos, predictions, costs, planned, clusters, budgets,
              stop_waves, mode, latencies):
        self.predictions[pos] = predictions
        self.costs[pos] = costs
        self.planned_costs[pos] = planned
        self.clusters[pos] = clusters
        self.budgets[pos] = budgets
        self.stop_waves[pos] = stop_waves
        self.modes[pos] = mode
        self.latencies_s[pos] = latencies
        self._ndone += len(pos)


class _Segment:
    """One enqueued block: columnar request arrays + the future they feed.

    ``submit`` makes 1-row segments around a RequestFuture; ``submit_many``
    makes one n-row segment around a BlockFuture. Admission slices segments
    off the queue head FIFO, splitting the last one if the batch fills
    mid-segment.
    """

    __slots__ = ("payloads", "emb", "budgets", "arrival", "slo",
                 "sink", "pos", "ids", "requests", "tenants")

    def __init__(self, payloads, emb, budgets, arrival, slo, sink, pos,
                 ids, requests=None, tenants=None):
        self.payloads = payloads      # (n, ...) array or list
        self.emb = emb                # (n, d)
        self.budgets = budgets        # (n,)
        self.arrival = arrival        # (n,)
        self.slo = slo                # (n,) with nan = no SLO
        self.sink = sink              # RequestFuture | BlockFuture
        self.pos = pos                # (n,) rows of `sink` these fill
        self.ids = ids                # (n,) scheduler-assigned request ids
        self.requests = requests      # Optional[List[Request]] (submit path)
        if tenants is None:
            tenants = np.full(self.budgets.shape[0], "default", object)
        self.tenants = tenants        # (n,) ledger principals

    def __len__(self) -> int:
        return self.budgets.shape[0]

    def split(self, k: int) -> "_Segment":
        """Pop the first ``k`` rows off as a new segment (FIFO admission)."""
        head = _Segment(
            self.payloads[:k], self.emb[:k], self.budgets[:k],
            self.arrival[:k], self.slo[:k], self.sink, self.pos[:k],
            self.ids[:k],
            self.requests[:k] if self.requests is not None else None,
            self.tenants[:k],
        )
        self.payloads = self.payloads[k:]
        self.emb = self.emb[k:]
        self.budgets = self.budgets[k:]
        self.arrival = self.arrival[k:]
        self.slo = self.slo[k:]
        self.pos = self.pos[k:]
        self.ids = self.ids[k:]
        if self.requests is not None:
            self.requests = self.requests[k:]
        self.tenants = self.tenants[k:]
        return head


class _Group:
    """One dispatched budget group riding in flight."""

    __slots__ = ("pending", "arrival", "part_sinks", "part_id", "part_pos",
                 "ids", "n", "requests", "tenants", "reserved")

    def __init__(self, pending, arrival, part_sinks, part_id, part_pos,
                 ids=None, requests=None, tenants=None, reserved=None):
        self.pending = pending        # router.PendingRoute
        self.arrival = arrival        # (n,)
        self.part_sinks = part_sinks  # list of futures contributing rows
        self.part_id = part_id        # (n,) index into part_sinks; None = one part
        self.part_pos = part_pos      # (n,) row of the sink each query fills
        self.ids = ids                # (n,) request ids (feedback key)
        self.n = arrival.shape[0]
        self.requests = requests
        self.tenants = tenants        # (n,) ledger principals; None = no ledger
        self.reserved = reserved      # (n,) admission reservations to settle


class BatchScheduler:
    """Continuous-batching front-end over a :class:`ThriftRouter`.

    Streaming use — submit anytime, drive with ``pump()`` (non-blocking
    progress) or ``drain()`` (run the backlog dry); hold futures::

        fut = sched.submit(Request(payload, emb, budget, slo_s=0.05))
        blk = sched.submit_many(payloads, embs, budget)   # columnar block
        sched.pump()          # admit/dispatch/retire whatever is due
        res = fut.result()    # drives the scheduler until this completes

    Batch-compat use (PR 2 semantics, used by the equivalence tests)::

        sched.submit(...); ...
        for requests, route_result in sched.flush():
            ...

    Args:
      router: the ThriftRouter data plane.
      max_batch: admission batch size cap.
      max_wait_s: oldest-request wait that forces admission.
      max_inflight: budget groups allowed in flight at once (2 =
        double-buffered waves; 1 degenerates to the PR 2 serial loop).
      speculation: ``"auto"`` (cost-aware switch), ``"jit"`` or
        ``"reference"`` to pin the data plane.
      speculation_threshold: USD per query the auto switch may gamble on
        speculatively invoked *metered* arms (see
        :meth:`ThriftRouter.speculation_cost`).
      slo_margin_s: dispatch headroom subtracted from a request's ``slo_s``
        when computing its admission deadline.
      prefetch_plans: build missing wave plans from the queued (cluster,
        budget) composition while waiting for the flush deadline.
      coalesce: saturation batch growth — when the backlog exceeds
        ``max_batch`` (arrivals outpacing service), one admission may take
        up to ``coalesce * max_batch`` requests, amortizing per-dispatch
        cost into bigger device batches exactly when latency is already
        queue-bound. 1 (default) keeps admissions at ``max_batch``; the
        legacy ``flush()`` API never coalesces.
      feedback: online estimation feedback from served traffic. ``True``
        builds a :class:`~repro.serving.feedback.FeedbackLog` over the
        router's estimator; or pass a FeedbackLog instance (shareable
        across schedulers bound to the same estimator). ``None``/``False``
        (default) disables it — zero overhead, PR 3 behavior. With
        feedback on, report ground truth via :meth:`record_outcome` /
        :meth:`record_outcomes`; pending labels fold into the estimator at
        the next admission boundary (never mid-wave).
      ledger: per-tenant cost accounting + hard budget enforcement.
        ``True`` builds a :class:`CostLedger` (unlimited tenants until
        ``set_limit``); or pass a configured CostLedger. With a ledger on,
        admission enforces tenant limits: a request whose budget does not
        fit the tenant's remaining headroom is *downgraded* to the largest
        affordable cheaper budget tier (``budget_tiers`` or the
        PlanService's observed budgets), or *rejected* outright — its
        future completes immediately with ``mode="rejected"``,
        ``prediction=-1`` and zero cost. ``None``/``False`` (default)
        disables all of it: zero overhead, prior behavior.
      budget_tiers: explicit downgrade ladder for ledger admission; when
        None the PlanService's observed budgets are used.
    """

    def __init__(
        self,
        router,
        max_batch: int = 64,
        max_wait_s: float = 0.02,
        max_inflight: int = 2,
        speculation: str = "auto",
        speculation_threshold: float = 0.0,
        slo_margin_s: float = 0.002,
        prefetch_plans: bool = True,
        coalesce: int = 1,
        feedback=None,
        ledger=None,
        budget_tiers=None,
    ):
        if speculation not in ("auto", "jit", "reference"):
            raise ValueError(f"unknown speculation mode {speculation!r}")
        self.router = router
        if feedback is True:
            feedback = FeedbackLog(router.estimator)
        self.feedback: Optional[FeedbackLog] = feedback or None
        # fault evidence (timeouts/errors/degrades) folds through the same
        # versioned estimator path as labels, so the Wilson drift gate can
        # replan flaky arms away and probe traffic can readmit them
        self.degradation: Optional[DegradationTracker] = (
            DegradationTracker(self.feedback)
            if self.feedback is not None else None
        )
        if ledger is True:
            ledger = CostLedger(num_arms=len(router.engine.arms))
        self.ledger: Optional[CostLedger] = ledger or None
        self.budget_tiers = (
            None if budget_tiers is None
            else sorted(float(b) for b in budget_tiers)
        )
        self._next_id = 0
        self.max_batch = int(max_batch)
        self.coalesce = max(1, int(coalesce))
        self.max_wait_s = float(max_wait_s)
        self.max_inflight = max(1, int(max_inflight))
        self.speculation = speculation
        self.speculation_threshold = float(speculation_threshold)
        self.slo_margin_s = float(slo_margin_s)
        self.prefetch_plans = bool(prefetch_plans)
        self._queue: collections.deque = collections.deque()  # of _Segment
        self._qlen = 0
        self._queue_version = 0
        self._prefetched_version = -1
        self._inflight: collections.deque = collections.deque()  # of _Group
        self._latencies: List[np.ndarray] = []
        self._lat_window = 1 << 17        # newest samples kept for percentiles
        self._lat_buffered = 0
        self.mitigator = StragglerMitigator(num_workers=len(router.engine.arms))
        self.arm_query_totals = np.zeros(len(router.engine.arms), np.int64)
        self._stats: Dict[str, float] = {
            "batches": 0,        # budget groups routed (PR 1/2 meaning)
            "requests": 0,       # requests admitted into routed batches
            "flushes": 0,        # admission events
            "submitted": 0,
            "completed": 0,
            "spec_jit": 0,       # groups served by the speculative jit plane
            "spec_reference": 0, # groups served by the compacting plane
            "inflight_peak": 0,
        }
        self._sync_plan_stats()

    # ------------------------------------------------------------------
    # Plan service plumbing (PR 2 API, unchanged)
    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[str, float]:
        """Control-plane counters, with the router's PlanService hit/miss/
        invalidation counters mirrored in on read (so the hot retire path
        never rebuilds the dict)."""
        self._sync_plan_stats()
        return self._stats

    def _sync_plan_stats(self):
        """Mirror the router's PlanService counters into ``stats`` so the
        serving control plane sees plan-cache hit/miss/invalidation rates
        without reaching into router internals. With feedback enabled, the
        FeedbackLog's label/drift counters are mirrored too — together they
        are the hit/miss/replan/drift dashboard of the online loop."""
        plans = getattr(self.router, "plans", None)
        if plans is not None:
            self._stats.update(plans.stats())
        if self.feedback is not None:
            self._stats.update(self.feedback.stats())
        if self.degradation is not None:
            self._stats.update(self.degradation.stats())
        if self.ledger is not None:
            self._stats.update(self.ledger.stats())

    # ------------------------------------------------------------------
    # Online ground-truth feedback (see serving/feedback.py)
    # ------------------------------------------------------------------
    def record_outcome(self, request_id: int, label: int) -> bool:
        """Report the ground-truth label of a completed request (keyed by
        ``RequestFuture.request_id`` / ``BlockFuture.request_ids``). The
        label is buffered and folds into the estimator at the next
        admission boundary — routing in flight is never perturbed. Returns
        True if the id matched a watched outcome."""
        if self.feedback is None:
            raise RuntimeError(
                "feedback is disabled; construct BatchScheduler(..., feedback=True)"
            )
        return self.feedback.record(request_id, label)

    def record_outcomes(self, request_ids, labels) -> int:
        """Batch :meth:`record_outcome`; returns how many ids matched."""
        if self.feedback is None:
            raise RuntimeError(
                "feedback is disabled; construct BatchScheduler(..., feedback=True)"
            )
        return self.feedback.record_many(request_ids, labels)

    def apply_feedback(self) -> Optional[FeedbackReport]:
        """Fold any pending labels into the estimator now. Called
        automatically at every admission boundary; public so a quiescent
        server (no traffic arriving) can still absorb late labels.

        A fold that drifted any clusters is followed by ONE batched replan:
        every plan the fold invalidated — across all drifted clusters and
        budgets — re-selects through a single
        :meth:`~repro.serving.plans.PlanService.replan_stale` dispatch, so
        a drift storm never serializes cold selections across the next
        batches."""
        # gate on has_pending, not the labeled count: degradation evidence
        # (attempts with zero labels) must still trigger a fold + replan
        if self.feedback is None or not self.feedback.has_pending:
            return None
        report = self.feedback.apply()
        if report.drifted:
            plans = getattr(self.router, "plans", None)
            if plans is not None:
                plans.replan_stale(report.drifted)
        self._sync_plan_stats()
        return report

    def prewarm(self, budgets: Optional[List[float]] = None) -> int:
        """Precompute wave plans ahead of traffic (delegates to the
        router's PlanService): with ``budgets``, plan every known cluster at
        each budget; without, re-plan the hottest observed pairs. Returns
        the number of plans built."""
        plans = getattr(self.router, "plans", None)
        if plans is None:
            return 0
        built = plans.prewarm(budgets=budgets)
        self._sync_plan_stats()
        return built

    def _prefetch(self):
        """Queue-composition plan prefetch: whenever the queued set has
        changed since the last look, hand its (embedding, budget) columns to
        the PlanService so missing plans are built before the flush."""
        if not self.prefetch_plans or not self._queue:
            return
        if self._queue_version == self._prefetched_version:
            return
        self._prefetched_version = self._queue_version
        plans = getattr(self.router, "plans", None)
        if plans is None:
            return
        emb = np.concatenate([s.emb for s in self._queue])
        budgets = np.concatenate([s.budgets for s in self._queue])
        plans.prefetch_for(emb, budgets)
        self._sync_plan_stats()

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def _alloc_ids(self, n: int) -> np.ndarray:
        """Fresh request ids. With feedback bound, the FeedbackLog is the
        id authority, so schedulers sharing one log never collide keys."""
        if self.feedback is not None:
            return self.feedback.next_ids(n)
        start = self._next_id
        self._next_id += n
        return np.arange(start, start + n, dtype=np.int64)

    def submit(self, req: Request) -> RequestFuture:
        """Enqueue one request; returns its completion future (which carries
        the ``request_id`` to feed :meth:`record_outcome` later)."""
        rid = int(self._alloc_ids(1)[0])
        fut = RequestFuture(self, req, request_id=rid)
        self._queue.append(_Segment(
            [req.payload],
            np.asarray(req.embedding, np.float64)[None, :],
            np.asarray([req.budget], np.float64),
            np.asarray([req.arrival_s], np.float64),
            np.asarray([np.nan if req.slo_s is None else req.slo_s]),
            fut, np.zeros(1, np.int64), np.asarray([rid], np.int64),
            requests=[req], tenants=np.asarray([req.tenant], object),
        ))
        self._qlen += 1
        self._queue_version += 1
        self._stats["submitted"] += 1
        return fut

    def submit_many(
        self,
        payloads,
        embeddings: np.ndarray,
        budgets,
        slo_s: Optional[float] = None,
        arrival_s=None,
        tenant="default",
    ) -> BlockFuture:
        """Columnar block submission: ``n`` requests enter as one segment of
        arrays and resolve into one :class:`BlockFuture` — the high-rate
        path (an arrival process delivers bursts, not single requests).
        ``tenant`` (scalar or per-row sequence) names the cost-ledger
        principal the block's spend is charged to."""
        emb = np.asarray(embeddings, np.float64)
        n = emb.shape[0]
        if n == 0:
            return BlockFuture(self, 0)   # already done; never enqueued
        budgets = np.broadcast_to(np.asarray(budgets, np.float64), (n,)).copy()
        if arrival_s is None:
            arrival = np.full(n, time.monotonic())
        else:
            arrival = np.broadcast_to(
                np.asarray(arrival_s, np.float64), (n,)
            ).copy()
        slo = np.full(n, np.nan if slo_s is None else float(slo_s))
        ids = self._alloc_ids(n)
        blk = BlockFuture(self, n, request_ids=ids)
        tenants = np.broadcast_to(np.asarray(tenant, object), (n,)).copy()
        self.submit_block(
            payloads, emb, budgets, arrival, slo, blk, np.arange(n), ids,
            tenants,
        )
        return blk

    def submit_block(self, payloads, emb, budgets, arrival, slo, sink, pos,
                     ids, tenants) -> None:
        """Enqueue pre-built columnar rows against an externally-owned sink
        (``sink``/``pos``): the admission seam a sharded front-end (see
        :class:`~repro.serving.replica.ReplicaSet`) uses to scatter one
        caller-visible :class:`BlockFuture` across several schedulers.
        ``submit_many`` is this plus the array building."""
        n = budgets.shape[0]
        self._queue.append(_Segment(
            payloads, emb, budgets, arrival, slo, sink, pos, ids,
            tenants=tenants,
        ))
        self._qlen += n
        self._queue_version += 1
        self._stats["submitted"] += n

    def _seg_deadline(self, seg: _Segment) -> float:
        """Earliest time any request in the segment must be admitted:
        arrival + max_wait, tightened by per-request SLOs."""
        wait = np.minimum(
            self.max_wait_s,
            np.where(np.isnan(seg.slo), self.max_wait_s,
                     np.maximum(seg.slo - self.slo_margin_s, 0.0)),
        )
        return float((seg.arrival + wait).min())

    def next_deadline(self) -> Optional[float]:
        """Monotonic time the queue's most urgent request must flush by
        (None when idle) — lets an event loop sleep instead of polling."""
        if not self._queue:
            return None
        return min(self._seg_deadline(s) for s in self._queue)

    def ready(self) -> bool:
        """Is a batch due for admission? Full batch, oldest-request wait
        expiry, or an SLO deadline — whichever comes first."""
        if not self._queue:
            return False
        if self._qlen >= self.max_batch:
            return True
        return time.monotonic() >= self.next_deadline()

    def _take_batch(self, coalesce: bool = True) -> List[_Segment]:
        """Pop one admission off the queue head (FIFO), splitting the
        boundary segment if needed. Admissions are ``max_batch`` requests,
        except under saturation (backlog > ``max_batch``) where they may
        grow to ``coalesce * max_batch`` — latency is already queue-bound
        there, so bigger device batches are free throughput."""
        limit = self.max_batch
        if coalesce and self._qlen > limit:
            limit = min(self._qlen, self.coalesce * self.max_batch)
        take: List[_Segment] = []
        n = 0
        while self._queue and n < limit:
            seg = self._queue[0]
            room = limit - n
            if len(seg) <= room:
                take.append(self._queue.popleft())
            else:
                take.append(seg.split(room))
            n += len(take[-1])
        self._qlen -= n
        self._queue_version += 1
        return take

    @staticmethod
    def _cat_payloads(parts: Sequence[Any]):
        if len(parts) == 1:
            return parts[0]
        if all(isinstance(p, np.ndarray) for p in parts):
            return np.concatenate(parts)
        out: List[Any] = []
        for p in parts:
            out.extend(list(p))
        return out

    @staticmethod
    def _index_payloads(payloads, rows: np.ndarray):
        if isinstance(payloads, np.ndarray):
            return payloads[rows]
        return [payloads[i] for i in rows]

    # ------------------------------------------------------------------
    # Dispatch / retire: the pipelined data plane
    # ------------------------------------------------------------------
    def _route_mode(self) -> str:
        # "auto" defers to begin_route's switch (which also honors a router
        # pinned to the reference plane via jit_waves=False)
        return self.speculation

    @staticmethod
    def _stack_segments(take: List[_Segment]):
        """Columnar view of an admitted batch; the single-segment case (the
        block-submission hot path) is zero-copy."""
        if len(take) == 1:
            s = take[0]
            return (s.payloads, s.emb, s.budgets, s.arrival, [s.sink], None,
                    s.pos, s.ids, s.tenants)
        payloads = BatchScheduler._cat_payloads([s.payloads for s in take])
        emb = np.concatenate([s.emb for s in take])
        budgets = np.concatenate([s.budgets for s in take])
        arrival = np.concatenate([s.arrival for s in take])
        part_sinks = [s.sink for s in take]
        part_id = np.concatenate([
            np.full(len(s), i, np.int64) for i, s in enumerate(take)
        ])
        part_pos = np.concatenate([s.pos for s in take])
        ids = np.concatenate([s.ids for s in take])
        tenants = np.concatenate([s.tenants for s in take])
        return (payloads, emb, budgets, arrival, part_sinks, part_id,
                part_pos, ids, tenants)

    def _downgrade_budget(self, tenant: str, budget: float) -> Optional[float]:
        """Largest budget tier strictly cheaper than ``budget`` that still
        fits the tenant's remaining ledger headroom; None when none does.
        Tiers come from ``budget_tiers`` or, by default, the budgets the
        PlanService has already planned (so a downgraded request lands on a
        hot plan, not a cold compile)."""
        tiers = self.budget_tiers
        if tiers is None:
            plans = getattr(self.router, "plans", None)
            tiers = plans.known_budgets() if plans is not None else []
        remaining = self.ledger.remaining(tenant)
        best = None
        for b in tiers:
            if 0.0 < b < budget and b <= remaining:
                best = b if best is None else max(best, b)
        return best

    def _admit_ledger(self, budgets, tenants, arrival, part_sinks, part_id,
                      part_pos, ids=None):
        """Hard budget enforcement at the admission boundary.

        Sequentially (arrival order — admission must not depend on how rows
        later split into budget groups): first the tenant's QPS token
        bucket (a rate-limited request is rejected outright — no budget
        interaction, no downgrade), then reserves each request's budget
        against its tenant; on a miss, tries a downgrade to the largest
        affordable cheaper tier; otherwise rejects. Rejected rows complete
        immediately (``mode="rejected"``, prediction -1, zero cost) and are
        dropped from the batch. Returns ``(keep_rows, budgets, reserved)``
        with ``budgets`` a (possibly downgraded) copy."""
        n = budgets.shape[0]
        budgets = budgets.copy()   # single-segment stacking is zero-copy
        reserved = np.zeros(n, np.float64)
        keep = np.ones(n, bool)
        led = self.ledger
        for i in range(n):
            tenant = tenants[i]
            amount = float(budgets[i])
            rid = int(ids[i]) if ids is not None else None
            if not led.allow_request(tenant):
                keep[i] = False
                led.note_rate_limited(tenant)
                continue
            if led.try_reserve(tenant, amount, request_id=rid):
                reserved[i] = amount
                continue
            down = self._downgrade_budget(tenant, amount)
            if down is not None and led.try_reserve(tenant, down,
                                                    request_id=rid):
                budgets[i] = reserved[i] = down
                led.note_downgraded(tenant)
                continue
            keep[i] = False
            led.note_rejected(tenant)
        rejected = np.flatnonzero(~keep)
        if rejected.size:
            k = rejected.shape[0]
            shell = _Group(None, arrival, part_sinks, part_id, part_pos)
            self._resolve_rows(
                shell, rejected,
                np.full(k, -1, np.int64), np.zeros(k), np.zeros(k),
                np.full(k, -1, np.int64), budgets[rejected],
                np.zeros(k, np.int64), "rejected", time.monotonic(),
            )
        return np.flatnonzero(keep), budgets, reserved

    def _dispatch_batch(self):
        """Admit one batch and dispatch its budget groups into flight.

        Pending ground-truth feedback folds into the estimator *here* — the
        admission boundary — so every query of the batch routes against one
        consistent estimator version and a fold can never land mid-wave.
        With a cost ledger bound, this is also where tenant limits are
        enforced (reserve / downgrade / reject)."""
        self.apply_feedback()
        take = self._take_batch()
        if not take:
            return
        (payloads, emb, budgets, arrival, part_sinks, part_id, part_pos,
         ids, tenants) = self._stack_segments(take)
        self._stats["flushes"] += 1
        reserved = None
        if self.ledger is not None:
            admitted, budgets, reserved = self._admit_ledger(
                budgets, tenants, arrival, part_sinks, part_id, part_pos,
                ids=ids,
            )
            if admitted.size < budgets.shape[0]:
                if admitted.size == 0:
                    return
                payloads = self._index_payloads(payloads, admitted)
                emb, budgets = emb[admitted], budgets[admitted]
                arrival, part_pos = arrival[admitted], part_pos[admitted]
                ids, tenants = ids[admitted], tenants[admitted]
                reserved = reserved[admitted]
                if part_id is not None:
                    part_id = part_id[admitted]
        self._stats["requests"] += budgets.shape[0]
        mode = self._route_mode()
        if (budgets == budgets[0]).all():
            group_rows = [None]                    # whole batch, no split
        else:
            # one group per budget, first-occurrence order, FIFO inside
            _, first = np.unique(budgets, return_index=True)
            group_rows = [
                np.flatnonzero(budgets == budgets[i]) for i in np.sort(first)
            ]
        for rows in group_rows:
            if rows is None:
                g_payloads, g_emb, g_budgets = payloads, emb, budgets
                g_arrival, g_id, g_pos, g_ids = arrival, part_id, part_pos, ids
                g_tenants = tenants if self.ledger is not None else None
                g_reserved = reserved
            else:
                g_payloads = self._index_payloads(payloads, rows)
                g_emb, g_budgets = emb[rows], budgets[rows]
                g_arrival, g_pos, g_ids = arrival[rows], part_pos[rows], ids[rows]
                g_id = part_id[rows] if part_id is not None else None
                g_tenants = tenants[rows] if self.ledger is not None else None
                g_reserved = reserved[rows] if reserved is not None else None
            self._launch(
                g_payloads, g_emb, g_budgets, g_arrival, part_sinks, g_id,
                g_pos, g_ids, g_tenants, g_reserved, mode,
            )
        self._stats["inflight_peak"] = max(
            self._stats["inflight_peak"], len(self._inflight)
        )

    def _launch(self, payloads, emb, budgets, arrival, part_sinks, part_id,
                part_pos, ids, tenants, reserved, mode):
        """Dispatch one admitted budget group into flight. The dispatch
        seam: a replica worker overrides this to *stage* the group so a
        :class:`~repro.serving.replica.ReplicaSet` can fuse same-budget
        groups from several replicas into one wave program."""
        pending = self.router.begin_route(
            payloads, emb, budgets, mode=mode,
            speculation_threshold=self.speculation_threshold,
        )
        self._stats["spec_" + pending.kind] += 1
        self._stats["batches"] += 1
        self._inflight.append(
            _Group(pending, arrival, part_sinks, part_id, part_pos,
                   ids=ids, tenants=tenants, reserved=reserved)
        )

    def _resolve_rows(self, group: _Group, rows: np.ndarray, predictions,
                      costs, planned, clusters, budgets, stop_waves, mode,
                      now: float):
        """Columnar completion: fill each contributing future's slice."""
        latencies = now - group.arrival[rows]
        self._latencies.append(latencies)
        self._lat_buffered += latencies.shape[0]
        if self._lat_buffered > 2 * self._lat_window:
            self._trim_latencies()
        self._stats["completed"] += rows.shape[0]
        if group.part_id is None:
            group.part_sinks[0]._fill(
                group.part_pos[rows], predictions, costs, planned, clusters,
                budgets, stop_waves, mode, latencies,
            )
            return
        gid = group.part_id[rows]
        for pid in np.unique(gid):
            sel = gid == pid
            group.part_sinks[pid]._fill(
                group.part_pos[rows[sel]], predictions[sel], costs[sel],
                planned[sel], clusters[sel], budgets[sel], stop_waves[sel],
                mode, latencies[sel],
            )

    def _retire(self, group: _Group) -> int:
        """Complete one in-flight group: step reference-mode groups wave by
        wave (futures fire at each query's stop wave), block on jit-mode
        device results, then account latencies and plan stats."""
        pending = group.pending
        if pending.kind == "reference" and pending.rng is None:
            all_rows = np.arange(group.n)
            resolved = np.zeros(group.n, bool)
            while not pending.exhausted:
                wave = pending._t
                rows, preds = pending.step()
                if rows.size:
                    self._resolve_rows(
                        group, rows, preds, pending.costs[rows],
                        pending.planned[rows], pending.cluster_ids[rows],
                        pending.budgets[rows],
                        np.full(rows.shape[0], min(wave, pending.T), np.int64),
                        "reference", time.monotonic(),
                    )
                    resolved[rows] = True
            res = pending.result()
            left = all_rows[~resolved]
            if left.size:   # defensive: every row should resolve via steps
                self._resolve_rows(
                    group, left, res.predictions[left], res.costs[left],
                    res.planned_costs[left], res.clusters[left],
                    res.budgets[left], res.stop_waves[left],
                    "reference", time.monotonic(),
                )
        else:
            res = pending.result()
            self._resolve_rows(
                group, np.arange(group.n), res.predictions, res.costs,
                res.planned_costs, res.clusters, res.budgets,
                res.stop_waves, pending.kind, time.monotonic(),
            )
        self._account(res, group)
        return group.n

    def _account(self, res, group: Optional[_Group] = None):
        lat = [
            arm.latency_s(int(n)) if n else 0.0
            for arm, n in zip(self.router.engine.arms, res.arm_query_counts)
        ]
        self.mitigator.record_step(lat)
        self.arm_query_totals += np.asarray(res.arm_query_counts, np.int64)
        if self.feedback is not None and group is not None and group.ids is not None:
            # register the group's outcomes so later ground-truth labels can
            # be matched to (cluster, invoked arms, responses) by request id
            fb = self.feedback
            probes = None
            if fb.probe_rate > 0.0 and group.n:
                # exploration side channel: invoke one unplanned arm for a
                # thinned subset of rows — never touches predictions/costs,
                # only the feedback block a later label scores
                rows = fb.probe_rows(group.n)
                if rows.size:
                    arms = fb.probe_arms(res.clusters[rows], res.schedule[rows])
                    ok = arms >= 0
                    rows, arms = rows[ok], arms[ok]
                degrade = None
                policy = getattr(self.router.engine, "fault_policy", None)
                if rows.size and policy is not None and policy.active:
                    # probes hit the same faulty arms: draw their fate
                    # *before* invoking, drop failed probes (recording the
                    # failure as degradation evidence), corrupt degraded ones
                    codes = policy.row_codes(arms, rows)
                    failed = (codes == FAULT_TIMEOUT) | (codes == FAULT_ERROR)
                    if failed.any():
                        if self.degradation is not None:
                            self.degradation.record_failures(
                                res.clusters[rows[failed]], arms[failed]
                            )
                        rows, arms = rows[~failed], arms[~failed]
                        codes = codes[~failed]
                    degrade = codes == FAULT_DEGRADE if rows.size else None
                if rows.size:
                    resp = self.router.engine.invoke_rows(
                        arms, group.pending.payloads, rows
                    )
                    if degrade is not None and degrade.any():
                        resp = np.where(
                            degrade, policy.corrupt_rows(arms, rows), resp
                        )
                    probes = (rows, arms, resp)
            fb.observe(
                group.ids, res.clusters, res.schedule, res.responses,
                res.invoked, probes=probes,
            )
            if (self.degradation is not None
                    and getattr(res, "fault_codes", None) is not None):
                self.degradation.record_route(
                    res.clusters, res.fault_schedule, res.fault_codes
                )
        if (self.ledger is not None and group is not None
                and group.tenants is not None):
            self._settle(res, group)
        self._sync_plan_stats()

    def _settle(self, res, group: _Group):
        """Retire-time ledger settlement: release each tenant's admission
        reservations, commit the realized charge with its exact per-arm
        attribution (the effective post-failover schedule — re-routed waves
        charge the arm actually invoked)."""
        costs = self.router.engine.costs
        tenants = group.tenants
        for tenant in set(tenants.tolist()):
            sel = tenants == tenant
            rows = np.flatnonzero(sel)
            arms = res.schedule[rows][res.invoked[rows]]
            arm_spend = np.bincount(arms, minlength=costs.size) * costs
            self.ledger.settle(
                tenant,
                reserved=float(group.reserved[sel].sum()),
                charged=float(res.costs[sel].sum()),
                arm_spend=arm_spend,
                requests=int(rows.size),
                request_ids=group.ids[rows] if group.ids is not None else None,
            )

    def reconcile_ledger(self) -> int:
        """Release ledger reservations no live request backs.

        The restart handshake: after ``CostLedger.restore()`` the dead
        process's admission reservations are still held (conservatively —
        the invariant ``spent + reserved <= limit`` must survive the
        handoff). A scheduler bound to the restored ledger calls this once
        to reconcile: every id-tracked reservation not matching a request
        this scheduler actually holds (queued or in flight) is released
        exactly. Returns the number of reservations released; 0 without a
        ledger."""
        if self.ledger is None:
            return 0
        live: List[int] = []
        for seg in self._queue:
            if seg.ids is not None:
                live.extend(np.asarray(seg.ids, np.int64).ravel().tolist())
        for group in self._inflight:
            if group.ids is not None:
                live.extend(np.asarray(group.ids, np.int64).ravel().tolist())
        return self.ledger.release_orphans(live)

    # ------------------------------------------------------------------
    # Driving
    # ------------------------------------------------------------------
    def pump(self) -> int:
        """Make progress without avoidable blocking; returns requests
        completed during the call. Retires every group whose device work
        already finished, admits/dispatches batches that are due (blocking
        on the oldest group only when the pipeline is full), and spends
        idle queue time prefetching plans for the queued composition."""
        done = 0
        while True:
            while self._inflight and self._inflight[0].pending.ready():
                done += self._retire(self._inflight.popleft())
            if self.ready():
                if len(self._inflight) >= self.max_inflight:
                    done += self._retire(self._inflight.popleft())
                self._dispatch_batch()
                continue
            break
        if self._queue:
            self._prefetch()
        return done

    def drain(self) -> int:
        """Run the backlog dry: admit everything queued (ignoring
        deadlines), keep ``max_inflight`` groups in flight, retire all.
        Returns requests completed."""
        done = 0
        while self._queue or self._inflight:
            while self._queue and len(self._inflight) < self.max_inflight:
                self._dispatch_batch()
            if self._inflight:   # a fully-rejected admission leaves nothing
                done += self._retire(self._inflight.popleft())
        return done

    def _force(self, fut) -> None:
        """Drive until ``fut`` completes (future.result() entry point)."""
        while not fut.done() and self._inflight:
            self._retire(self._inflight.popleft())
        if not fut.done():
            self.drain()

    # ------------------------------------------------------------------
    # Latency accounting
    # ------------------------------------------------------------------
    def _trim_latencies(self):
        """Keep only the newest ``_lat_window`` samples, so a long-running
        server's latency history stays bounded (the percentile summary is a
        sliding window, like the StragglerMitigator's)."""
        lat = np.concatenate(self._latencies)[-self._lat_window:]
        self._latencies = [lat]
        self._lat_buffered = lat.shape[0]

    def latency_stats(self) -> Dict[str, float]:
        """Completion-latency summary: ``count`` covers everything ever
        completed; the percentiles cover the newest ``_lat_window``
        (default 128k) samples."""
        if not self._latencies:
            return {"count": 0}
        self._trim_latencies()
        lat = self._latencies[0]
        return {
            "count": int(self._stats["completed"]),
            "p50_s": float(np.percentile(lat, 50)),
            "p99_s": float(np.percentile(lat, 99)),
            "mean_s": float(lat.mean()),
            "max_s": float(lat.max()),
        }

    # ------------------------------------------------------------------
    # PR 2 one-shot API (kept for batch callers and the equivalence tests)
    # ------------------------------------------------------------------
    def flush(self) -> List[Tuple[List[Request], Any]]:
        """Admit one batch and route it synchronously as a single
        heterogeneous-budget call; returns ``[(requests, RouteResult)]``.

        Accounting matches PR 2: ``stats["batches"]`` counts the budget
        groups actually routed and the StragglerMitigator only sees the
        latency of arms the wavefront really invoked. Futures of the
        flushed requests complete before this returns.
        """
        self.apply_feedback()
        take = self._take_batch(coalesce=False)
        if not take:
            return []
        (payloads, emb, budgets, arrival, part_sinks, part_id, part_pos,
         ids, tenants) = self._stack_segments(take)
        self._stats["flushes"] += 1
        reserved = None
        if self.ledger is not None:
            admitted, budgets, reserved = self._admit_ledger(
                budgets, tenants, arrival, part_sinks, part_id, part_pos,
                ids=ids,
            )
            if admitted.size < budgets.shape[0]:
                if admitted.size == 0:
                    return []
                payloads = self._index_payloads(payloads, admitted)
                emb, budgets = emb[admitted], budgets[admitted]
                arrival, part_pos = arrival[admitted], part_pos[admitted]
                ids, tenants = ids[admitted], tenants[admitted]
                reserved = reserved[admitted]
                if part_id is not None:
                    part_id = part_id[admitted]
        pending = self.router.begin_route(
            payloads, emb, budgets, mode=self._route_mode(),
            speculation_threshold=self.speculation_threshold,
        )
        res = pending.result()
        self._stats["spec_" + pending.kind] += 1
        self._stats["batches"] += len(np.unique(budgets))
        self._stats["requests"] += budgets.shape[0]
        group = _Group(
            pending, arrival, part_sinks, part_id, part_pos, ids=ids,
            tenants=tenants if self.ledger is not None else None,
            reserved=reserved,
        )
        self._resolve_rows(
            group, np.arange(group.n), res.predictions, res.costs,
            res.planned_costs, res.clusters, res.budgets, res.stop_waves,
            pending.kind, time.monotonic(),
        )
        self._account(res, group)
        requests: List[Request] = []
        for s in take:
            if s.requests is not None:
                requests.extend(s.requests)
            else:
                requests.extend(
                    Request(p, e, float(b), arrival_s=float(a))
                    for p, e, b, a in zip(s.payloads, s.emb, s.budgets, s.arrival)
                )
        if self.ledger is not None and len(requests) != budgets.shape[0]:
            requests = [requests[i] for i in admitted]   # drop rejected rows
        return [(requests, res)]
