"""Persistent XLA compilation-cache plumbing for cold starts.

Compile-bucket warmth normally dies with the process: every restart pays
the full lowering + XLA compile cost for each `_wave_scan` / planner
bucket again before serving is fast (PR 9 made the cost ledger the last
*state* to survive restarts; the compile cache was the last *latency*).
JAX ships a persistent on-disk compilation cache — executables keyed by
(HLO, jaxlib, backend) — which turns a warm restart's compiles into disk
loads.

Opt-in, off by default: set ``REPRO_COMPILE_CACHE_DIR=/path`` (or pass
``cache_dir``) and every jit compile triggered afterwards — including the
prewarm loops in :meth:`ThriftRouter.prewarm_compile` /
:meth:`ReplicaSet.prewarm_compile` — reads through / writes to that
directory. The thresholds are pinned so *all* entries persist (JAX's
defaults skip programs that compile in under a second, which is exactly
the regime of the serving buckets on CPU).

Honesty fields: :func:`configure_compile_cache` returns what actually
happened (enabled, directory, backend, whether the backend supports the
cache, and a detail string) rather than assuming support — mirroring the
``parallel_capable`` pattern from the cross-device bench. Known gap
recorded by :func:`repro.kernels.ops.kernel_compile_probe`: the Pallas
kernels cannot lower natively on the CPU backend (interpret mode only),
so ``REPRO_KERNEL_COMPILE=1`` validation needs a real TPU/GPU — the probe
documents the exact error per kernel.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

ENV_VAR = "REPRO_COMPILE_CACHE_DIR"

# jax config keys -> pinned values: persist every entry, however small or
# fast-compiling (the serving buckets are sub-second compiles on CPU).
_CACHE_KEYS = (
    ("jax_persistent_cache_min_compile_time_secs", 0.0),
    ("jax_persistent_cache_min_entry_size_bytes", -1),
)

_state: dict = {"dir": None, "info": None}   # idempotence memo


def cache_supported() -> bool:
    """Best-effort probe: does this jax/backend pair implement the
    persistent compilation cache? CPU/GPU/TPU backends do on the pinned
    jax; interpret-mode Pallas and exotic plugin backends may not."""
    try:
        from jax._src import compilation_cache  # noqa: F401
    except Exception:
        return False
    return jax.default_backend() in ("cpu", "gpu", "cuda", "rocm", "tpu")


def configure_compile_cache(cache_dir: Optional[str] = None) -> dict:
    """Enable jax's persistent compilation cache if opted in.

    ``cache_dir`` overrides the ``REPRO_COMPILE_CACHE_DIR`` env var; with
    neither set this is a no-op (the default — serving behaviour is
    unchanged unless a deployment opts in). Safe to call repeatedly
    (every ``prewarm_compile`` does): reconfiguration only happens when
    the target directory changes.

    Returns the honesty record::

        {"enabled": bool, "cache_dir": str|None, "backend": str,
         "supported": bool, "detail": str}
    """
    target = cache_dir if cache_dir is not None else os.environ.get(ENV_VAR)
    if not target:
        return {
            "enabled": False, "cache_dir": None,
            "backend": jax.default_backend(), "supported": cache_supported(),
            "detail": f"{ENV_VAR} not set — persistent cache off (default)",
        }
    target = str(target)
    if _state["dir"] == target and _state["info"] is not None:
        return dict(_state["info"])

    supported = cache_supported()
    info = {
        "enabled": False, "cache_dir": target,
        "backend": jax.default_backend(), "supported": supported,
        "detail": "",
    }
    if not supported:
        info["detail"] = (
            "backend does not implement the persistent compilation cache; "
            "compiles stay in-process only"
        )
        _state.update(dir=target, info=dict(info))
        return info
    try:
        os.makedirs(target, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", target)
        for key, val in _CACHE_KEYS:
            jax.config.update(key, val)
        # the cache singleton latches on first compile: a process that
        # already compiled anything ignores a later cache_dir unless the
        # singleton is reset (observed on the pinned jax 0.4.x)
        from jax._src import compilation_cache

        compilation_cache.reset_cache()
    except Exception as exc:  # unknown config key on an older jax, ro-fs …
        info["detail"] = f"configuration failed: {exc!r}"
        _state.update(dir=target, info=dict(info))
        return info
    info["enabled"] = True
    info["detail"] = "persistent compilation cache active"
    _state.update(dir=target, info=dict(info))
    return info
