"""ShapeDtypeStruct input stand-ins for every (architecture x shape) cell.

``input_specs`` returns weak-type-correct, shardable specs with no device
allocation — the modality frontends of [vlm]/[audio] archs are stubbed here
as precomputed patch/frame embeddings, per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import LM, ModelConfig, ShapeConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs_for(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training / prefill batch: tokens (+ stub frontend embeddings)."""
    lf = cfg.frontend_len if cfg.frontend != "none" else 0
    s_tok = shape.seq_len - lf
    assert s_tok > 0, (cfg.name, shape.name)
    out = {"tokens": _sds((shape.global_batch, s_tok), jnp.int32)}
    if lf:
        out["frontend_embeds"] = _sds(
            (shape.global_batch, lf, cfg.d_model), cfg.dtype
        )
    return out


def decode_specs_for(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Any, Dict]:
    """(cache_specs, token_specs) for one serve_step with a seq_len-deep cache."""
    model = LM(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, prefilled=shape.seq_len - 1)
    )
    tokens = {"tokens": _sds((shape.global_batch, 1), jnp.int32)}
    return cache, tokens


def param_specs_for(cfg: ModelConfig) -> Any:
    model = LM(cfg)
    return jax.eval_shape(model.init, jax.random.key(0))


def opt_specs_for(param_shapes: Any) -> Any:
    from repro.training import adamw_init

    return jax.eval_shape(adamw_init, param_shapes)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Everything the step function for this cell consumes (params excluded)."""
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_specs_for(cfg, shape)}
    cache, tokens = decode_specs_for(cfg, shape)
    return {"cache": cache, "batch": tokens}
