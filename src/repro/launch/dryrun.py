import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell with ShapeDtypeStruct inputs (no allocation), then extract
memory_analysis / cost_analysis / collective bytes for the roofline.

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count on first init). Run as:

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out benchmarks/results/dryrun
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.distributed.sharding import AxisRules, param_specs, batch_specs, cache_specs, use_rules
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.roofline import (
    analytic_bytes,
    analytic_flops,
    analytic_memory,
    hlo_collective_bytes,
    model_flops,
    roofline_terms,
    wire_bytes_per_chip,
    xla_cost_analysis,
)
from repro.launch.specs import batch_specs_for, decode_specs_for
from repro.models import LM, SHAPES, shape_applicable
from repro.training import OptimizerConfig, adamw_init, make_train_step


def _mem_summary(mem) -> Dict[str, float]:
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "peak_memory_in_bytes",
    )
    return {k: float(getattr(mem, k, 0) or 0) for k in keys}


def dryrun_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    rules_overrides: Optional[Dict[str, Any]] = None,
    cfg_overrides: Optional[Dict[str, Any]] = None,
    verbose: bool = True,
) -> Dict[str, Any]:
    """Lower+compile one cell; returns the roofline record.

    ``rules_overrides`` remaps logical sharding axes and ``cfg_overrides``
    patches ModelConfig fields — the two knobs the perf iterations turn.
    """
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "kind": shape.kind,
    }
    if not shape_applicable(cfg, shape):
        rec["skipped"] = "full-attention arch: long_500k requires sub-quadratic attention"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = AxisRules(mesh, rules_overrides or {})
    model = LM(cfg)

    t0 = time.time()
    param_shapes = jax.eval_shape(model.init, jax.random.key(0))
    p_sh = param_specs(param_shapes, rules)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, param_shapes)
        o_sh = param_specs(opt_shapes, rules)
        batch = batch_specs_for(cfg, shape)
        b_sh = batch_specs(batch, rules)
        step = make_train_step(model, OptimizerConfig())
        with use_rules(rules), mesh:
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(  # thriftlint: ignore[recompile-risk] AOT driver: compiles exactly one cell per call; the wrapper is consumed by .lower() immediately
                param_shapes, opt_shapes, batch
            )
    elif shape.kind == "prefill":
        batch = batch_specs_for(cfg, shape)
        b_sh = batch_specs(batch, rules)

        def prefill_step(params, b):
            return model.prefill(params, b["tokens"], b.get("frontend_embeds"))

        # The prefill OUTPUT cache must carry the decode cache sharding
        # (batch x time) or it dominates per-chip memory at 32k.
        out_shapes = jax.eval_shape(prefill_step, param_shapes, batch)
        logits_sh = rules.sharding_for(out_shapes[0].shape, ("batch", "vocab"))
        cache_sh = cache_specs(out_shapes[1], rules)
        with use_rules(rules), mesh:
            lowered = jax.jit(  # thriftlint: ignore[recompile-risk] AOT driver: one lower+compile per cell is the measurement itself
                prefill_step, in_shardings=(p_sh, b_sh),
                out_shardings=(logits_sh, cache_sh),
            ).lower(param_shapes, batch)
    else:  # decode
        cache_shapes, tokens = decode_specs_for(cfg, shape)
        c_sh = cache_specs(cache_shapes, rules)
        b_sh = batch_specs(tokens, rules)

        def serve_step(params, cache, b):
            return model.decode_step(params, cache, b["tokens"])

        with use_rules(rules), mesh:
            lowered = jax.jit(serve_step, in_shardings=(p_sh, c_sh, b_sh)).lower(  # thriftlint: ignore[recompile-risk] AOT driver: wrapper consumed by .lower() immediately, no cache to churn
                param_shapes, cache_shapes, tokens
            )
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _mem_summary(compiled.memory_analysis())
    cost = xla_cost_analysis(compiled)
    text = compiled.as_text()
    colls = hlo_collective_bytes(text)

    fl = analytic_flops(cfg, shape)
    by = analytic_bytes(cfg, shape)
    mf = model_flops(cfg, shape)
    wire = wire_bytes_per_chip(colls)
    terms = roofline_terms(
        fl["total"], by["total"], colls["total"], chips, HW, wire_per_chip=wire
    )
    dp = chips // mesh.shape["model"]
    amem = analytic_memory(cfg, shape, dp=dp, tp=mesh.shape["model"])

    rec.update(
        {
            "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem,
            "analytic_memory": amem,
            "fits_hbm": amem["total"] <= HW["hbm_bytes"],
            "xla_flops_body_once": float(cost.get("flops", -1.0)),
            "xla_bytes_body_once": float(cost.get("bytes accessed", -1.0)),
            "analytic_flops_total": fl["total"],
            "analytic_flops_fwd": fl["fwd"],
            "analytic_bytes": by["total"],
            "model_flops": mf,
            "useful_flops_ratio": mf / fl["total"] if fl["total"] else 0.0,
            "collective_bytes": colls,
            "roofline": terms,
            "hlo_bytes": len(text),
        }
    )
    if verbose:
        print(
            f"[{arch} x {shape_name} x {mesh_name}] compile={t_compile:.1f}s "
            f"mem/chip={amem['total']/1e9:.2f}GB "
            f"fits={rec['fits_hbm']} "
            f"compute={terms['compute_s']*1e3:.2f}ms mem={terms['memory_s']*1e3:.2f}ms "
            f"coll={terms['collective_s']*1e3:.2f}ms -> {terms['bottleneck']}"
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run every (arch x shape) cell")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"skip {tag} (exists)")
            continue
        try:
            rec = dryrun_cell(a, s, multi_pod=mp)
        except Exception as e:  # a failure here is a bug in the system
            rec = {
                "arch": a, "shape": s,
                "mesh": "2x16x16" if mp else "16x16",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(f"FAIL {tag}: {rec['error']}")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=float)


if __name__ == "__main__":
    main()
