"""Training launcher: real steps on the local device(s), or distributed
under a forced-device debug mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \\
        --steps 50 --batch 8 --seq 64

Full configs are for the dry-run / real clusters; on this CPU container use
``--smoke`` (the reduced same-family config). Checkpoints + restart come
from repro.checkpoint; fault handling from repro.distributed.fault.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config, list_archs
from repro.data import DataPipeline
from repro.distributed.fault import FaultTolerantDriver
from repro.models import LM
from repro.training import CompressionConfig, OptimizerConfig, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--compress", default="none", choices=["none", "int8", "topk"])
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    comp = CompressionConfig(codec=args.compress)
    params, opt = init_train_state(model, jax.random.key(0), comp)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[{cfg.name}] {n/1e6:.2f}M params, {args.steps} steps")

    step_fn = jax.jit(  # thriftlint: ignore[recompile-risk] launcher main() runs once per process; the wrapper outlives the whole training loop
        make_train_step(
            model,
            OptimizerConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps),
            comp,
        )
    )

    rng = np.random.default_rng(0)
    lf = cfg.frontend_len if cfg.frontend != "none" else 0

    def make_batch(step):
        b = {"tokens": rng.integers(0, cfg.vocab_size, (args.batch, args.seq - lf)).astype(np.int32)}
        if lf:
            b["frontend_embeds"] = rng.normal(0, 1, (args.batch, lf, cfg.d_model)).astype(np.float32)
        return b

    pipe = DataPipeline(make_batch)
    mgr = CheckpointManager(args.ckpt + "/" + cfg.name)
    driver = FaultTolerantDriver(mgr, save_every=args.save_every)
    state, start = driver.restore({"params": params, "opt": opt})
    params, opt = state["params"], state["opt"]
    if start:
        print(f"resumed from step {start - 1}")

    t0 = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt, m = step_fn(params, opt, batch)
        driver.maybe_save(s, {"params": params, "opt": opt})
        if s % 10 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(m['loss']):.4f} lr {float(m['lr']):.2e}")
    pipe.close()
    print(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
