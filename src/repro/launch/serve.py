"""Serving launcher: stand up an oracle (or freshly-trained) pool, calibrate
success probabilities, and serve a stream of classification queries through
the continuous-batching front-end under a per-query budget.

Requests arrive as a Poisson process at ``--qps`` (0 = as fast as possible),
are admitted by the scheduler's arrival/SLO-aware flush policy, ride the
pipelined budget-group waves, and complete through per-request futures; the
run reports throughput, p50/p99 latency, accuracy, realized cost and which
data plane (speculative jit vs compacting reference) served the traffic.

    PYTHONPATH=src python -m repro.launch.serve --queries 500 --budget 1e-4
    PYTHONPATH=src python -m repro.launch.serve --qps 20000 --metered
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import BatchScheduler, OracleArm, PoolEngine, ThriftRouter


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arms", type=int, default=12)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--queries", type=int, default=500)
    ap.add_argument("--budget", type=float, default=1e-4)
    ap.add_argument("--history", type=int, default=2000)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="Poisson arrival rate; 0 = open the floodgates")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request completion SLO fed to the flush policy")
    ap.add_argument("--metered", action="store_true",
                    help="mark every arm as a metered API so the speculation "
                         "switch picks the compacting reference plane")
    args = ap.parse_args()

    wl = OracleWorkload(
        num_classes=args.classes, num_clusters=args.clusters, num_arms=args.arms
    )
    engine = PoolEngine(
        [OracleArm(f"llm-{i}", wl, i, metered=args.metered)
         for i in range(args.arms)]
    )
    T, emb, _ = wl.response_table(args.history)
    assign, _ = kmeans(emb, args.clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    router = ThriftRouter(engine, est, num_classes=args.classes)
    sched = BatchScheduler(
        router, max_batch=args.max_batch, max_wait_s=args.max_wait_ms / 1e3
    )
    sched.prewarm(budgets=[args.budget])

    rng = np.random.default_rng(1)
    cid, qemb, labels = wl.sample_queries(args.queries, rng)
    payloads = np.column_stack([cid, labels])
    slo_s = None if args.slo_ms is None else args.slo_ms / 1e3

    t0 = time.monotonic()
    blocks = []          # (BlockFuture, label slice) in submission order
    if args.qps <= 0:
        blocks.append((sched.submit_many(payloads, qemb, args.budget,
                                         slo_s=slo_s), labels))
        sched.drain()
    else:
        # Poisson arrivals: exponential gaps, submitted in the bursts the
        # wall clock actually delivers (columnar blocks, like a real front
        # door batching its accept loop).
        arrivals = t0 + np.cumsum(
            rng.exponential(1.0 / args.qps, args.queries)
        )
        sent = 0
        while sent < args.queries:
            now = time.monotonic()
            due = int(np.searchsorted(arrivals, now, side="right"))
            if due > sent:
                blocks.append((
                    sched.submit_many(
                        payloads[sent:due], qemb[sent:due], args.budget,
                        slo_s=slo_s, arrival_s=arrivals[sent:due],
                    ),
                    labels[sent:due],
                ))
                sent = due
            sched.pump()
        sched.drain()
    dt = time.monotonic() - t0

    preds = np.concatenate([b.predictions for b, _ in blocks])
    lab = np.concatenate([l for _, l in blocks])
    cost = np.concatenate([b.costs for b, _ in blocks])
    n = int(sched.stats["completed"])
    lat = sched.latency_stats()
    st = sched.stats  # plan + speculation counters
    print(
        f"served {n} queries in {dt:.2f}s ({n/max(dt,1e-9):.0f} qps) | "
        f"p50 {1e3*lat.get('p50_s', 0):.2f}ms p99 {1e3*lat.get('p99_s', 0):.2f}ms | "
        f"accuracy {(preds == lab).mean():.3f} | mean cost {cost.mean():.3e} "
        f"(budget {args.budget:.0e}) | "
        f"planes jit={st['spec_jit']} ref={st['spec_reference']} | "
        f"flushes {st['flushes']} groups {st['batches']} | "
        f"plan hit/miss {st['plan_hits']}/{st['plan_misses']} "
        f"(prefetched {st['plan_prefetches']}) | "
        f"stragglers={sched.mitigator.stragglers()}"
    )


if __name__ == "__main__":
    main()
