"""Serving launcher: stand up an oracle (or freshly-trained) pool, calibrate
success probabilities, and serve a stream of classification queries through
the continuous-batching front-end under a per-query budget.

Requests arrive as a Poisson process at ``--qps`` (0 = as fast as possible),
are admitted by the scheduler's arrival/SLO-aware flush policy, ride the
pipelined budget-group waves, and complete through per-request futures; the
run reports throughput, p50/p99 latency, accuracy, realized cost and which
data plane (speculative jit vs compacting reference) served the traffic.

With ``--drift-after N`` the demo exercises the online loop end to end:
after N served queries the truth drifts (the served plans' arms degrade for
half the clusters), ground-truth labels stream back per completed block,
and the drift-invalidated clusters replan as ONE batched-planner dispatch
at the next admission boundary. ``--probe-rate r`` additionally probes one
currently-unplanned arm on ~r of feedback-eligible requests, so recovered
arms re-enter the estimates.

``--fault-rate r`` attaches a FaultPolicy to the pool: the listed
``--fault-arms`` (default: every arm) time out / error / degrade at the
given per-cell rates, failed wave slots re-route in-wave to the plan's
next-best affordable arm, and the failure evidence folds into the
estimator so flaky arms replan away (combine with ``--drift-after`` or
``--probe-rate`` to enable the feedback loop).

    PYTHONPATH=src python -m repro.launch.serve --queries 500 --budget 1e-4
    PYTHONPATH=src python -m repro.launch.serve --qps 20000 --metered
    PYTHONPATH=src python -m repro.launch.serve --queries 2000 \
        --drift-after 500 --probe-rate 0.05
    PYTHONPATH=src python -m repro.launch.serve --queries 2000 \
        --probe-rate 0.05 --fault-rate 0.3 --fault-arms 0,1
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.distributed.fault import FaultPolicy
from repro.serving import (
    BatchScheduler,
    FeedbackLog,
    OracleArm,
    PoolEngine,
    ReplicaSet,
    ThriftRouter,
    configure_compile_cache,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arms", type=int, default=12)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--queries", type=int, default=500)
    ap.add_argument("--budget", type=float, default=1e-4)
    ap.add_argument("--history", type=int, default=2000)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through an R-replica ReplicaSet (sharded "
                         "admission, per-device overlapped or fused waves, "
                         "shard-merged feedback); 1 = the plain "
                         "BatchScheduler path")
    ap.add_argument("--devices", type=int, default=0,
                    help="force this many host (CPU) XLA devices so the "
                         "replica plane can overlap per-device wave "
                         "programs; 0 = whatever the process already has. "
                         "Must take effect before JAX initializes its "
                         "backend, so it is applied at the top of main()")
    ap.add_argument("--placement", type=str, default="auto",
                    choices=["auto", "overlapped", "fused", "inline"],
                    help="replica wave placement (auto: overlapped when "
                         ">1 device, else fused; see ReplicaSet)")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="Poisson arrival rate; 0 = open the floodgates")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request completion SLO fed to the flush policy")
    ap.add_argument("--metered", action="store_true",
                    help="mark every arm as a metered API so the speculation "
                         "switch picks the compacting reference plane")
    ap.add_argument("--drift-after", type=int, default=0,
                    help="inject truth drift after this many served queries "
                         "(0 = no drift); enables the feedback loop and "
                         "batched drift replans")
    ap.add_argument("--probe-rate", type=float, default=0.0,
                    help="exploration probe rate (fraction of requests that "
                         "invoke one unplanned arm); enables feedback")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="per-cell fault rate injected on --fault-arms "
                         "(split 50/30/20 across timeout/error/degrade); "
                         "0 = no fault injection")
    ap.add_argument("--fault-arms", type=str, default="",
                    help="comma-separated arm indices the fault policy "
                         "targets (default: all arms)")
    ap.add_argument("--compile-cache-dir", type=str, default=None,
                    help="persist XLA executables to this directory so a "
                         "restarted process loads its wave/planner compile "
                         "buckets from disk instead of re-lowering "
                         "(default: $REPRO_COMPILE_CACHE_DIR, else off)")
    args = ap.parse_args()

    cache_info = configure_compile_cache(args.compile_cache_dir)
    if cache_info["cache_dir"] is not None:
        print(
            f"compile cache: enabled={cache_info['enabled']} "
            f"dir={cache_info['cache_dir']} backend={cache_info['backend']} "
            f"supported={cache_info['supported']} — {cache_info['detail']}"
        )

    if args.devices > 0:
        # must land before the first backend touch (jax.devices() inside
        # ReplicaSet); module imports alone don't initialize the backend
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.devices}"
        ).strip()

    wl = OracleWorkload(
        num_classes=args.classes, num_clusters=args.clusters, num_arms=args.arms
    )
    engine = PoolEngine(
        [OracleArm(f"llm-{i}", wl, i, metered=args.metered)
         for i in range(args.arms)]
    )
    if args.fault_rate > 0:
        targets = (
            [int(a) for a in args.fault_arms.split(",") if a.strip()]
            if args.fault_arms else list(range(args.arms))
        )
        engine.fault_policy = FaultPolicy(
            args.arms, args.classes, seed=7
        ).set_arms(
            targets,
            timeout=0.5 * args.fault_rate,
            error=0.3 * args.fault_rate,
            degrade=0.2 * args.fault_rate,
        )
    T, emb, _ = wl.response_table(args.history)
    assign, _ = kmeans(emb, args.clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    router = ThriftRouter(engine, est, num_classes=args.classes)
    online = args.drift_after > 0 or args.probe_rate > 0
    feedback = (
        FeedbackLog(est, probe_rate=args.probe_rate) if online else None
    )
    if args.replicas > 1:
        sched = ReplicaSet(
            router, replicas=args.replicas, max_batch=args.max_batch,
            max_wait_s=args.max_wait_ms / 1e3, feedback=feedback,
            placement=None if args.placement == "auto" else args.placement,
        )
        stragglers = sched.stragglers
    else:
        sched = BatchScheduler(
            router, max_batch=args.max_batch,
            max_wait_s=args.max_wait_ms / 1e3, feedback=feedback,
        )
        stragglers = sched.mitigator.stragglers
    sched.prewarm(budgets=[args.budget])

    rng = np.random.default_rng(1)
    cid, qemb, labels = wl.sample_queries(args.queries, rng)
    payloads = np.column_stack([cid, labels])
    slo_s = None if args.slo_ms is None else args.slo_ms / 1e3

    drifted = [False]

    def maybe_drift(served: int) -> None:
        """The mid-stream shift: degrade the served plans' arms for half
        the clusters once ``--drift-after`` queries have gone out."""
        if not args.drift_after or drifted[0] or served < args.drift_after:
            return
        drifted[0] = True
        targets = list(range(max(1, args.clusters // 2)))
        for t in targets:
            wl.drift_arms(
                router.plans.plan(t, args.budget).order, 0.30, clusters=[t]
            )

    t0 = time.monotonic()
    blocks = []          # (BlockFuture, label slice) in submission order
    if args.qps <= 0:
        # feedback/drift need mid-stream boundaries: chunk the floodgates
        # submission so labels fold and replans fire between chunks
        step = args.max_batch if online else args.queries
        for s in range(0, args.queries, max(1, step)):
            e = min(args.queries, s + max(1, step))
            blk = sched.submit_many(payloads[s:e], qemb[s:e], args.budget,
                                    slo_s=slo_s)
            blocks.append((blk, labels[s:e]))
            sched.drain()
            if online:
                sched.record_outcomes(blk.request_ids, labels[s:e])
            maybe_drift(e)
        if online:
            sched.apply_feedback()   # fold the final chunk's labels too
    else:
        # Poisson arrivals: exponential gaps, submitted in the bursts the
        # wall clock actually delivers (columnar blocks, like a real front
        # door batching its accept loop).
        arrivals = t0 + np.cumsum(
            rng.exponential(1.0 / args.qps, args.queries)
        )
        sent = 0
        recorded = 0
        while sent < args.queries:
            now = time.monotonic()
            due = int(np.searchsorted(arrivals, now, side="right"))
            if due > sent:
                blocks.append((
                    sched.submit_many(
                        payloads[sent:due], qemb[sent:due], args.budget,
                        slo_s=slo_s, arrival_s=arrivals[sent:due],
                    ),
                    labels[sent:due],
                ))
                sent = due
            sched.pump()
            if online:
                while recorded < len(blocks) and blocks[recorded][0].done():
                    blk, lab_r = blocks[recorded]
                    sched.record_outcomes(blk.request_ids, lab_r)
                    recorded += 1
                maybe_drift(int(sched.stats["completed"]))
        sched.drain()
        if online:
            for blk, lab_r in blocks[recorded:]:
                sched.record_outcomes(blk.request_ids, lab_r)
            # no further admission will fold these: absorb them now so the
            # drift -> batched-replan counters reflect the whole stream
            sched.apply_feedback()
    dt = time.monotonic() - t0

    preds = np.concatenate([b.predictions for b, _ in blocks])
    lab = np.concatenate([l for _, l in blocks])
    cost = np.concatenate([b.costs for b, _ in blocks])
    n = int(sched.stats["completed"])
    lat = sched.latency_stats()
    st = sched.stats  # plan + speculation counters
    print(
        f"served {n} queries in {dt:.2f}s ({n/max(dt,1e-9):.0f} qps) | "
        f"p50 {1e3*lat.get('p50_s', 0):.2f}ms p99 {1e3*lat.get('p99_s', 0):.2f}ms | "
        f"accuracy {(preds == lab).mean():.3f} | mean cost {cost.mean():.3e} "
        f"(budget {args.budget:.0e}) | "
        f"planes jit={st['spec_jit']} ref={st['spec_reference']} | "
        f"flushes {st['flushes']} groups {st['batches']} | "
        f"plan hit/miss {st['plan_hits']}/{st['plan_misses']} "
        f"(prefetched {st['plan_prefetches']}) | "
        f"stragglers={stragglers()}"
    )
    if args.replicas > 1:
        print(
            f"replica plane: R={st['replicas']} on "
            f"{st['replica_devices']} device(s) [{sched.placement}] | "
            f"overlapped dispatches {st['replica_overlapped']} "
            f"({st['replica_overlapped_rows']} rows) | fused dispatches "
            f"{st['replica_fused']} ({st['replica_fused_rows']} rows) | "
            f"affinity spills {st['replica_spills']}"
        )
    if args.fault_rate > 0:
        print(
            f"fault plane: rate {args.fault_rate:.2f} on "
            f"{len(targets)} arm(s) | attempted failures "
            f"{st.get('degradation_failures', 0)} "
            f"(degraded {st.get('degradation_degraded', 0)}) over "
            f"{st.get('degradation_routes', 0)} faulted routes"
            + ("" if online else
               " | (enable --probe-rate/--drift-after to fold failures "
               "into the estimator)")
        )
    if online:
        tail = preds[args.drift_after:] if args.drift_after else preds
        tail_lab = lab[args.drift_after:] if args.drift_after else lab
        print(
            f"online loop: labels {st['feedback_labels']} "
            f"drifts {st['feedback_drifts']} | batched replans "
            f"{st['plan_batch_replans']} rebuilding {st['plan_batch_replanned']} "
            f"plans (stale dropped {st['plan_stale_dropped']}) | probes "
            f"{st['feedback_probes']} | post-drift accuracy "
            f"{(tail == tail_lab).mean():.3f}"
        )


if __name__ == "__main__":
    main()
