"""Serving launcher: stand up an oracle (or freshly-trained) pool, calibrate
success probabilities, and route a stream of classification queries through
the ThriftLLM router under a per-query budget.

    PYTHONPATH=src python -m repro.launch.serve --queries 500 --budget 1e-4
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import BatchScheduler, OracleArm, PoolEngine, Request, ThriftRouter


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arms", type=int, default=12)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=6)
    ap.add_argument("--queries", type=int, default=500)
    ap.add_argument("--budget", type=float, default=1e-4)
    ap.add_argument("--history", type=int, default=2000)
    ap.add_argument("--max-batch", type=int, default=64)
    args = ap.parse_args()

    wl = OracleWorkload(
        num_classes=args.classes, num_clusters=args.clusters, num_arms=args.arms
    )
    engine = PoolEngine([OracleArm(f"llm-{i}", wl, i) for i in range(args.arms)])
    T, emb, _ = wl.response_table(args.history)
    assign, _ = kmeans(emb, args.clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    router = ThriftRouter(engine, est, num_classes=args.classes)
    sched = BatchScheduler(router, max_batch=args.max_batch, max_wait_s=0.0)

    rng = np.random.default_rng(1)
    cid, qemb, labels = wl.sample_queries(args.queries, rng)
    t0 = time.time()
    for i in range(args.queries):
        sched.submit(Request(payload=(cid[i], labels[i]), embedding=qemb[i], budget=args.budget))

    n, correct, cost = 0, 0, 0.0
    results = []
    while sched.ready() or (n < args.queries and sched._queue):
        for group, res in sched.flush():
            for r, pred, c in zip(group, res.predictions, res.costs):
                correct += int(pred == r.payload[1])
                cost += c
                n += 1
    dt = time.time() - t0
    print(
        f"routed {n} queries in {dt:.2f}s ({n/max(dt,1e-9):.0f} qps) | "
        f"accuracy {correct/max(n,1):.3f} | mean cost {cost/max(n,1):.3e} "
        f"(budget {args.budget:.0e}) | stragglers={sched.mitigator.stragglers()}"
    )


if __name__ == "__main__":
    main()
