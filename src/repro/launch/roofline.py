"""Roofline accounting from the compiled dry-run artifact.

Two complementary sources (see EXPERIMENTS.md §Roofline for why both):

1. **HLO parsing** (`hlo_collective_bytes`): walks ``compiled.as_text()``,
   builds the computation call graph, extracts while-loop trip counts from
   loop-condition constants, and sums collective operand bytes with the
   correct loop multipliers. XLA's own ``cost_analysis()`` counts while
   bodies ONCE (verified empirically), which would undercount a
   scan-over-layers model by ~num_layers — the multiplier fixes that.

2. **Analytic implementation counting** (`analytic_flops` / `analytic_bytes`):
   exact multiply-add counts of the einsums this framework emits, including
   deliberate baseline waste (masked causal blocks = ~2x attention FLOPs,
   MoE capacity padding, remat recompute). Validated against XLA
   cost_analysis on small *unrolled* configs in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

from repro.models import ModelConfig, ShapeConfig
from repro.models.init import padded_vocab
from repro.models.model import block_window

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Version-proof reader for ``compiled.cost_analysis()``.

    Across jaxlib releases this API has returned a flat dict of counters, a
    *list* of per-computation dicts (so ``cost_analysis()["flops"]`` raises
    ``TypeError: list indices must be integers``), or None. Normalize to one
    flat {counter: float} mapping: dicts pass through, list entries are
    summed key-wise (the common single-entry list is therefore a
    passthrough too). Every read of ``cost_analysis()`` in this repo must go
    through this shim.
    """
    analysis = compiled.cost_analysis()
    if analysis is None:
        return {}
    if isinstance(analysis, dict):
        return {k: float(v) for k, v in analysis.items()
                if isinstance(v, (int, float))}
    out: Dict[str, float] = {}
    for entry in analysis:
        for k, v in dict(entry).items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + float(v)
    return out


def _shape_bytes(type_str: str) -> int:
    """bytes of an HLO type string; tuples sum their elements."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result_bytes: int
    operands: List[str]
    callees: List[Tuple[str, str]]   # (attr, computation) e.g. ("body", "wide.region_0")


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},:#\s*]+?))\s*"
    r"([\w\-]+)\((.*)$"
)
_CALLEE_RE = re.compile(r"(to_apply|condition|body|calls)=\{?%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_HEADER_START_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*[su](?:8|16|32|64)\[\]\s*constant\((\d+)\)")


def _match_header(line: str) -> Optional[str]:
    """Computation header: ``%name (params...) -> type {`` with possibly
    nested parens in tuple-typed parameters."""
    if "=" in line.split("(")[0]:
        return None
    m = _HEADER_START_RE.match(line)
    if not m:
        return None
    # balance parens from the first '('
    start = line.index("(")
    depth = 0
    end = -1
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    if end < 0:
        return None
    rest = line[end + 1 :]
    if "->" in rest and rest.rstrip().endswith("{"):
        return m.group(1)
    return None


def parse_hlo(text: str):
    """Returns (computations, constants): computation name -> {instr -> _Instr}
    and computation name -> {instr -> int scalar constant}."""
    comps: Dict[str, Dict[str, _Instr]] = {}
    consts: Dict[str, Dict[str, int]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        header = _match_header(line)
        if header:
            cur = header
            comps[cur] = {}
            consts[cur] = {}
            continue
        if cur is None:
            continue
        cm = _CONST_RE.match(line.strip())
        if cm:
            consts[cur][cm.group(1)] = int(cm.group(2))
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        # operand section: up to the closing paren at depth 0
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str, attr_str = rest[:end], rest[end:]
        operands = _OPERAND_RE.findall(operand_str)
        callees = _CALLEE_RE.findall(attr_str)
        comps[cur][name] = _Instr(name, op, _shape_bytes(type_str), operands, callees)
    return comps, consts


def hlo_collective_bytes(text: str) -> Dict[str, float]:
    """Sum collective operand bytes with while-loop multipliers.

    Trip counts come from the largest scalar integer constant reachable from
    the loop-condition computation (XLA lowers lax.scan to
    ``while (i < N)`` with N in the condition or a wrapped compare called by
    it). Returns per-kind byte totals plus 'total' and 'unscoped_while'
    (loops whose trip count could not be parsed — counted once).
    """
    comps, consts = parse_hlo(text)
    entry = next((c for c in comps if "main" in c), None) or next(iter(comps))
    out = {k: 0.0 for k in COLLECTIVES}
    unscoped = [0]

    def transitive_consts(comp_name: str, seen=None) -> List[int]:
        seen = seen if seen is not None else set()
        if comp_name in seen or comp_name not in comps:
            return []
        seen.add(comp_name)
        vals = list(consts.get(comp_name, {}).values())
        for ins in comps[comp_name].values():
            for _, cal in ins.callees:
                vals += transitive_consts(cal, seen)
        return vals

    seen_stack: List[str] = []

    def walk(comp_name: str, mult: float):
        if comp_name in seen_stack or comp_name not in comps:
            return
        seen_stack.append(comp_name)
        instrs = comps[comp_name]
        for ins in instrs.values():
            if ins.op in COLLECTIVES:
                ob = sum(
                    instrs[o].result_bytes for o in ins.operands if o in instrs
                )
                if ob == 0:
                    ob = ins.result_bytes
                out[ins.op] += ob * mult
            if ins.op == "while":
                cond = next((c for a, c in ins.callees if a == "condition"), None)
                body = next((c for a, c in ins.callees if a == "body"), None)
                vals = transitive_consts(cond) if cond else []
                tc = max(vals) if vals else 0
                if tc <= 0:
                    tc = 1
                    unscoped[0] += 1
                if body:
                    walk(body, mult * tc)
                if cond:
                    walk(cond, mult * tc)
            else:
                for _, cal in ins.callees:
                    walk(cal, mult)
        seen_stack.pop()

    walk(entry, 1.0)
    res = {k: v for k, v in out.items()}
    res["total"] = sum(out.values())
    res["unscoped_while"] = float(unscoped[0])
    return res


# ---------------------------------------------------------------------------
# Analytic implementation FLOPs / bytes
# ---------------------------------------------------------------------------


def _attn_flops(cfg: ModelConfig, Bt: float, S: float, T: float, blocked: bool) -> float:
    """Forward attention flops for Bt sequences of S queries against T keys.

    The blocked baseline visits every (padded) KV block and masks, so its
    score/value flops use the full T (the deliberate ~2x causal waste)."""
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    D = cfg.d_model
    fl = 2 * Bt * S * D * (H + 2 * G) * hd          # qkv projections
    fl += 6 * Bt * S * (H + G) * hd                 # rope
    fl += 2 * Bt * S * T * H * hd                   # scores
    fl += 5 * Bt * S * T * H                        # softmax-ish
    fl += 2 * Bt * S * T * H * hd                   # prob @ v
    fl += 2 * Bt * S * H * hd * D                   # out proj
    return fl


def _mlp_flops(cfg: ModelConfig, tokens: float) -> float:
    n_mats = 3 if cfg.mlp_variant == "swiglu" else 2
    return 2 * n_mats * tokens * cfg.d_model * cfg.d_ff + 4 * tokens * cfg.d_ff


def _moe_flops(cfg: ModelConfig, tokens: float) -> float:
    E, k = cfg.num_experts, cfg.experts_per_token
    C = max(8.0, math.ceil(tokens * k / E * cfg.expert_capacity_factor / 8) * 8)
    n_mats = 3 if cfg.mlp_variant == "swiglu" else 2
    fl = 2 * tokens * cfg.d_model * E               # router
    fl += 2 * n_mats * (E * C) * cfg.d_model * cfg.d_ff
    fl += 2 * tokens * k * cfg.d_model              # combine
    return fl


def _ssm_flops(cfg: ModelConfig, tokens: float) -> float:
    D, Din, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    fl = 2 * tokens * D * 2 * Din                   # in_proj
    fl += 2 * cfg.ssm_conv * tokens * Din           # conv
    fl += 2 * tokens * Din * (R + 2 * N)            # x_proj
    fl += 2 * tokens * R * Din                      # dt_proj
    fl += 8 * tokens * Din * N                      # recurrence + contraction
    fl += 6 * tokens * Din                          # gates
    fl += 2 * tokens * Din * D                      # out_proj
    return fl


def _rec_flops(cfg: ModelConfig, tokens: float) -> float:
    D, Dr = cfg.d_model, cfg.rnn_width
    fl = 2 * tokens * D * 2 * Dr                    # wy, wx
    fl += 2 * cfg.ssm_conv * tokens * Dr            # conv
    fl += 2 * 2 * tokens * Dr * Dr                  # gates
    fl += 12 * tokens * Dr                          # rg-lru scan
    fl += 2 * tokens * Dr * D                       # out proj
    return fl + _mlp_flops(cfg, tokens)


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Global forward / total FLOPs of this implementation for one step."""
    Bt = float(shape.global_batch)
    win = block_window(cfg)
    if shape.kind in ("train", "prefill"):
        S = float(shape.seq_len)
        # baseline blocked attention visits all (masked) KV blocks; the
        # prefix-bucketed causal scan (perf iteration #1) visits a
        # (G+1)/(2G) fraction
        if cfg.attn_buckets > 0:
            G = cfg.attn_buckets
            T = S * (G + 1) / (2.0 * G)
        else:
            T = S
        decode = False
    else:
        S = 1.0
        T = float(min(win, shape.seq_len) if win else shape.seq_len)
        decode = True
    tokens = Bt * S

    fwd = 0.0
    for t in cfg.layer_types:
        if t == "attn":
            fwd += _attn_flops(cfg, Bt, S, T, not decode) + _mlp_flops(cfg, tokens)
        elif t == "moe":
            fwd += _attn_flops(cfg, Bt, S, T, not decode) + _moe_flops(cfg, tokens)
        elif t == "ssm":
            fwd += _ssm_flops(cfg, tokens)
        elif t == "rec":
            fwd += _rec_flops(cfg, tokens)
    V = padded_vocab(cfg)
    if shape.kind == "train":
        fwd += 2 * tokens * cfg.d_model * V + 4 * tokens * V       # logits+loss
    else:
        fwd += 2 * Bt * cfg.d_model * V                            # last-position logits

    if shape.kind == "train":
        total = (4.0 if cfg.remat else 3.0) * fwd
    else:
        total = fwd
    return {"fwd": fwd, "total": total}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Idealized MODEL_FLOPS: 6·N_active·tokens (train) / 2·N_active·tokens."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Global HBM traffic estimate (bytes) for one step of this impl."""
    n_params = cfg.param_count()
    p_bytes = 2.0 if cfg.dtype == "bfloat16" else 4.0
    Bt = float(shape.global_batch)
    D = cfg.d_model

    if shape.kind == "train":
        micro = max(cfg.num_microbatches, 1)
        passes = (3.0 if cfg.remat else 2.0)  # fwd (+recompute) + bwd
        traffic = n_params * p_bytes * (passes * micro + 1)      # reads + grad write
        traffic += n_params * 4.0 * 5                            # adam m,v,master r/w
        act = Bt * shape.seq_len * D * p_bytes
        traffic += act * len(cfg.layer_types) * 4                # per-layer act r/w
        return {"total": traffic}
    if shape.kind == "prefill":
        act = Bt * shape.seq_len * D * p_bytes
        return {"total": n_params * p_bytes + act * len(cfg.layer_types) * 4}
    # decode: params + full cache traffic dominate
    cache = _cache_bytes(cfg, shape, p_bytes)
    return {"total": n_params * p_bytes + cache, "cache": cache}


def analytic_memory(
    cfg: ModelConfig, shape: ShapeConfig, dp: int, tp: int
) -> Dict[str, float]:
    """Per-chip HBM residency model (bytes) under the baseline sharding:
    params/optimizer sharded over dp*tp (FSDP x TP), batch over dp,
    activations per microbatch, KV cache over dp (+ tp when heads divide).

    This is the fits-in-HBM criterion for the dry-run; XLA's CPU-backend
    memory_analysis is used only as a cross-check on argument sizes (its
    peak/temp fields are not meaningful for the partitioned module on CPU).
    """
    chips = dp * tp
    p_bytes = 2.0 if cfg.dtype == "bfloat16" else 4.0
    n = cfg.param_count()
    out: Dict[str, float] = {}
    out["params"] = n * p_bytes / chips

    if shape.kind == "train":
        out["opt_state"] = n * 12.0 / chips        # m, v, master fp32
        out["grads"] = n * 4.0 / chips             # fp32 accumulators
        micro = max(cfg.num_microbatches, 1)
        b_local = shape.global_batch / dp / micro
        carry = b_local * shape.seq_len * cfg.d_model * p_bytes
        out["act_carries"] = carry * cfg.num_layers
        # transient working set: widest per-layer intermediate (attention
        # block scores or mlp hidden), a few copies
        widest = max(
            b_local * shape.seq_len * max(cfg.d_ff, cfg.d_model * 2, 1) * p_bytes / tp,
            b_local * shape.seq_len * 512 * max(cfg.num_heads, 1) * 4.0 / tp,
        )
        V = padded_vocab(cfg)
        s_eff = min(cfg.loss_chunk, shape.seq_len) if cfg.loss_chunk else shape.seq_len
        logits = b_local * s_eff * V * 4.0 / tp
        out["transients"] = 3 * widest + logits
    elif shape.kind == "prefill":
        b_local = shape.global_batch / dp
        out["acts"] = 4 * b_local * shape.seq_len * cfg.d_model * p_bytes
        # output cache carries the decode sharding: batch over dp, time over tp
        out["cache_out"] = _cache_bytes(cfg, shape, p_bytes) / (dp * tp)
    else:
        # cache sharded over batch (dp, capped by B) and time/state (tp)
        shards = max(min(dp, shape.global_batch), 1) * tp
        out["cache"] = _cache_bytes(cfg, shape, p_bytes) / shards
        out["transients"] = out["params"] * 0.05
    out["total"] = sum(out.values())
    return out


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig, p_bytes: float) -> float:
    win = block_window(cfg)
    T = float(min(win, shape.seq_len) if win else shape.seq_len)
    B = float(shape.global_batch)
    # int8 KV (perf iteration #3): 1 byte/elem + one fp32 scale per (t, head)
    kv_bytes = 1.0 + 4.0 / max(cfg.head_dim, 1) if cfg.kv_quant == "int8" else p_bytes
    total = 0.0
    for t in cfg.layer_types:
        if t in ("attn", "moe"):
            total += B * T * cfg.num_kv_heads * cfg.head_dim * 2 * kv_bytes
        elif t == "ssm":
            total += B * cfg.d_inner * cfg.ssm_state * 4.0
            total += B * (cfg.ssm_conv - 1) * cfg.d_inner * p_bytes
        elif t == "rec":
            total += B * cfg.rnn_width * 4.0
            total += B * (cfg.ssm_conv - 1) * cfg.rnn_width * p_bytes
    return total


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


# Wire bytes pushed through EACH chip's links per byte of (per-device) HLO
# operand, by collective kind: ring all-reduce moves ~2x the operand (reduce-
# scatter phase + all-gather phase); all-gather moves ~the output (~operand
# here since we record operand bytes of the gather's input times the group,
# conservatively 1x); the rest ~1x.
WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def wire_bytes_per_chip(coll: Dict[str, float]) -> float:
    """Per-chip wire traffic from the parsed per-device operand byte sums.

    The SPMD module's operand shapes are per-device shards (or full global
    tensors when GSPMD involuntarily replicates — exactly the pathology this
    accounting surfaces), and each chip pushes ~WIRE_FACTOR x operand bytes
    through its own links, independent of chip count.
    """
    return sum(WIRE_FACTOR.get(k, 1.0) * v for k, v in coll.items() if k in WIRE_FACTOR)


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    collective_bytes: float,
    chips: int,
    hw: Dict[str, float],
    wire_per_chip: Optional[float] = None,
) -> Dict[str, float]:
    """The three roofline terms in seconds.

    ``collective_bytes`` follows the assignment's convention (global bytes,
    divided by aggregate chips x link bandwidth); when ``wire_per_chip`` is
    supplied (per-chip wire traffic from :func:`wire_bytes_per_chip`) the
    collective term is wire_per_chip / link_bw — the physically meaningful
    form, equal to the assignment's formula with
    collective_bytes = wire_per_chip * chips.
    """
    compute_s = flops / (chips * hw["peak_flops"])
    memory_s = hbm_bytes / (chips * hw["hbm_bw"])
    if wire_per_chip is not None:
        collective_s = wire_per_chip / hw["ici_bw"]
    else:
        collective_s = collective_bytes / (chips * hw["ici_bw"])
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom
    terms["step_s_lower_bound"] = max(compute_s, memory_s, collective_s)
    return terms
