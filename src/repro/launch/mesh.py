"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256-chip single pod, or 2x16x16 = 512-chip two-pod mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU integration tests (requires forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e-class hardware constants for the roofline analysis.
HW = {
    "peak_flops": 197e12,      # bf16 FLOP/s per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link (~per chip aggregate used)
    "hbm_bytes": 16e9,         # HBM capacity per chip
}
