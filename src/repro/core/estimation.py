"""Success-probability estimation from historical data (Section 3.1 + 4.4).

Pipeline: embed historical queries -> cluster (K-means / DBSCAN) -> per-cluster
per-arm accuracy means p-hat with confidence intervals (Hoeffding / Wilson)
-> optional median-boosting of the interval failure probability (Lemma 5)
-> at query time, map a test embedding to the nearest cluster and read its
p-hat vector.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from .types import QueryClass


# ---------------------------------------------------------------------------
# Confidence intervals
# ---------------------------------------------------------------------------


def hoeffding_interval(p_hat: np.ndarray, n, delta: float) -> Tuple[np.ndarray, np.ndarray]:
    """Two-sided Hoeffding CI at confidence 1 - delta.

    ``n`` may be a scalar or an array of per-arm observation counts (online
    feedback observes arms unevenly — see ``SuccessProbEstimator.update_counts``);
    entries with ``n <= 0`` get the vacuous [0, 1] interval.
    """
    n = np.asarray(n, np.float64)
    if n.ndim == 0 and n <= 0:
        return np.zeros_like(p_hat), np.ones_like(p_hat)
    half = np.sqrt(math.log(2.0 / delta) / (2.0 * np.maximum(n, 1.0)))
    lo = np.clip(p_hat - half, 0.0, 1.0)
    hi = np.clip(p_hat + half, 0.0, 1.0)
    return np.where(n > 0, lo, 0.0), np.where(n > 0, hi, 1.0)


def wilson_interval(p_hat: np.ndarray, n, delta: float) -> Tuple[np.ndarray, np.ndarray]:
    """Wilson score interval — tighter than Hoeffding at small n.

    Accepts scalar or per-arm array ``n`` like :func:`hoeffding_interval`;
    the serving drift detector (``serving/feedback.py``) relies on the
    per-arm form to compare old-vs-new estimates at their own counts.
    """
    n = np.asarray(n, np.float64)
    if n.ndim == 0 and n <= 0:
        return np.zeros_like(p_hat), np.ones_like(p_hat)
    # two-sided normal quantile via inverse erf
    from scipy.special import erfinv

    z = math.sqrt(2.0) * float(erfinv(1.0 - delta))
    safe = np.maximum(n, 1.0)
    denom = 1.0 + z * z / safe
    center = (p_hat + z * z / (2 * safe)) / denom
    half = z * np.sqrt(p_hat * (1 - p_hat) / safe + z * z / (4 * safe * safe)) / denom
    lo = np.clip(center - half, 0.0, 1.0)
    hi = np.clip(center + half, 0.0, 1.0)
    return np.where(n > 0, lo, 0.0), np.where(n > 0, hi, 1.0)


def median_boost_rounds(num_arms: int, delta: float, delta_l: float) -> int:
    """Lemma 5 repetition count: Lambda_l = 6 log(L/delta) / (1-2 delta_l)^2."""
    if delta_l >= 0.5:
        raise ValueError("median boosting needs delta_l < 1/2")
    return max(1, int(math.ceil(6.0 * math.log(num_arms / delta) / (1.0 - 2.0 * delta_l) ** 2)))


def median_boosted_interval(
    table: np.ndarray,            # (n, L) boolean outcomes for one cluster
    delta: float,
    delta_l: float = 0.25,
    subsample_frac: float = 0.5,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Median-of-repetitions interval (Lemma 5).

    Repeats the base estimator Lambda times on bootstrap subsamples and takes
    the interval whose center is the median estimate, driving the failure
    probability down to exp(-Lambda (1-2 delta_l)^2 / 2).

    Returns (p_hat, lo, hi), each (L,).
    """
    n, L = table.shape
    rounds = median_boost_rounds(L, delta, delta_l)
    rng = np.random.default_rng(seed)
    sub_n = max(1, int(n * subsample_frac))
    ests = np.empty((rounds, L))
    los = np.empty((rounds, L))
    his = np.empty((rounds, L))
    for r in range(rounds):
        idx = rng.choice(n, size=sub_n, replace=True)
        p_hat = table[idx].mean(axis=0)
        lo, hi = hoeffding_interval(p_hat, sub_n, delta_l)
        ests[r], los[r], his[r] = p_hat, lo, hi
    med = np.argsort(ests, axis=0)[rounds // 2]
    cols = np.arange(L)
    return ests[med, cols], los[med, cols], his[med, cols]


def fold_counts(
    p_hat: np.ndarray,
    counts: np.ndarray,
    successes: np.ndarray,
    attempts: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact streaming fold of per-arm (successes, attempts) feedback into a
    (p_hat, counts) estimate; arms with zero attempts keep their estimate.

    The single fold authority: :meth:`SuccessProbEstimator.update_counts`
    commits with it and the serving drift detector
    (``serving/feedback.py``) pre-computes its candidate with it, so the
    drift decision can never diverge from what actually folds in.
    Returns ``(new_p_hat, new_counts)``.
    """
    new_counts = counts + attempts
    new_p = np.where(
        attempts > 0,
        (p_hat * counts + successes) / np.maximum(new_counts, 1.0),
        p_hat,
    )
    return new_p, new_counts


# ---------------------------------------------------------------------------
# Historical-table estimation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterStats:
    """Per-cluster success-probability estimates over the pool.

    Besides the estimate itself, a cluster carries the state the online
    feedback loop needs: per-arm observation counts (served traffic observes
    arms unevenly — only invoked waves yield feedback), the estimator
    ``version`` of the last *plan-visible* change, and a snapshot of the
    estimate at that version. Plan caches key on ``version``; the drift
    detector compares fresh feedback against the snapshot, so feedback that
    merely confirms the current estimate never invalidates a plan.
    """

    centroid: np.ndarray          # (d,) embedding centroid
    p_hat: np.ndarray             # (L,)
    lo: np.ndarray                # (L,)
    hi: np.ndarray                # (L,)
    count: int
    arm_counts: Optional[np.ndarray] = None   # (L,) per-arm observations
    version: int = 0              # estimator version of last plan-visible change
    plan_p_hat: Optional[np.ndarray] = None   # estimate snapshot at `version`
    plan_arm_counts: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.arm_counts is None:
            self.arm_counts = np.full(self.p_hat.shape, float(self.count))
        if self.plan_p_hat is None:
            self.plan_p_hat = self.p_hat
        if self.plan_arm_counts is None:
            self.plan_arm_counts = self.arm_counts


class SuccessProbEstimator:
    """Section 3.1 estimator: cluster historical queries, average accuracy.

    Args:
      table: (N, L) boolean historical response-correctness matrix T.
      embeddings: (N, d) query embeddings.
      cluster_ids: (N,) precomputed cluster assignment (from
        ``repro.core.clustering``).
      delta: per-arm interval failure probability target.
      boost: apply Lemma-5 median boosting to the intervals.
    """

    def __init__(
        self,
        table: np.ndarray,
        embeddings: np.ndarray,
        cluster_ids: np.ndarray,
        delta: float = 0.01,
        boost: bool = False,
        min_cluster_size: int = 3,
    ):
        table = np.asarray(table, np.float64)
        embeddings = np.asarray(embeddings, np.float64)
        cluster_ids = np.asarray(cluster_ids, np.int64)
        self.num_arms = table.shape[1]
        self.clusters: Dict[int, ClusterStats] = {}
        self._global_p = table.mean(axis=0)
        # version: strictly monotone, bumped by every feedback fold.
        # plan_version: the version of the last *plan-visible* change — the
        # coarse key the PlanService's batch tables invalidate on (confirming
        # feedback bumps `version` but leaves `plan_version` put).
        self.version = 0
        self.plan_version = 0

        for cid in np.unique(cluster_ids):
            if cid < 0:  # DBSCAN noise: folded into the global estimate
                continue
            idx = np.flatnonzero(cluster_ids == cid)
            if idx.size < min_cluster_size:
                continue
            sub = table[idx]
            if boost:
                p_hat, lo, hi = median_boosted_interval(sub, delta)
            else:
                p_hat = sub.mean(axis=0)
                lo, hi = hoeffding_interval(p_hat, idx.size, delta)
            self.clusters[int(cid)] = ClusterStats(
                centroid=embeddings[idx].mean(axis=0),
                p_hat=p_hat,
                lo=lo,
                hi=hi,
                count=int(idx.size),
            )
        if not self.clusters:  # degenerate: one global cluster
            lo, hi = hoeffding_interval(self._global_p, table.shape[0], delta)
            self.clusters[0] = ClusterStats(
                centroid=embeddings.mean(axis=0),
                p_hat=self._global_p,
                lo=lo,
                hi=hi,
                count=table.shape[0],
            )
        self._centroids = np.stack([c.centroid for c in self.clusters.values()])
        self._cids = np.asarray(list(self.clusters.keys()))
        self._centroid_sq = (self._centroids ** 2).sum(axis=1)

    def lookup(self, embedding: np.ndarray) -> ClusterStats:
        """Nearest-centroid mapping of a test query to a historical cluster
        (the paper's semantic-similarity mapping, App. B). Delegates to
        :meth:`lookup_batch` so single and batched lookups always agree."""
        return self.clusters[int(self.lookup_batch(embedding[None, :])[0])]

    @property
    def cluster_order(self) -> np.ndarray:
        """(C,) cluster ids in dense-index order — the alignment contract
        for :meth:`lookup_batch_indices` and the PlanService batch tables."""
        return self._cids

    def lookup_batch_indices(self, embeddings: np.ndarray) -> np.ndarray:
        """(B, d) -> (B,) dense indices into :attr:`cluster_order`.

        The serving fast path: a dense index doubles as the gather index
        into precomputed per-cluster wave tables, so routing a batch never
        needs an ``np.unique`` pass over its cluster ids."""
        e = np.asarray(embeddings, np.float64)
        d = self._centroid_sq[None, :] - 2.0 * (e @ self._centroids.T)
        return np.argmin(d, axis=1)

    def lookup_batch(self, embeddings: np.ndarray) -> np.ndarray:
        """(B, d) -> (B,) cluster ids (matmul distance, no (B, C, d) temp)."""
        return self._cids[self.lookup_batch_indices(embeddings)]

    def update(
        self, cluster_id: int, outcomes: np.ndarray, delta: float = 0.01
    ) -> ClusterStats:
        """Online recalibration: fold a batch of observed per-arm correctness
        outcomes (n, L) into the cluster's running estimate — the production
        analogue of the paper's growing historical table. Counts accumulate
        exactly (streaming mean) and the CI tightens with n. Delegates to
        :meth:`update_counts` with every arm observed n times; a direct call
        is always plan-visible (cached plans for this cluster invalidate)."""
        outcomes = np.atleast_2d(np.asarray(outcomes, np.float64))
        n_new = outcomes.shape[0]
        return self.update_counts(
            cluster_id,
            outcomes.sum(axis=0),
            np.full(outcomes.shape[1], float(n_new)),
            queries=n_new,
            delta=delta,
        )

    def update_counts(
        self,
        cluster_id: int,
        successes: np.ndarray,
        attempts: np.ndarray,
        queries: int = 0,
        delta: float = 0.01,
        plan_visible: bool = True,
    ) -> ClusterStats:
        """Vectorized per-(cluster, arm) feedback fold — the online loop's
        entry point (Sec. 3.1's growing table, fed from served traffic).

        Args:
          successes/attempts: (L,) per-arm correct counts and observation
            counts. ``attempts[l]`` may be 0 for arms the serving plans never
            invoked — those arms keep their current estimate and interval.
          queries: labeled queries this fold represents (bookkeeping only).
          plan_visible: bump the cluster's plan ``version`` (and the
            estimator's ``plan_version``) and re-snapshot the estimate. The
            drift detector passes ``False`` for feedback that confirms the
            current estimate, so plan caches keep serving.

        Counts accumulate exactly, so folding the same feedback in any batch
        order yields the same estimate (up to float rounding), and the
        estimator ``version`` is strictly monotone under any interleaving.
        """
        st = self.clusters[int(cluster_id)]
        successes = np.asarray(successes, np.float64)
        attempts = np.asarray(attempts, np.float64)
        st.p_hat, st.arm_counts = fold_counts(
            st.p_hat, st.arm_counts, successes, attempts
        )
        st.count = int(st.count + queries)
        st.lo, st.hi = hoeffding_interval(st.p_hat, st.arm_counts, delta)
        self.version += 1
        if plan_visible:
            st.version = self.version
            st.plan_p_hat = st.p_hat
            st.plan_arm_counts = st.arm_counts
            self.plan_version = self.version
        return st

    def touch(self, cluster_id: Optional[int] = None) -> int:
        """Mark estimates as changed out-of-band.

        The serving plan caches key on estimator *versions*, which only
        :meth:`update` / :meth:`update_counts` bump — a direct assignment
        to ``clusters[c].p_hat`` is invisible to them and would keep stale
        plans serving. Call this afterwards (one cluster, or all with
        ``None``) to bump the version(s) and re-snapshot, making the
        change plan-visible. Returns the new estimator version."""
        cids = list(self.clusters) if cluster_id is None else [int(cluster_id)]
        for cid in cids:
            st = self.clusters[cid]
            self.version += 1
            st.version = self.version
            st.plan_p_hat = st.p_hat
            st.plan_arm_counts = st.arm_counts
        self.plan_version = self.version
        return self.version

    def query_class(
        self, embedding: np.ndarray, num_classes: int, alpha: Optional[float] = None
    ) -> QueryClass:
        """Build a QueryClass for a test query; ``alpha`` optionally overrides
        the interval width (the Table 6 ablation: lo = p - a/2, hi = p + a/2)."""
        st = self.lookup(embedding)
        if alpha is not None:
            lo = np.clip(st.p_hat - alpha / 2, 0.0, 1.0)
            hi = np.clip(st.p_hat + alpha / 2, 0.0, 1.0)
        else:
            lo, hi = st.lo, st.hi
        return QueryClass(
            probs=st.p_hat, num_classes=num_classes, lo=lo, hi=hi,
            meta={"count": st.count},
        )
