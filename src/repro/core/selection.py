"""LLM ensemble selection: GreedyLLM (Alg. 1), SurGreedyLLM (Alg. 2) and the
adaptive ThriftLLM loop (Alg. 3).

Two planes with bit-identical outputs:

* the **serial** plane (:func:`sur_greedy`) — numpy control flow, one
  device dispatch per greedy round through the grouped CRN estimator;
* the **batched** plane (:func:`sur_greedy_many`) — G (p-vector, budget)
  groups planned by ONE jitted program (:func:`_sur_greedy_scan`): a
  ``lax.while`` over greedy rounds whose body evaluates every group's
  masked candidate expansion simultaneously over stacked ``(G, theta, L)``
  CRN response samples.

Both planes evaluate xi through the same bit-stable cores in
``repro.core.mc`` and run the same IEEE-f64 round logic (affordability,
gain/cost ratios, the Alg. 1 p/b tie-break), so under a shared CRN seed the
batched planner returns exactly the serial chosen sets, orders, values and
spend — the contract ``tests/test_selection_batched.py`` pins bitwise.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .belief import (
    aggregate_log_beliefs,
    empty_log_belief,
    log_weight,
    predict_from_beliefs,
    top2_beliefs,
)
from .correctness import gamma
from .mc import (
    GroupedXiEstimator,
    _marginal_xi_core,
    _tables_xi_core,
    bucket_size,
    theta_for,
)
from .types import InvocationResult, SelectionResult, clip_probs

# Continue invoking on near-ties so Prop. 4 (prediction equality) holds
# deterministically; costs at most the paper's condition, never more than S*.
STOP_MARGIN = 1e-9
RATIO_TIE_RTOL = 1e-9


def greedy(
    p: np.ndarray,
    b: np.ndarray,
    budget: float,
    value_batch_fn: Callable[[np.ndarray], np.ndarray],
    empty_value: float,
) -> Tuple[List[int], float]:
    """GreedyLLM (Algorithm 1) on an arbitrary set function.

    Each iteration evaluates *all* affordable candidates in one batched call
    and adds the arm with the best marginal-gain / cost ratio; ties broken by
    the p/b ratio (Alg. 1 line 4). Returns (chosen order, final value).
    """
    p = np.asarray(p, np.float64)
    b = np.asarray(b, np.float64)
    L = p.size
    chosen: List[int] = []
    chosen_mask = np.zeros(L, np.float32)
    cand_buf = np.empty((L, L), np.float32)   # reused across rounds
    in_pool = np.ones(L, bool)
    spent = 0.0
    current = float(empty_value)

    while True:
        afford = np.flatnonzero(in_pool & (b <= budget - spent + 1e-15))
        if afford.size == 0:
            break
        cand = cand_buf[: afford.size]
        cand[:] = chosen_mask
        cand[np.arange(afford.size), afford] = 1.0
        vals = np.asarray(value_batch_fn(cand), np.float64)
        ratios = (vals - current) / b[afford]
        best = float(np.max(ratios))
        tied = np.flatnonzero(np.isclose(ratios, best, rtol=RATIO_TIE_RTOL, atol=1e-15))
        if tied.size > 1:  # tie-break by success-prob / cost ratio
            ti = int(tied[np.argmax(p[afford[tied]] / b[afford[tied]])])
        else:
            ti = int(tied[0])
        pick = int(afford[ti])
        chosen.append(pick)
        chosen_mask[pick] = 1.0
        in_pool[pick] = False
        spent += b[pick]
        current = float(vals[ti])                 # vals aligned with afford
    return chosen, current


def gamma_value_batch(p: np.ndarray) -> Callable[[np.ndarray], np.ndarray]:
    """Batched closed-form gamma over candidate masks."""
    log1m = np.log1p(-clip_probs(p))

    def fn(masks: np.ndarray) -> np.ndarray:
        return 1.0 - np.exp(masks @ log1m)

    return fn


def _greedy_gamma(
    p: np.ndarray, b: np.ndarray, budget: float
) -> Tuple[List[int], float]:
    """Greedy-on-gamma (Alg. 1 on the closed-form gamma), serial plane.

    Carries the chosen set's survival product ``q = prod(1 - p_l)`` instead
    of re-exponentiating mask sums: each round's candidate values are
    ``1 - q * m`` with the per-arm factors ``m = exp(log1p(-p))`` computed
    once up front.  The loop body is then pure IEEE-f64 multiply/subtract —
    no transcendentals — so :func:`_sur_greedy_scan_core` can run the exact
    same statements on device and bit-match this function round for round.
    Control flow (tie window, p/b tie-break) mirrors :func:`_greedy_xi`.
    """
    p = np.asarray(clip_probs(p), np.float64)
    b = np.asarray(b, np.float64)
    L = p.size
    m = np.exp(np.log1p(-p))                  # per-arm survival factor
    in_pool = np.ones(L, bool)
    q = 1.0                                   # survival of the chosen set
    spent = 0.0
    current = 0.0                             # gamma(empty) = 0
    chosen: List[int] = []
    while True:
        afford = in_pool & (b <= budget - spent + 1e-15)
        if not afford.any():
            break
        vals = 1.0 - q * m                    # gamma(chosen ∪ {l}) for all l
        ratios = np.where(afford, (vals - current) / b, -np.inf)
        best = ratios.max()
        tied = afford & (
            (ratios == best)
            | (np.abs(ratios - best) <= 1e-15 + RATIO_TIE_RTOL * abs(best))
        )
        pb = np.where(tied, p / b, -np.inf)
        pick = int(np.argmax(pb))
        chosen.append(pick)
        in_pool[pick] = False
        spent += float(b[pick])
        current = float(vals[pick])
        q = q * float(m[pick])
    return chosen, current


def _greedy_xi(
    p: np.ndarray, b: np.ndarray, budget: float, est: GroupedXiEstimator,
    group: int = 0,
) -> Tuple[List[int], float, np.ndarray, np.ndarray]:
    """Greedy-on-xi (Alg. 1 specialized to the CRN estimator), serial plane.

    Identical control flow to :func:`greedy`, but marginal gains come from
    the estimator's incremental base+candidate evaluation
    (:meth:`GroupedXiEstimator.marginal`): the chosen set's belief table is
    carried across rounds in pick order and each round extends it by every
    candidate arm in one dispatch. :func:`_sur_greedy_scan` runs this exact
    loop, on the same evaluator, inside one jitted program — keeping both
    planes on the same arithmetic is what makes them bit-identical.
    """
    K = est.num_classes
    L = int(p.size)
    T = est.responses.shape[1]
    resp = est.responses[group]
    w32 = est.log_weights[group]
    base_raw = np.zeros((1, T, K), np.float32)
    base_cnt = np.zeros((1, T, K), np.int32)
    in_pool = np.ones(L, bool)
    spent = 0.0
    current = 1.0 / K
    chosen: List[int] = []
    while True:
        afford = in_pool & (b <= budget - spent + 1e-15)
        if not afford.any():
            break
        vals = est.marginal(base_raw, base_cnt)[group]        # (L,) f64
        ratios = np.where(afford, (vals - current) / b, -np.inf)
        best = ratios.max()
        tied = afford & (
            (ratios == best)
            | (np.abs(ratios - best) <= 1e-15 + RATIO_TIE_RTOL * abs(best))
        )
        pb = np.where(tied, p / b, -np.inf)
        pick = int(np.argmax(pb))
        chosen.append(pick)
        in_pool[pick] = False
        spent += float(b[pick])
        current = float(vals[pick])
        col = resp[:, pick]
        rows = np.flatnonzero(col >= 0)
        base_raw[0, rows, col[rows]] += w32[pick]
        base_cnt[0, rows, col[rows]] += 1
    return chosen, current, base_raw, base_cnt


def _assemble_result(
    p: np.ndarray, b: np.ndarray, budget: float, l_star: int,
    s1: Sequence[int], s2: Sequence[int], xi_vals: np.ndarray,
) -> SelectionResult:
    """Shared Alg. 2 epilogue: argmax of the three candidates + Theorem 3
    diagnostics (used by both the serial and the batched plane)."""
    cands = [
        np.asarray([l_star]), np.asarray(s1, np.int64), np.asarray(s2, np.int64)
    ]
    pick = int(np.argmax(xi_vals))
    chosen = cands[pick]
    return SelectionResult(
        chosen=chosen,
        xi_est=float(xi_vals[pick]),
        cost=float(b[chosen].sum()) if chosen.size else 0.0,
        budget=budget,
        s1=cands[1],
        s2=cands[2],
        l_star=l_star,
        xi_s1=float(xi_vals[1]),
        xi_s2=float(xi_vals[2]),
        p_star=float(p[l_star]),
        gamma_s2=gamma(p[np.asarray(s2, np.int64)]) if len(s2) else 0.0,
    )


def sur_greedy(
    p: np.ndarray,
    b: np.ndarray,
    budget: float,
    num_classes: int,
    key: jax.Array,
    theta: int,
    p_all: Optional[np.ndarray] = None,
    use_kernel: bool = False,
) -> SelectionResult:
    """SurGreedyLLM (Algorithm 2) with CRN Monte-Carlo xi estimation.

    The serial reference plane of the planner: one group, host-side greedy
    rounds, one device dispatch per round. :func:`sur_greedy_many` is the
    batched plane; under the same ``key`` it bit-matches this function
    group by group.

    Returns the best of {best affordable single arm, greedy-on-xi,
    greedy-on-gamma} together with the Theorem 3 diagnostics.
    """
    p = clip_probs(p)
    b = np.asarray(b, np.float64)
    K = int(num_classes)

    afford = np.flatnonzero(b <= budget + 1e-15)
    if afford.size == 0:
        return SelectionResult(
            chosen=np.zeros(0, np.int64), xi_est=1.0 / K, cost=0.0, budget=budget
        )
    est = GroupedXiEstimator(
        key, p[None, :], K, np.asarray([theta]), p_all=p_all,
        use_kernel=use_kernel,
    )
    l_star = int(afford[np.argmax(p[afford])])

    s1, _, s1_raw, s1_cnt = _greedy_xi(p, b, budget, est)
    s2, _ = _greedy_gamma(p, b, budget)

    # Evaluate the three candidates with the *same* CRN draws.
    xi_vals = est.final_xi([l_star], [s1], [s2], s1_raw, s1_cnt)[0]
    return _assemble_result(p, b, budget, l_star, s1, s2, xi_vals)


# ---------------------------------------------------------------------------
# The batched planner: G (p-vector, budget) groups in one jitted program
# ---------------------------------------------------------------------------


def _sur_greedy_scan_core(
    resp_t: jnp.ndarray,      # (G, L, T) int32, -1 past each group's theta
    valid: jnp.ndarray,       # (G, T) f32 0/1 draw mask
    log_weights: jnp.ndarray, # (G, L) f32
    empty: jnp.ndarray,       # (G,) f32
    theta: jnp.ndarray,       # (G,) f64
    p: jnp.ndarray,           # (G, L) f64 clipped success probs
    b: jnp.ndarray,           # (G, L) f64 pool costs
    budgets: jnp.ndarray,     # (G,) f64
    m: jnp.ndarray,           # (G, L) f64 survival factors exp(log1p(-p))
    *,
    num_classes: int,
    full: bool = True,
):
    """The whole Alg. 2 planner for all G groups as one device program.

    Four fused phases:

    1. **greedy-on-xi** — a ``lax.while`` whose rounds evaluate the masked
       candidate expansion of *every* group simultaneously
       (`_marginal_xi_core` over the stacked CRN draws), then run Alg. 1's
       round logic — affordability, gain/cost ratios, the near-tie window
       and the p/b tie-break — as f64 elementwise ops that mirror
       :func:`_greedy_xi`'s numpy statements one for one;
    2. **greedy-on-gamma** — a second ``lax.while`` mirroring
       :func:`_greedy_gamma` (survival-product carry, pure multiply /
       subtract — the serial plane precomputes the same ``m`` factors so
       neither plane exponentiates inside the loop);
    3. **l***— the best affordable single arm, a masked first-max argmax
       identical to the serial compressed ``afford[argmax(p[afford])]``;
    4. **candidate scoring** — the l*/s1/s2 belief tables accumulated in
       ascending arm order (the exact f32 operand sequence of
       :meth:`GroupedXiEstimator._accumulate`) and scored by
       :func:`_tables_xi_core` in-program: ``final_xi`` without leaving
       the device.

    Groups whose affordable set empties freeze in place; padded groups
    (budget < 0) never pick and stay inert. Runs under ``enable_x64``.

    With ``full=False`` only phase 1 runs and the return is the PR 9
    planner surface ``(picks, npick, value, spent, base_raw, base_cnt)``
    (kept as the bench baseline / reference plane). With ``full=True``
    the return is ``(picks (G, L) int32 in pick order (-1 pad),
    npick (G,), g_picks (G, L), g_npick (G,), l_star (G,) int32,
    xi_vals (G, 3) f64)``.
    """
    G, L, T = resp_t.shape
    K = num_classes
    arange_l = jnp.arange(L, dtype=jnp.int32)

    def cond(st):
        return st["alive"].any()

    def body(st):
        afford = st["in_pool"] & (
            b <= budgets[:, None] - st["spent"][:, None] + 1e-15
        )
        has = afford.any(axis=1)
        return jax.lax.cond(
            has.any(),
            lambda: _round(st, afford, has),
            lambda: dict(st, alive=has),   # every group done: freeze
        )

    def _round(st, afford, has):
        vals = _marginal_xi_core(
            resp_t, st["base_raw"], st["base_cnt"], log_weights, empty,
            valid, theta, K,
        )                                                     # (G, L) f64
        ratios = jnp.where(afford, (vals - st["current"][:, None]) / b, -jnp.inf)
        best = jnp.max(ratios, axis=1)
        tied = afford & (
            (ratios == best[:, None])
            | (jnp.abs(ratios - best[:, None])
               <= 1e-15 + RATIO_TIE_RTOL * jnp.abs(best[:, None]))
        )
        pb = jnp.where(tied, p / b, -jnp.inf)
        pick = jnp.argmax(pb, axis=1).astype(jnp.int32)       # first max
        oh_pick = arange_l[None, :] == pick[:, None]
        upd = has[:, None] & oh_pick
        b_pick = jnp.take_along_axis(b, pick[:, None].astype(jnp.int64), 1)[:, 0]
        v_pick = jnp.take_along_axis(vals, pick[:, None].astype(jnp.int64), 1)[:, 0]
        w_pick = jnp.take_along_axis(
            log_weights, pick[:, None].astype(jnp.int64), 1
        )[:, 0]
        resp_pick = jnp.take_along_axis(
            resp_t, pick[:, None, None].astype(jnp.int64), 1
        )[:, 0, :]                                            # (G, T)
        oh_resp = resp_pick[..., None] == jnp.arange(K, dtype=resp_t.dtype)
        grow = has[:, None, None] & oh_resp                   # padded rows: -1
        return {
            "in_pool": st["in_pool"] & ~upd,
            "spent": jnp.where(has, st["spent"] + b_pick, st["spent"]),
            "current": jnp.where(has, v_pick, st["current"]),
            "base_raw": jnp.where(
                grow, st["base_raw"] + w_pick[:, None, None], st["base_raw"]
            ),
            "base_cnt": st["base_cnt"] + jnp.where(grow, 1, 0).astype(jnp.int32),
            "picks": jnp.where(
                has[:, None] & (arange_l[None, :] == st["npick"][:, None]),
                pick[:, None], st["picks"],
            ),
            "npick": st["npick"] + has.astype(jnp.int32),
            "alive": has,
        }

    init = {
        "in_pool": jnp.ones((G, L), bool),
        "spent": jnp.zeros(G, jnp.float64),
        "current": jnp.full(G, 1.0 / K, jnp.float64),
        "base_raw": jnp.zeros((G, T, K), jnp.float32),
        "base_cnt": jnp.zeros((G, T, K), jnp.int32),
        "picks": jnp.full((G, L), -1, jnp.int32),
        "npick": jnp.zeros(G, jnp.int32),
        "alive": jnp.ones(G, bool),
    }
    st = jax.lax.while_loop(cond, body, init)
    if not full:
        return (st["picks"], st["npick"], st["current"], st["spent"],
                st["base_raw"], st["base_cnt"])

    # -- phase 2: greedy-on-gamma (mirrors `_greedy_gamma` statement for
    # statement; the survival-product carry keeps the loop transcendental-
    # free, so both planes run identical IEEE multiply/subtract chains) --
    def gcond(st2):
        return st2["alive"].any()

    def gbody(st2):
        afford = st2["in_pool"] & (
            b <= budgets[:, None] - st2["spent"][:, None] + 1e-15
        )
        has = afford.any(axis=1)
        vals = 1.0 - st2["q"][:, None] * m                    # (G, L) f64
        ratios = jnp.where(
            afford, (vals - st2["current"][:, None]) / b, -jnp.inf
        )
        best = jnp.max(ratios, axis=1)
        tied = afford & (
            (ratios == best[:, None])
            | (jnp.abs(ratios - best[:, None])
               <= 1e-15 + RATIO_TIE_RTOL * jnp.abs(best[:, None]))
        )
        pb = jnp.where(tied, p / b, -jnp.inf)
        pick = jnp.argmax(pb, axis=1).astype(jnp.int32)       # first max
        oh_pick = arange_l[None, :] == pick[:, None]
        upd = has[:, None] & oh_pick
        b_pick = jnp.take_along_axis(
            b, pick[:, None].astype(jnp.int64), 1
        )[:, 0]
        v_pick = jnp.take_along_axis(
            vals, pick[:, None].astype(jnp.int64), 1
        )[:, 0]
        m_pick = jnp.take_along_axis(
            m, pick[:, None].astype(jnp.int64), 1
        )[:, 0]
        return {
            "in_pool": st2["in_pool"] & ~upd,
            "spent": jnp.where(has, st2["spent"] + b_pick, st2["spent"]),
            "current": jnp.where(has, v_pick, st2["current"]),
            "q": jnp.where(has, st2["q"] * m_pick, st2["q"]),
            "picks": jnp.where(
                has[:, None] & (arange_l[None, :] == st2["npick"][:, None]),
                pick[:, None], st2["picks"],
            ),
            "npick": st2["npick"] + has.astype(jnp.int32),
            "alive": has,
        }

    ginit = {
        "in_pool": jnp.ones((G, L), bool),
        "spent": jnp.zeros(G, jnp.float64),
        "current": jnp.zeros(G, jnp.float64),
        "q": jnp.ones(G, jnp.float64),
        "picks": jnp.full((G, L), -1, jnp.int32),
        "npick": jnp.zeros(G, jnp.int32),
        "alive": jnp.ones(G, bool),
    }
    st2 = jax.lax.while_loop(gcond, gbody, ginit)

    # -- phase 3: l* — first-max argmax over the affordable arms, the
    # device form of the serial `afford[argmax(p[afford])]` (non-afforded
    # arms at -inf lose to any affordable one; padded groups afford
    # nothing and resolve to arm 0, discarded by the caller) --
    afford0 = b <= budgets[:, None] + 1e-15
    l_star = jnp.argmax(
        jnp.where(afford0, p, -jnp.inf), axis=1
    ).astype(jnp.int32)

    # -- phase 4: Alg. 2 candidate scoring in-program. The l* and s2
    # belief tables are folded in ascending arm order — one f32 add per
    # draw per arm, the same operand sequence as
    # `GroupedXiEstimator._accumulate` — and scored by the same
    # `_tables_xi_core` the host path jits, so xi comes back bit-identical
    # to `est.final_xi(...)` without a host round-trip. --
    arange_k = jnp.arange(K, dtype=resp_t.dtype)
    resp_l = jnp.take_along_axis(
        resp_t, l_star[:, None, None].astype(jnp.int64), 1
    )[:, 0, :]                                                # (G, T)
    w_l = jnp.take_along_axis(
        log_weights, l_star[:, None].astype(jnp.int64), 1
    )                                                         # (G, 1)
    oh_l = resp_l[..., None] == arange_k                      # (G, T, K)
    raw_star = jnp.where(oh_l, w_l[:, :, None], jnp.float32(0.0))
    cnt_star = oh_l.astype(jnp.int32)

    chosen2 = ~st2["in_pool"]                                 # the s2 set

    def fold(l, carry):
        raw, cnt = carry
        sel = jax.lax.dynamic_index_in_dim(
            chosen2, l, axis=1, keepdims=False
        )                                                     # (G,)
        col = jax.lax.dynamic_index_in_dim(
            resp_t, l, axis=1, keepdims=False
        )                                                     # (G, T)
        w_arm = jax.lax.dynamic_index_in_dim(
            log_weights, l, axis=1, keepdims=False
        )                                                     # (G,)
        add = sel[:, None, None] & (col[..., None] == arange_k)
        raw = jnp.where(add, raw + w_arm[:, None, None], raw)
        cnt = cnt + jnp.where(add, 1, 0).astype(jnp.int32)
        return (raw, cnt)

    raw_s2, cnt_s2 = jax.lax.fori_loop(
        0, L, fold,
        (jnp.zeros((G, T, K), jnp.float32), jnp.zeros((G, T, K), jnp.int32)),
    )

    raw3 = jnp.stack([raw_star, st["base_raw"], raw_s2], axis=1)
    cnt3 = jnp.stack([cnt_star, st["base_cnt"], cnt_s2], axis=1)
    xi_vals = _tables_xi_core(raw3, cnt3, empty, valid, theta, K)

    return (st["picks"], st["npick"], st2["picks"], st2["npick"],
            l_star, xi_vals)


@contextlib.contextmanager
def _quiet_donation():
    """Donation is declarative — XLA aliases what it can and (on backends/
    shapes where an input can't be reused) warns once at compile time about
    the rest. The contract we assert is the caller-side one ("this buffer
    is dead after the call"), so the partial-use warning is expected noise;
    dispatch seams of donating wrappers run under this context."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable"
        )
        yield


# Donating wrapper (the serving default) and its no-donation twin. The
# donated positions are the staged response/valid/weight tables: every
# caller in the tree stages them from host numpy (jit transfers a fresh
# device copy and donates *that* copy, never the host buffer), or hands
# over throwaway device arrays — after the call the argument is dead, so
# XLA may reuse its memory for loop carries and outputs. Donation changes
# buffer lifetimes only, never arithmetic: on/off is bit-identical, and
# each wrapper owns one compile per bucket (CompileSentinel-clean).
_sur_greedy_scan = functools.partial(
    jax.jit, static_argnames=("num_classes", "full"), donate_argnums=(0, 1, 2),
)(_sur_greedy_scan_core)

_sur_greedy_scan_nodonate = functools.partial(
    jax.jit, static_argnames=("num_classes", "full"),
)(_sur_greedy_scan_core)


# Reusable staging buffers for the batched planner, keyed by padded shape
# (Gp, L, T): warm replans hit the same compile bucket over and over, so
# re-allocating ~13 MB of padded tables per call is pure churn. The scratch
# is *host numpy* — the jit transfers a fresh device copy per call (and the
# donating wrapper donates that copy, never these buffers), so reuse is
# safe even with donation on. Planning is control-plane work serialized by
# the PlanService; the scratch is not thread-safe by itself.
_PLAN_SCRATCH: dict = {}


def _plan_scratch(Gp: int, L: int, T: int) -> dict:
    key = (Gp, L, T)
    scr = _PLAN_SCRATCH.get(key)
    if scr is None:
        scr = {
            "resp": np.empty((Gp, L, T), np.int32),
            "valid": np.empty((Gp, T), np.float32),
            "w": np.empty((Gp, L), np.float32),
            "empty": np.empty(Gp, np.float32),
            "theta": np.empty(Gp, np.float64),
            "p": np.empty((Gp, L), np.float64),
            "m": np.empty((Gp, L), np.float64),
            "budgets": np.empty(Gp, np.float64),
        }
        _PLAN_SCRATCH[key] = scr
    return scr


def _stage_groups(est: GroupedXiEstimator, b: np.ndarray,
                  budgets_live: np.ndarray, group_bucket: int):
    """Fill the bucket-keyed scratch with the padded planner tables.

    Rows past ``n`` get the inert pad values every call (a previous call on
    the same bucket may have staged more live groups).  Returns
    ``(scratch, b_p, n, Gp)``; ``b_p`` is a broadcast view, never written.
    """
    n = est.num_groups
    L = est.num_arms
    Gp = bucket_size(n, group_bucket)
    T = est.responses.shape[1]
    scr = _plan_scratch(Gp, L, T)
    scr["resp"][:n] = est.responses_t
    scr["resp"][n:] = -1
    scr["valid"][:n] = est.valid
    scr["valid"][n:] = 0.0
    scr["w"][:n] = est.log_weights
    scr["w"][n:] = 0.0
    scr["empty"][:n] = est.empty
    scr["empty"][n:] = 0.0
    scr["theta"][:n] = est.theta_f
    scr["theta"][n:] = 1.0
    scr["p"][:n] = est.ps
    scr["p"][n:] = 0.5
    # the gamma survival factors, elementwise in-place (the same
    # `np.exp(np.log1p(-p))` values `_greedy_gamma` precomputes serially)
    np.negative(scr["p"], out=scr["m"])
    np.log1p(scr["m"], out=scr["m"])
    np.exp(scr["m"], out=scr["m"])
    scr["budgets"][:n] = budgets_live
    scr["budgets"][n:] = -1.0               # pad groups afford nothing
    b_p = np.broadcast_to(b, (Gp, L))
    return scr, b_p, n, Gp


def _live_split(ps, b, budgets, K):
    """Serial early-return for groups that afford nothing; the rest plan."""
    G = ps.shape[0]
    results: List[Optional[SelectionResult]] = [None] * G
    live: List[int] = []
    for g in range(G):
        if (b <= budgets[g] + 1e-15).any():
            live.append(g)
        else:
            results[g] = SelectionResult(
                chosen=np.zeros(0, np.int64), xi_est=1.0 / K, cost=0.0,
                budget=float(budgets[g]),
            )
    return results, live


def sur_greedy_many(
    ps: np.ndarray,
    b: np.ndarray,
    budgets: np.ndarray,
    num_classes: int,
    key: jax.Array,
    thetas,
    use_kernel: bool = False,
    group_bucket: int = 8,
    donate: bool = True,
) -> List[SelectionResult]:
    """SurGreedyLLM over G stacked (p-vector, budget) groups — the batched
    planner plane.

    One :class:`GroupedXiEstimator` shares the CRN draws and ONE
    :func:`_sur_greedy_scan` dispatch runs everything: every group's
    greedy-on-xi, greedy-on-gamma, the best affordable single arm, and the
    Alg. 2 candidate scoring (``final_xi``) — there is no per-group Python
    work between staging the tables and reading back the planned sets.
    Under the same ``key`` the results bit-match ``[sur_greedy(ps[g], b,
    budgets[g], ...) for g]``; groups are padded to ``group_bucket``
    multiples so serving replans reuse a handful of compiled programs, and
    the padded staging buffers are reused from bucket-keyed scratch.

    Args:
      ps: (G, L) per-group success probabilities.
      b: (L,) shared pool costs.
      budgets: (G,) per-group budgets.
      thetas: scalar or (G,) Monte-Carlo sample counts.
      donate: donate the staged response/valid/weight tables to XLA
        (bit-identical either way; ``False`` keeps the transferred device
        copies alive for callers that want to inspect them).
    """
    ps = clip_probs(np.atleast_2d(np.asarray(ps, np.float64)))
    G, L = ps.shape
    b = np.asarray(b, np.float64)
    budgets = np.broadcast_to(np.asarray(budgets, np.float64), (G,))
    thetas = np.broadcast_to(np.asarray(thetas, np.int64), (G,))
    K = int(num_classes)

    results, live = _live_split(ps, b, budgets, K)
    if not live:
        return results

    est = GroupedXiEstimator(
        key, ps[live], K, thetas[live], use_kernel=use_kernel
    )
    scr, b_p, n, _ = _stage_groups(est, b, budgets[live], group_bucket)
    scan_fn = _sur_greedy_scan if donate else _sur_greedy_scan_nodonate
    with enable_x64(), _quiet_donation():
        out = scan_fn(
            scr["resp"], scr["valid"], scr["w"], scr["empty"], scr["theta"],
            scr["p"], b_p, scr["budgets"], scr["m"],
            num_classes=K, full=True,
        )
    picks, npick, g_picks, g_npick, l_star, xi_vals = (
        np.asarray(o) for o in out
    )

    for i, g in enumerate(live):
        s1 = [int(a) for a in picks[i, : npick[i]]]
        s2 = [int(a) for a in g_picks[i, : g_npick[i]]]
        results[g] = _assemble_result(
            est.ps[i], b, float(budgets[g]), int(l_star[i]), s1, s2,
            xi_vals[i],
        )
    return results


def _sur_greedy_many_hostgamma(
    ps: np.ndarray,
    b: np.ndarray,
    budgets: np.ndarray,
    num_classes: int,
    key: jax.Array,
    thetas,
    use_kernel: bool = False,
    group_bucket: int = 8,
) -> List[SelectionResult]:
    """The PR 9 planner plane, kept verbatim as reference and bench
    baseline: the device scan runs greedy-on-xi only (``full=False``, no
    donation), then a per-group host loop runs greedy-on-gamma / l* and
    ``est.final_xi`` stages the candidate tables back through a separate
    dispatch. Bit-identical to :func:`sur_greedy_many`; strictly more
    host work per group."""
    ps = clip_probs(np.atleast_2d(np.asarray(ps, np.float64)))
    G, L = ps.shape
    b = np.asarray(b, np.float64)
    budgets = np.broadcast_to(np.asarray(budgets, np.float64), (G,))
    thetas = np.broadcast_to(np.asarray(thetas, np.int64), (G,))
    K = int(num_classes)

    results, live = _live_split(ps, b, budgets, K)
    if not live:
        return results

    est = GroupedXiEstimator(
        key, ps[live], K, thetas[live], use_kernel=use_kernel
    )
    n = len(live)
    Gp = bucket_size(n, group_bucket)
    T = est.responses.shape[1]
    resp_p = np.full((Gp, L, T), -1, np.int32)
    resp_p[:n] = est.responses_t
    valid_p = np.zeros((Gp, T), np.float32)
    valid_p[:n] = est.valid
    w_p = np.zeros((Gp, L), np.float32)
    w_p[:n] = est.log_weights
    empty_p = np.zeros(Gp, np.float32)
    empty_p[:n] = est.empty
    theta_p = np.ones(Gp, np.float64)
    theta_p[:n] = est.theta_f
    p_p = np.full((Gp, L), 0.5, np.float64)
    p_p[:n] = est.ps
    b_p = np.broadcast_to(b, (Gp, L))
    budgets_p = np.full(Gp, -1.0, np.float64)
    budgets_p[:n] = budgets[live]
    m_p = np.exp(np.log1p(-p_p))

    with enable_x64():
        picks, npick, _, _, s1_raw, s1_cnt = _sur_greedy_scan_nodonate(
            resp_p, valid_p, w_p, empty_p, theta_p, p_p, b_p, budgets_p,
            m_p, num_classes=K, full=False,
        )
    picks = np.asarray(picks)
    npick = np.asarray(npick)
    s1_raw = np.asarray(s1_raw)[:n]
    s1_cnt = np.asarray(s1_cnt)[:n]

    l_stars: List[int] = []
    s1s: List[List[int]] = []
    s2s: List[List[int]] = []
    for i, g in enumerate(live):
        p_g = est.ps[i]
        afford = np.flatnonzero(b <= budgets[g] + 1e-15)
        l_stars.append(int(afford[np.argmax(p_g[afford])]))
        s1s.append([int(a) for a in picks[i, : npick[i]]])
        s2s.append(_greedy_gamma(p_g, b, budgets[g])[0])

    xi_vals = est.final_xi(l_stars, s1s, s2s, s1_raw, s1_cnt)  # (n, 3) f64
    for i, g in enumerate(live):
        results[g] = _assemble_result(
            est.ps[i], b, float(budgets[g]), l_stars[i], s1s[i], s2s[i],
            xi_vals[i],
        )
    return results


def adaptive_invoke(
    selection: Sequence[int],
    p: np.ndarray,
    num_classes: int,
    invoke_fn: Callable[[int], int],
    p_all: Optional[np.ndarray] = None,
    rng: Optional[np.random.Generator] = None,
    costs: Optional[np.ndarray] = None,
) -> InvocationResult:
    """Adaptive invocation (Algorithm 3 lines 3-11).

    Invokes arms of ``selection`` in decreasing-p order and early-stops when
    the residual potential belief F(T*) can no longer change the prediction:
    ``F(T*) * H2(phi) <= H1(phi)`` (Prop. 4 guarantees prediction equality
    with the full set).

    Args:
      invoke_fn: ``arm_index -> class_id`` — runs the real model (or oracle).
    """
    p = clip_probs(p)
    K = int(num_classes)
    w = log_weight(p, K)
    empty = empty_log_belief(p if p_all is None else p_all)
    sel = sorted(selection, key=lambda i: -p[i])
    remaining = list(sel)

    used: List[int] = []
    responses: List[int] = []
    beliefs = np.full(K, empty, np.float64)
    counts = np.zeros(K, np.int64)

    while remaining:
        log_f = float(np.sum(w[remaining]))
        h1, h2, _ = top2_beliefs(beliefs)
        if not (log_f + h2 > h1 - STOP_MARGIN):
            break  # residual arms cannot flip the prediction (Prop. 4)
        arm = remaining.pop(0)
        r = int(invoke_fn(arm))
        used.append(arm)
        responses.append(r)
        if counts[r] == 0:
            beliefs[r] = w[arm]
        else:
            beliefs[r] += w[arm]
        counts[r] += 1

    pred, _ = predict_from_beliefs(beliefs, rng)
    cost_vec = np.asarray(costs, np.float64) if costs is not None else np.zeros(p.size)
    return InvocationResult(
        prediction=int(pred),
        used=np.asarray(used, np.int64),
        responses=np.asarray(responses, np.int64),
        cost=float(cost_vec[used].sum()) if used else 0.0,
        planned_cost=float(cost_vec[list(sel)].sum()) if len(sel) else 0.0,
        log_beliefs=beliefs,
    )


@dataclasses.dataclass
class ThriftLLM:
    """End-to-end selector (Algorithm 3): SurGreedy selection + adaptive
    invocation, parameterized by the paper's (eps, delta).

    One instance is bound to a pool (costs) and reused across query classes;
    per-class selections are cached because selection depends only on
    (p-vector, K, budget).
    """

    costs: np.ndarray
    eps: float = 0.1
    delta: float = 0.01
    seed: int = 0
    use_kernel: bool = False

    def __post_init__(self):
        self.costs = np.asarray(self.costs, np.float64)
        self._cache: dict = {}

    def rebind_costs(self, costs: np.ndarray) -> None:
        """Swap in a new pool cost vector and drop every cached selection.

        Selections depend on prices, so they cannot survive a re-pricing;
        the serving PlanService calls this when the pool fingerprint
        changes (see :meth:`repro.serving.plans.PlanService.refresh`).
        """
        self.costs = np.asarray(costs, np.float64)
        self._cache.clear()

    def trim_cache(self, max_entries: int) -> int:
        """Drop the oldest cached selections beyond ``max_entries``.

        Selection keys embed the p-vector, so once an estimate moves (the
        online-feedback steady state) its old entries can never be hit
        again — without trimming, continuous drift would grow the memo
        indefinitely. Insertion order doubles as age (never-rekeyed dict).
        Returns the number of entries dropped."""
        drop = len(self._cache) - int(max_entries)
        if drop <= 0:
            return 0
        for key in list(self._cache)[:drop]:
            del self._cache[key]
        return drop

    def theta(self, p: np.ndarray, budget: float) -> int:
        afford = np.flatnonzero(self.costs <= budget + 1e-15)
        p_star = float(np.max(clip_probs(p)[afford])) if afford.size else 1.0
        return theta_for(self.eps, self.delta, p_star, len(self.costs))

    @staticmethod
    def _memo_key(p: np.ndarray, num_classes: int, budget: float):
        return (
            np.round(np.asarray(p, np.float64), 12).tobytes(), num_classes,
            budget,
        )

    def select(self, p: np.ndarray, num_classes: int, budget: float) -> SelectionResult:
        key_tuple = self._memo_key(p, num_classes, budget)
        if key_tuple in self._cache:
            return self._cache[key_tuple]
        res = sur_greedy(
            p,
            self.costs,
            budget,
            num_classes,
            jax.random.key(self.seed),
            self.theta(p, budget),
            use_kernel=self.use_kernel,
        )
        self._cache[key_tuple] = res
        return res

    def select_many(
        self,
        ps: np.ndarray,
        num_classes: int,
        budgets,
        max_group: int = 64,
    ) -> List[SelectionResult]:
        """Batched :meth:`select` over stacked (p-vector, budget) pairs.

        Cache-consistent with the serial path: cached pairs are returned
        as-is, the misses are planned by :func:`sur_greedy_many` in one
        device program (chunked at ``max_group`` groups to bound peak
        memory) and memoized under the same keys — so serial and batched
        callers share one selection cache and, by the planner's CRN
        contract, identical results.
        """
        ps = np.atleast_2d(np.asarray(ps, np.float64))
        G = ps.shape[0]
        budgets = np.broadcast_to(np.asarray(budgets, np.float64), (G,))
        keys = [
            self._memo_key(ps[g], num_classes, float(budgets[g]))
            for g in range(G)
        ]
        miss: List[int] = []
        seen = set()
        for g, k in enumerate(keys):
            if k not in self._cache and k not in seen:
                miss.append(g)
                seen.add(k)
        for s in range(0, len(miss), max_group):
            chunk = miss[s:s + max_group]
            thetas = np.asarray(
                [self.theta(ps[g], float(budgets[g])) for g in chunk], np.int64
            )
            res = sur_greedy_many(
                ps[chunk],
                self.costs,
                budgets[chunk],
                num_classes,
                jax.random.key(self.seed),
                thetas,
                use_kernel=self.use_kernel,
            )
            for g, r in zip(chunk, res):
                self._cache[keys[g]] = r
        return [self._cache[k] for k in keys]

    def answer(
        self,
        p: np.ndarray,
        num_classes: int,
        budget: float,
        invoke_fn: Callable[[int], int],
        rng: Optional[np.random.Generator] = None,
    ) -> InvocationResult:
        sel = self.select(p, num_classes, budget)
        return adaptive_invoke(
            list(sel.chosen), p, num_classes, invoke_fn, rng=rng, costs=self.costs
        )
