"""LLM ensemble selection: GreedyLLM (Alg. 1), SurGreedyLLM (Alg. 2) and the
adaptive ThriftLLM loop (Alg. 3).

The selector is control-plane code: pools are small (L ~ 12-16), so the outer
loops are numpy; every xi evaluation inside the greedy is batched through the
jit'd CRN Monte-Carlo estimator (one device call per greedy iteration).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .belief import (
    aggregate_log_beliefs,
    empty_log_belief,
    log_weight,
    predict_from_beliefs,
    top2_beliefs,
)
from .correctness import gamma
from .mc import McXiEstimator, theta_for
from .types import InvocationResult, SelectionResult, clip_probs

# Continue invoking on near-ties so Prop. 4 (prediction equality) holds
# deterministically; costs at most the paper's condition, never more than S*.
STOP_MARGIN = 1e-9
RATIO_TIE_RTOL = 1e-9


def greedy(
    p: np.ndarray,
    b: np.ndarray,
    budget: float,
    value_batch_fn: Callable[[np.ndarray], np.ndarray],
    empty_value: float,
) -> Tuple[List[int], float]:
    """GreedyLLM (Algorithm 1) on an arbitrary set function.

    Each iteration evaluates *all* affordable candidates in one batched call
    and adds the arm with the best marginal-gain / cost ratio; ties broken by
    the p/b ratio (Alg. 1 line 4). Returns (chosen order, final value).
    """
    p = np.asarray(p, np.float64)
    b = np.asarray(b, np.float64)
    L = p.size
    chosen: List[int] = []
    chosen_mask = np.zeros(L, np.float32)
    in_pool = np.ones(L, bool)
    spent = 0.0
    current = float(empty_value)

    while True:
        afford = np.flatnonzero(in_pool & (b <= budget - spent + 1e-15))
        if afford.size == 0:
            break
        cand = np.repeat(chosen_mask[None, :], afford.size, axis=0)
        cand[np.arange(afford.size), afford] = 1.0
        vals = np.asarray(value_batch_fn(cand), np.float64)
        ratios = (vals - current) / b[afford]
        best = float(np.max(ratios))
        tied = np.flatnonzero(np.isclose(ratios, best, rtol=RATIO_TIE_RTOL, atol=1e-15))
        if tied.size > 1:  # tie-break by success-prob / cost ratio
            pb = p[afford[tied]] / b[afford[tied]]
            tied = tied[np.argmax(pb)]
        else:
            tied = tied[0]
        pick = int(afford[int(tied)])
        chosen.append(pick)
        chosen_mask[pick] = 1.0
        in_pool[pick] = False
        spent += b[pick]
        current = float(vals[list(afford).index(pick)])  # vals aligned with afford
    return chosen, current


def gamma_value_batch(p: np.ndarray) -> Callable[[np.ndarray], np.ndarray]:
    """Batched closed-form gamma over candidate masks."""
    log1m = np.log1p(-clip_probs(p))

    def fn(masks: np.ndarray) -> np.ndarray:
        return 1.0 - np.exp(masks @ log1m)

    return fn


def sur_greedy(
    p: np.ndarray,
    b: np.ndarray,
    budget: float,
    num_classes: int,
    key: jax.Array,
    theta: int,
    p_all: Optional[np.ndarray] = None,
    use_kernel: bool = False,
) -> SelectionResult:
    """SurGreedyLLM (Algorithm 2) with CRN Monte-Carlo xi estimation.

    Returns the best of {best affordable single arm, greedy-on-xi,
    greedy-on-gamma} together with the Theorem 3 diagnostics.
    """
    p = clip_probs(p)
    b = np.asarray(b, np.float64)
    K = int(num_classes)
    est = McXiEstimator(key, p, K, theta, p_all=p_all, use_kernel=use_kernel)

    afford = np.flatnonzero(b <= budget + 1e-15)
    if afford.size == 0:
        return SelectionResult(
            chosen=np.zeros(0, np.int64), xi_est=1.0 / K, cost=0.0, budget=budget
        )
    l_star = int(afford[np.argmax(p[afford])])
    p_star = float(p[l_star])

    s1, _ = greedy(p, b, budget, est, empty_value=1.0 / K)
    s2, _ = greedy(p, b, budget, gamma_value_batch(p), empty_value=0.0)

    # Evaluate the three candidates with the *same* CRN draws.
    masks = np.zeros((3, p.size), np.float32)
    masks[0, l_star] = 1.0
    if s1:
        masks[1, np.asarray(s1)] = 1.0
    if s2:
        masks[2, np.asarray(s2)] = 1.0
    xi_vals = est(masks)
    cands = [np.asarray([l_star]), np.asarray(s1, np.int64), np.asarray(s2, np.int64)]
    pick = int(np.argmax(xi_vals))
    chosen = cands[pick]
    return SelectionResult(
        chosen=chosen,
        xi_est=float(xi_vals[pick]),
        cost=float(b[chosen].sum()) if chosen.size else 0.0,
        budget=budget,
        s1=cands[1],
        s2=cands[2],
        l_star=l_star,
        xi_s1=float(xi_vals[1]),
        xi_s2=float(xi_vals[2]),
        p_star=p_star,
        gamma_s2=gamma(p[np.asarray(s2, np.int64)]) if s2 else 0.0,
    )


def adaptive_invoke(
    selection: Sequence[int],
    p: np.ndarray,
    num_classes: int,
    invoke_fn: Callable[[int], int],
    p_all: Optional[np.ndarray] = None,
    rng: Optional[np.random.Generator] = None,
    costs: Optional[np.ndarray] = None,
) -> InvocationResult:
    """Adaptive invocation (Algorithm 3 lines 3-11).

    Invokes arms of ``selection`` in decreasing-p order and early-stops when
    the residual potential belief F(T*) can no longer change the prediction:
    ``F(T*) * H2(phi) <= H1(phi)`` (Prop. 4 guarantees prediction equality
    with the full set).

    Args:
      invoke_fn: ``arm_index -> class_id`` — runs the real model (or oracle).
    """
    p = clip_probs(p)
    K = int(num_classes)
    w = log_weight(p, K)
    empty = empty_log_belief(p if p_all is None else p_all)
    sel = sorted(selection, key=lambda i: -p[i])
    remaining = list(sel)

    used: List[int] = []
    responses: List[int] = []
    beliefs = np.full(K, empty, np.float64)
    counts = np.zeros(K, np.int64)

    while remaining:
        log_f = float(np.sum(w[remaining]))
        h1, h2, _ = top2_beliefs(beliefs)
        if not (log_f + h2 > h1 - STOP_MARGIN):
            break  # residual arms cannot flip the prediction (Prop. 4)
        arm = remaining.pop(0)
        r = int(invoke_fn(arm))
        used.append(arm)
        responses.append(r)
        if counts[r] == 0:
            beliefs[r] = w[arm]
        else:
            beliefs[r] += w[arm]
        counts[r] += 1

    pred, _ = predict_from_beliefs(beliefs, rng)
    cost_vec = np.asarray(costs, np.float64) if costs is not None else np.zeros(p.size)
    return InvocationResult(
        prediction=int(pred),
        used=np.asarray(used, np.int64),
        responses=np.asarray(responses, np.int64),
        cost=float(cost_vec[used].sum()) if used else 0.0,
        planned_cost=float(cost_vec[list(sel)].sum()) if len(sel) else 0.0,
        log_beliefs=beliefs,
    )


@dataclasses.dataclass
class ThriftLLM:
    """End-to-end selector (Algorithm 3): SurGreedy selection + adaptive
    invocation, parameterized by the paper's (eps, delta).

    One instance is bound to a pool (costs) and reused across query classes;
    per-class selections are cached because selection depends only on
    (p-vector, K, budget).
    """

    costs: np.ndarray
    eps: float = 0.1
    delta: float = 0.01
    seed: int = 0
    use_kernel: bool = False

    def __post_init__(self):
        self.costs = np.asarray(self.costs, np.float64)
        self._cache: dict = {}

    def rebind_costs(self, costs: np.ndarray) -> None:
        """Swap in a new pool cost vector and drop every cached selection.

        Selections depend on prices, so they cannot survive a re-pricing;
        the serving PlanService calls this when the pool fingerprint
        changes (see :meth:`repro.serving.plans.PlanService.refresh`).
        """
        self.costs = np.asarray(costs, np.float64)
        self._cache.clear()

    def trim_cache(self, max_entries: int) -> int:
        """Drop the oldest cached selections beyond ``max_entries``.

        Selection keys embed the p-vector, so once an estimate moves (the
        online-feedback steady state) its old entries can never be hit
        again — without trimming, continuous drift would grow the memo
        indefinitely. Insertion order doubles as age (never-rekeyed dict).
        Returns the number of entries dropped."""
        drop = len(self._cache) - int(max_entries)
        if drop <= 0:
            return 0
        for key in list(self._cache)[:drop]:
            del self._cache[key]
        return drop

    def theta(self, p: np.ndarray, budget: float) -> int:
        afford = np.flatnonzero(self.costs <= budget + 1e-15)
        p_star = float(np.max(clip_probs(p)[afford])) if afford.size else 1.0
        return theta_for(self.eps, self.delta, p_star, len(self.costs))

    def select(self, p: np.ndarray, num_classes: int, budget: float) -> SelectionResult:
        key_tuple = (np.round(np.asarray(p, np.float64), 12).tobytes(), num_classes, budget)
        if key_tuple in self._cache:
            return self._cache[key_tuple]
        res = sur_greedy(
            p,
            self.costs,
            budget,
            num_classes,
            jax.random.key(self.seed),
            self.theta(p, budget),
            use_kernel=self.use_kernel,
        )
        self._cache[key_tuple] = res
        return res

    def answer(
        self,
        p: np.ndarray,
        num_classes: int,
        budget: float,
        invoke_fn: Callable[[int], int],
        rng: Optional[np.random.Generator] = None,
    ) -> InvocationResult:
        sel = self.select(p, num_classes, budget)
        return adaptive_invoke(
            list(sel.chosen), p, num_classes, invoke_fn, rng=rng, costs=self.costs
        )
