"""Baseline selectors the paper compares against (Section 5 / 6).

* :class:`FrugalCascade` — FrugalGPT-style cost-ascending cascade with a
  belief-margin confidence gate; budget enforced only in expectation
  (faithful to the paper's criticism) with an optional strict per-query mode.
* :func:`blender_all` — LLM-Blender-style use-everything baseline with
  majority fusion (no budget awareness).
* :func:`topk_weighted` — LLM-Ensemble-style greedy top-weight under budget.
* :func:`single_best` / :func:`random_subset` — sanity baselines.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from .belief import aggregate_predict, empty_log_belief, log_weight, top2_beliefs
from .types import InvocationResult, clip_probs


@dataclasses.dataclass
class FrugalCascade:
    """Cost-ascending cascade: invoke the cheapest arm, escalate while the
    belief margin H1 - H2 is below ``margin`` and expected budget remains.

    FrugalGPT's scorer is a learned model; our gate uses the calibrated
    belief margin, which plays the same role (confidence of the current
    answer). ``strict`` switches to per-query budget enforcement for the
    fairness-adjusted comparison in the paper's Section 6.2.
    """

    costs: np.ndarray
    margin: float = 1.0
    strict: bool = False

    def answer(
        self,
        p: np.ndarray,
        num_classes: int,
        budget: float,
        invoke_fn: Callable[[int], int],
        rng: Optional[np.random.Generator] = None,
    ) -> InvocationResult:
        p = clip_probs(p)
        b = np.asarray(self.costs, np.float64)
        K = int(num_classes)
        w = log_weight(p, K)
        empty = empty_log_belief(p)
        order = np.argsort(b, kind="stable")

        beliefs = np.full(K, empty, np.float64)
        counts = np.zeros(K, np.int64)
        used: List[int] = []
        responses: List[int] = []
        spent = 0.0
        for arm in order:
            if self.strict and spent + b[arm] > budget + 1e-15:
                continue
            if not self.strict and spent >= budget:
                break
            r = int(invoke_fn(int(arm)))
            used.append(int(arm))
            responses.append(r)
            spent += float(b[arm])
            beliefs[r] = w[arm] if counts[r] == 0 else beliefs[r] + w[arm]
            counts[r] += 1
            h1, h2, _ = top2_beliefs(beliefs)
            if h1 - h2 >= self.margin:
                break
        # FrugalGPT adopts only the LAST executed model's response:
        pred = responses[-1] if responses else (int(rng.integers(K)) if rng else 0)
        return InvocationResult(
            prediction=int(pred),
            used=np.asarray(used, np.int64),
            responses=np.asarray(responses, np.int64),
            cost=spent,
            planned_cost=spent,
            log_beliefs=beliefs,
        )


def blender_all(
    p: np.ndarray,
    num_classes: int,
    invoke_fn: Callable[[int], int],
    costs: np.ndarray,
    rng: Optional[np.random.Generator] = None,
) -> InvocationResult:
    """Use-all-arms baseline with majority fusion (LLM-Blender analogue)."""
    L = len(p)
    responses = np.asarray([int(invoke_fn(i)) for i in range(L)], np.int64)
    pred = aggregate_predict(responses, np.asarray(p), num_classes, method="majority", rng=rng)
    return InvocationResult(
        prediction=pred,
        used=np.arange(L),
        responses=responses,
        cost=float(np.sum(costs)),
        planned_cost=float(np.sum(costs)),
        log_beliefs=np.zeros(num_classes),
    )


def topk_weighted(
    p: np.ndarray, costs: np.ndarray, budget: float
) -> np.ndarray:
    """LLM-Ensemble analogue: greedily take highest-p arms while affordable."""
    p = np.asarray(p, np.float64)
    b = np.asarray(costs, np.float64)
    chosen: List[int] = []
    spent = 0.0
    for arm in np.argsort(-p, kind="stable"):
        if spent + b[arm] <= budget + 1e-15:
            chosen.append(int(arm))
            spent += float(b[arm])
    return np.asarray(chosen, np.int64)


def single_best(p: np.ndarray, costs: np.ndarray, budget: float) -> np.ndarray:
    p = np.asarray(p, np.float64)
    afford = np.flatnonzero(np.asarray(costs, np.float64) <= budget + 1e-15)
    if afford.size == 0:
        return np.zeros(0, np.int64)
    return np.asarray([afford[np.argmax(p[afford])]], np.int64)


def random_subset(costs: np.ndarray, budget: float, rng: np.random.Generator) -> np.ndarray:
    b = np.asarray(costs, np.float64)
    order = rng.permutation(len(b))
    chosen: List[int] = []
    spent = 0.0
    for arm in order:
        if spent + b[arm] <= budget + 1e-15:
            chosen.append(int(arm))
            spent += float(b[arm])
    return np.asarray(chosen, np.int64)
