"""Core datatypes for the ThriftLLM ensemble-selection framework.

The control plane works on small dense arrays:
  * ``p``  -- (L,) success probabilities of the candidate pool on a query class
  * ``b``  -- (L,) per-query costs of the candidates (USD or FLOP-derived)
  * ``K``  -- number of classes of the classification query class
  * ``B``  -- budget per query (same unit as ``b``)

Arms are *operators* in the paper's DB framing: an arm wraps any callable
model (a real JAX model in ``repro.models`` or a simulated oracle in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Sequence

import numpy as np

# Numerical floor used when converting success probabilities to belief
# weights; keeps log(p(K-1)/(1-p)) finite for p in {0, 1}.
P_FLOOR = 1e-4


@dataclasses.dataclass(frozen=True)
class Arm:
    """One candidate LLM operator in the pool.

    Attributes:
      name: human-readable identifier (e.g. ``"smollm-135m"``).
      cost: per-query cost ``b_i``. For real models this is derived from
        FLOPs/token x $/FLOP so that stronger => pricier, mirroring the
        paper's Table 4 regime; a USD override may be supplied.
      invoke: optional callable ``(query) -> class_id`` used by the adaptive
        invocation loop (Algorithm 3). ``None`` for pure selection math.
      meta: free-form metadata (arch id, flops/token, provider, ...).
    """

    name: str
    cost: float
    invoke: Optional[Callable[[Any], int]] = None
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class QueryClass:
    """A query class Q: semantically-similar queries sharing success probs.

    Attributes:
      probs: (L,) estimated success probability of each arm on this class.
      num_classes: K, the label-space size of the classification task.
      lo / hi: optional (L,) confidence-interval bounds around ``probs``
        (Section 4.4); equal to ``probs`` when intervals are not tracked.
      meta: e.g. cluster id, centroid, sample count.
    """

    probs: np.ndarray
    num_classes: int
    lo: Optional[np.ndarray] = None
    hi: Optional[np.ndarray] = None
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        object.__setattr__(self, "probs", np.asarray(self.probs, np.float64))
        if self.lo is None:
            object.__setattr__(self, "lo", self.probs)
        if self.hi is None:
            object.__setattr__(self, "hi", self.probs)


@dataclasses.dataclass
class SelectionResult:
    """Output of SurGreedyLLM / ThriftLLM selection for one query class."""

    chosen: np.ndarray                 # (m,) int indices into the pool, ranked
    xi_est: float                      # estimated correctness prob of chosen
    cost: float                        # sum of costs of chosen
    budget: float
    # Diagnostics for the Theorem 3 instance-dependent bound:
    s1: Optional[np.ndarray] = None    # greedy-on-xi set
    s2: Optional[np.ndarray] = None    # greedy-on-gamma set
    l_star: Optional[int] = None       # best affordable single arm
    xi_s1: float = 0.0
    xi_s2: float = 0.0
    p_star: float = 0.0
    gamma_s2: float = 0.0

    @property
    def approx_ratio_bound(self) -> float:
        """Instance-dependent factor from Theorem 3 (excluding the 1-1/sqrt(e))."""
        denom = max(self.gamma_s2, self.p_star)
        if denom <= 0:
            return 0.0
        return max(self.xi_s1, self.xi_s2, self.p_star) / denom


@dataclasses.dataclass
class InvocationResult:
    """Output of the adaptive invocation loop (Algorithm 3, lines 3-11)."""

    prediction: int
    used: np.ndarray                   # indices actually invoked, in order
    responses: np.ndarray              # their responses
    cost: float                        # realized cost (<= planned cost)
    planned_cost: float                # cost of the full selected set S*
    log_beliefs: np.ndarray            # (K,) final log-belief per class


def clip_probs(p: np.ndarray, floor: float = P_FLOOR) -> np.ndarray:
    """Clip probabilities into [floor, 1-floor] for numerically-safe logits."""
    return np.clip(np.asarray(p, np.float64), floor, 1.0 - floor)


def pool_cost(b: np.ndarray, idx: Sequence[int]) -> float:
    return float(np.sum(np.asarray(b, np.float64)[np.asarray(idx, np.int64)])) if len(idx) else 0.0
