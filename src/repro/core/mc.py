"""Monte-Carlo estimation of the correctness probability (Lemma 4).

The estimator draws ``theta`` synthetic observations of the *whole pool* once
(common random numbers) and evaluates any candidate subset as a masked belief
contraction over those shared draws. CRN pairs the greedy comparisons, which
substantially reduces the variance of marginal-gain rankings and means one
``sample + one-hot`` materialization serves an entire SurGreedyLLM run.

The masked evaluation is a dense ``(C, L) x (theta, L, K)`` contraction — the
TPU hot-spot of the selector. ``repro.kernels.mc_correctness`` implements it
as a Pallas kernel with theta-tiling; :func:`xi_from_responses` is its oracle.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .belief import empty_log_belief, log_weight
from .types import clip_probs

TIE_TOL = 1e-6


def theta_for(eps: float, delta: float, p_star: float, num_arms: int) -> int:
    """theta = (8 + 2 eps) / (eps^2 p*) * ln(2 L^2 / delta)  (Algorithm 3)."""
    p_star = max(p_star, 1e-6)
    theta = (8.0 + 2.0 * eps) / (eps * eps * p_star) * math.log(2.0 * num_arms * num_arms / delta)
    return int(math.ceil(theta))


@functools.partial(jax.jit, static_argnames=("num_classes", "theta"))
def sample_pool_responses(
    key: jax.Array, p: jnp.ndarray, num_classes: int, theta: int
) -> jnp.ndarray:
    """(theta, L) int32 responses of every arm, ground truth = class 0.

    Arm i answers 0 w.p. p_i, else uniformly one of the K-1 wrong classes.
    """
    num_arms = p.shape[0]
    ku, kc = jax.random.split(key)
    u = jax.random.uniform(ku, (theta, num_arms))
    wrong = jax.random.randint(kc, (theta, num_arms), 1, num_classes)
    return jnp.where(u < p[None, :], 0, wrong).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_classes",))
def xi_from_responses(
    responses: jnp.ndarray,     # (theta, L) int32
    masks: jnp.ndarray,         # (C, L) float32 subset indicators
    log_weights: jnp.ndarray,   # (L,) float32
    empty_belief: jnp.ndarray,  # scalar float32
    num_classes: int,
) -> jnp.ndarray:
    """Estimate xi for C candidate subsets from shared response draws.

    Returns (C,) float32. Fractional tie credit reproduces random
    tie-breaking in expectation. This function is the pure-jnp oracle of the
    ``mc_correctness`` Pallas kernel.
    """
    onehot = jax.nn.one_hot(responses, num_classes, dtype=jnp.float32)  # (T, L, K)
    mw = masks * log_weights[None, :]                                   # (C, L)
    beliefs = jnp.einsum("cl,tlk->ctk", mw, onehot)                     # (C, T, K)
    counts = jnp.einsum("cl,tlk->ctk", masks, onehot)
    beliefs = jnp.where(counts > 0, beliefs, empty_belief)
    mx = jnp.max(beliefs, axis=-1, keepdims=True)
    is_max = (beliefs >= mx - TIE_TOL).astype(jnp.float32)
    ties = jnp.sum(is_max, axis=-1)
    credit = is_max[:, :, 0] / ties
    return jnp.mean(credit, axis=-1)


class McXiEstimator:
    """Stateful CRN estimator bound to one (pool, query-class) pair.

    Usage::

        est = McXiEstimator(key, p, K, theta)
        vals = est(masks)          # (C,) numpy
        x    = est.xi(indices)     # scalar
    """

    def __init__(
        self,
        key: jax.Array,
        p: np.ndarray,
        num_classes: int,
        theta: int,
        p_all: Optional[np.ndarray] = None,
        use_kernel: bool = False,
    ):
        self.p = clip_probs(p)
        self.num_arms = int(self.p.size)
        self.num_classes = int(num_classes)
        self.theta = int(theta)
        self.use_kernel = use_kernel
        self._w = jnp.asarray(log_weight(self.p, self.num_classes), jnp.float32)
        self._empty = jnp.float32(
            empty_log_belief(self.p if p_all is None else p_all)
        )
        self._responses = sample_pool_responses(
            key, jnp.asarray(self.p, jnp.float32), self.num_classes, self.theta
        )

    def __call__(self, masks: np.ndarray) -> np.ndarray:
        masks = jnp.asarray(np.atleast_2d(masks), jnp.float32)
        if self.use_kernel:
            from repro.kernels import ops as kernel_ops  # lazy: optional dep

            vals = kernel_ops.mc_correctness(
                self._responses, masks, self._w, self._empty, self.num_classes
            )
        else:
            vals = xi_from_responses(
                self._responses, masks, self._w, self._empty, self.num_classes
            )
        return np.asarray(vals)

    def xi(self, indices) -> float:
        mask = np.zeros(self.num_arms, np.float32)
        if len(indices):
            mask[np.asarray(indices, np.int64)] = 1.0
        return float(self(mask[None, :])[0])
