"""Monte-Carlo estimation of the correctness probability (Lemma 4).

The estimator draws ``theta`` synthetic observations of the *whole pool* once
(common random numbers) and evaluates any candidate subset as a masked belief
contraction over those shared draws. CRN pairs the greedy comparisons, which
substantially reduces the variance of marginal-gain rankings and means one
``sample + one-hot`` materialization serves an entire SurGreedyLLM run.

The masked evaluation is a dense ``(C, L) x (theta, L, K)`` contraction — the
TPU hot-spot of the selector. ``repro.kernels.mc_correctness`` implements it
as a Pallas kernel with theta-tiling; :func:`xi_from_responses` is its oracle.

Batched planning (`sur_greedy_many`) stacks G groups' draws into one
:class:`GroupedXiEstimator` over ``(G, theta_max, L)`` response tensors, so
a whole (cluster, budget) batch shares one device program per greedy round.
The grouped evaluators (:func:`xi_from_responses_grouped`,
:func:`xi_marginal_grouped`) are written for *bit-stability*: every
floating-point reduction is either exact (integer-valued tie counts,
order-independent in any tiling/padding/batching) or an elementwise chain in
a fixed order, so group g's xi values are bitwise identical whether it is
evaluated alone (G=1, theta_g draws) or inside a padded (G, theta_max)
batch. That is the contract the batched-vs-serial equivalence suite pins.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .belief import empty_log_belief, log_weight
from .types import clip_probs

TIE_TOL = 1e-6


def theta_for(eps: float, delta: float, p_star: float, num_arms: int) -> int:
    """theta = (8 + 2 eps) / (eps^2 p*) * ln(2 L^2 / delta)  (Algorithm 3)."""
    p_star = max(p_star, 1e-6)
    theta = (8.0 + 2.0 * eps) / (eps * eps * p_star) * math.log(2.0 * num_arms * num_arms / delta)
    return int(math.ceil(theta))


def _draw_rows(key: jax.Array, num_arms: int, num_classes: int, theta: int):
    """(theta, L) uniform + wrong-class draws whose row ``t`` depends only
    on ``(key, t)`` (per-row ``fold_in``), never on ``theta``.

    This counter-stability is what lets the grouped sampler draw ONE
    ``(theta_max, L)`` tensor and hand every group its own prefix — bitwise
    the draws :func:`sample_pool_responses` would make for that group's
    theta alone.
    """
    ku, kc = jax.random.split(key)

    def row(t):
        u = jax.random.uniform(jax.random.fold_in(ku, t), (num_arms,))
        wrong = jax.random.randint(
            jax.random.fold_in(kc, t), (num_arms,), 1, num_classes
        )
        return u, wrong

    return jax.vmap(row)(jnp.arange(theta, dtype=jnp.int32))


@functools.partial(jax.jit, static_argnames=("num_classes", "theta"))
def sample_pool_responses(
    key: jax.Array, p: jnp.ndarray, num_classes: int, theta: int
) -> jnp.ndarray:
    """(theta, L) int32 responses of every arm, ground truth = class 0.

    Arm i answers 0 w.p. p_i, else uniformly one of the K-1 wrong classes.
    """
    u, wrong = _draw_rows(key, p.shape[0], num_classes, theta)
    return jnp.where(u < p[None, :], 0, wrong).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_classes", "theta"))
def sample_pool_responses_grouped(
    key: jax.Array, ps: jnp.ndarray, num_classes: int, theta: int
) -> jnp.ndarray:
    """(G, theta, L) responses for G groups sharing one CRN draw tensor.

    Group g's rows ``[:theta_g]`` are bitwise identical to
    ``sample_pool_responses(key, ps[g], num_classes, theta_g)`` — the
    per-row ``fold_in`` makes draws prefix-stable, so one dispatch serves
    every ragged theta (callers mask rows past each group's own theta).
    """
    u, wrong = _draw_rows(key, ps.shape[1], num_classes, theta)
    return jnp.where(u[None] < ps[:, None, :], 0, wrong[None]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_classes",))
def xi_from_responses(
    responses: jnp.ndarray,     # (theta, L) int32
    masks: jnp.ndarray,         # (C, L) float32 subset indicators
    log_weights: jnp.ndarray,   # (L,) float32
    empty_belief: jnp.ndarray,  # scalar float32
    num_classes: int,
) -> jnp.ndarray:
    """Estimate xi for C candidate subsets from shared response draws.

    Returns (C,) float32. Fractional tie credit reproduces random
    tie-breaking in expectation. This function is the pure-jnp oracle of the
    ``mc_correctness`` Pallas kernel.
    """
    onehot = jax.nn.one_hot(responses, num_classes, dtype=jnp.float32)  # (T, L, K)
    mw = masks * log_weights[None, :]                                   # (C, L)
    beliefs = jnp.einsum("cl,tlk->ctk", mw, onehot)  # thriftlint: ignore[f64-reduction] (C,T,K) f32 by design: this is the bit-level oracle of the f32 mc_correctness kernel
    counts = jnp.einsum("cl,tlk->ctk", masks, onehot)  # thriftlint: ignore[f64-reduction] f32 by design: kernel-oracle parity (and counts are exact small ints)
    beliefs = jnp.where(counts > 0, beliefs, empty_belief)
    mx = jnp.max(beliefs, axis=-1, keepdims=True)
    is_max = (beliefs >= mx - TIE_TOL).astype(jnp.float32)
    ties = jnp.sum(is_max, axis=-1)  # thriftlint: ignore[f64-reduction] exact: sums K indicator values, K << 2^24
    credit = is_max[:, :, 0] / ties
    return jnp.mean(credit, axis=-1)  # thriftlint: ignore[f64-reduction] f32 by design: the kernel reduces credit in f32; serial oracle must match it bitwise


class McXiEstimator:
    """Stateful CRN estimator bound to one (pool, query-class) pair.

    Usage::

        est = McXiEstimator(key, p, K, theta)
        vals = est(masks)          # (C,) numpy
        x    = est.xi(indices)     # scalar
    """

    def __init__(
        self,
        key: jax.Array,
        p: np.ndarray,
        num_classes: int,
        theta: int,
        p_all: Optional[np.ndarray] = None,
        use_kernel: bool = False,
    ):
        self.p = clip_probs(p)
        self.num_arms = int(self.p.size)
        self.num_classes = int(num_classes)
        self.theta = int(theta)
        self.use_kernel = use_kernel
        self._w = jnp.asarray(log_weight(self.p, self.num_classes), jnp.float32)
        self._empty = jnp.float32(
            empty_log_belief(self.p if p_all is None else p_all)
        )
        self._responses = sample_pool_responses(
            key, jnp.asarray(self.p, jnp.float32), self.num_classes, self.theta
        )

    def __call__(self, masks: np.ndarray) -> np.ndarray:
        masks = jnp.asarray(np.atleast_2d(masks), jnp.float32)
        if self.use_kernel:
            from repro.kernels import ops as kernel_ops  # lazy: optional dep

            vals = kernel_ops.mc_correctness(
                self._responses, masks, self._w, self._empty, self.num_classes
            )
        else:
            vals = xi_from_responses(
                self._responses, masks, self._w, self._empty, self.num_classes
            )
        return np.asarray(vals)

    def xi(self, indices) -> float:
        mask = np.zeros(self.num_arms, np.float32)
        if len(indices):
            mask[np.asarray(indices, np.int64)] = 1.0
        return float(self(mask[None, :])[0])


# ---------------------------------------------------------------------------
# Grouped (batched-planner) evaluation
# ---------------------------------------------------------------------------


def bucket_size(n: int, base: int) -> int:
    """Round ``n`` up to a compile bucket: multiples of ``base`` up to
    ``4 * base``, powers of two beyond (same policy as the serving router's
    wave buckets) — the grouped programs compile once per bucket instead of
    once per exact (G, theta)."""
    n = max(1, int(n))
    if n <= 4 * base:
        return max(base, -(-n // base) * base)
    m = 4 * base
    while m < n:
        m *= 2
    return m


def _hist_from_ties(hit0: jnp.ndarray, ties: jnp.ndarray, num_classes: int):
    """(hit0 (..., T) bool, ties (..., T) i32) -> (..., K) f32 counts.

    ``counts[..., j]`` = number of draws where class 0 attains the maximum
    belief with exactly ``j + 1`` classes tied. Every reduction sums
    integer-valued f32 (exact below 2^24), so the result is independent of
    summation order, padding and batching — the bit-stability anchor of
    the batched planner.
    """
    one = jnp.float32(1.0)
    zero = jnp.float32(0.0)
    return jnp.stack(
        [
            jnp.sum(jnp.where(hit0 & (ties == j + 1), one, zero), axis=-1)  # thriftlint: ignore[f64-reduction] exact: 0/1 indicator counts below 2^24, order-free by construction (see docstring)
            for j in range(num_classes)
        ],
        axis=-1,
    )


def _xi_from_ties(hit0: jnp.ndarray, ties: jnp.ndarray, theta: jnp.ndarray,
                  num_classes: int):
    """Exact fractional-credit mean from per-draw (hit0, ties) configs.

    Fast path (lcm(1..K) < 2^24, i.e. K <= 17): each draw's credit
    ``1/ties`` is scaled by the lcm into an exact small integer, summed
    exactly (f64 accumulator, order-free), and divided once — a single
    reduction instead of a per-tie-count histogram. Beyond that the
    histogram path keeps exactness. Both are bitwise batching-invariant
    and the planes share whichever branch K selects.
    """
    lcm = math.lcm(*range(1, num_classes + 1))
    if lcm < (1 << 24):
        # f32 division is exact here: ties divides the lcm, so the true
        # quotient is an integer < 2^24 and correct rounding returns it
        scaled = jnp.float32(lcm) / jnp.maximum(ties, 1).astype(jnp.float32)
        credit = jnp.where(hit0, scaled, jnp.float32(0.0))
        s = jnp.sum(credit, axis=-1, dtype=jnp.float64)
        return s / (theta * np.float64(lcm))
    hist = _hist_from_ties(hit0, ties, num_classes)
    return _xi_from_hist(hist, theta, num_classes)


def _tie_histogram(disp: jnp.ndarray, valid: jnp.ndarray, num_classes: int):
    """Per-draw tie bookkeeping of the fractional-credit estimator.

    ``disp`` is ``(..., T, K)`` displayed log-beliefs; ``valid`` broadcasts
    over the draw axis with 0 marking padding. Returns the per-draw
    ``(hit0, ties)`` max/tie configuration.
    """
    mx = jnp.max(disp, axis=-1, keepdims=True)
    is_max = disp >= mx - TIE_TOL
    ties = jnp.sum(is_max.astype(jnp.int32), axis=-1)     # (..., T)
    hit0 = is_max[..., 0] & (valid > 0)
    return hit0, ties


def _xi_from_hist(hist: jnp.ndarray, theta: jnp.ndarray, num_classes: int):
    """Exact tie-count histogram -> xi, in float64.

    ``xi = (sum_j hist_j / (j + 1)) / theta`` evaluated as a fixed-order
    elementwise chain — deterministic IEEE ops, so the value per group does
    not depend on how many groups share the program.
    """
    acc = hist[..., 0].astype(jnp.float64)
    for j in range(1, num_classes):
        acc = acc + hist[..., j].astype(jnp.float64) / np.float64(j + 1)
    return acc / theta


def _masked_xi_core(responses, masks, log_weights, empty, valid, theta,
                    num_classes: int):
    """xi of C arbitrary (binary-mask) subsets per group.

    responses: (G, T, L) int32, -1 past each group's theta.
    masks:     (G, C, L) f32 0/1 subset indicators.
    log_weights: (G, L) f32; empty: (G,) f32; valid: (G, T) f32;
    theta: (G,) f64. Returns (G, C) f64.

    Belief accumulation is an explicit chain over the (static) arm axis in
    ascending index order — no dot contraction whose reduction tree could
    vary with shape — so per-group values are batching-invariant.
    """
    G, T, L = responses.shape
    K = num_classes
    C = masks.shape[1]
    oh = responses[..., None] == jnp.arange(K, dtype=responses.dtype)
    raw = jnp.zeros((G, C, T, K), jnp.float32)
    cnt = jnp.zeros((G, C, T, K), jnp.int32)
    for l in range(L):
        sel = (masks[:, :, l] > 0)[:, :, None, None] & oh[:, :, l, :][:, None]
        w_l = log_weights[:, l][:, None, None, None]
        raw = jnp.where(sel, raw + w_l, raw)
        cnt = cnt + sel.astype(jnp.int32)
    disp = jnp.where(cnt > 0, raw, empty[:, None, None, None])
    hit0, ties = _tie_histogram(disp, valid[:, None, :], K)
    return _xi_from_ties(hit0, ties, theta[:, None], K)


def _marginal_xi_core(resp_t, base_raw, base_cnt, log_weights, empty,
                      valid, theta, num_classes: int):
    """xi of (current set ∪ {l}) for every arm l, per group.

    The greedy hot path: the current set's belief table ``(base_raw,
    base_cnt)`` (accumulated incrementally in pick order) is extended by one
    arm's response column, so a round costs O(G L theta K) elementwise work
    instead of rebuilding every candidate mask from scratch. A candidate's
    response touches exactly one class per draw, so the displayed beliefs
    are one ``where`` over the (precomputed, L-independent) base display
    table — the same IEEE values the mask chain produces, with half the
    memory traffic.

    A candidate only moves ONE class's belief per draw, so instead of
    materializing the (G, L, T, K) modified tables this decomposes against
    the base's exact top-2: with ``a`` the modified class's new value and
    ``excl`` the exact max over the other classes, the new max is
    ``max(a, excl)`` and the tie count is recovered from per-class
    threshold counts. All selections (max, second max, duplicate count)
    are exact, so the result is bitwise the naive per-candidate max — at a
    fraction of the memory traffic.

    resp_t: (G, L, T) int32 wave-major-transposed responses;
    base_raw: (G, T, K) f32; base_cnt: (G, T, K) int32. Returns (G, L) f64.
    """
    K = num_classes
    G, L, T = resp_t.shape
    base_disp = jnp.where(base_cnt > 0, base_raw, empty[:, None, None])

    # exact top-2 of the base display, plus the max's multiplicity
    m1 = jnp.full((G, T), -jnp.inf, base_disp.dtype)
    m2 = m1
    c1 = jnp.zeros((G, T), jnp.int32)
    for k in range(K):
        v = base_disp[:, :, k]
        gt = v > m1
        eq = v == m1
        m2 = jnp.where(gt, m1, jnp.maximum(m2, v))
        c1 = jnp.where(gt, 1, jnp.where(eq, c1 + 1, c1))
        m1 = jnp.where(gt, v, m1)

    is_mod = resp_t >= 0                                  # -1 = no response
    kc = jnp.maximum(resp_t, 0)                           # (G, L, T)
    # per-draw class select as a K-step where chain (CPU-vectorizable,
    # unlike a general gather); selects exact values, order-free
    rawstar = jnp.broadcast_to(base_raw[:, None, :, 0], (G, L, T))
    dispstar = jnp.broadcast_to(base_disp[:, None, :, 0], (G, L, T))
    for k in range(1, K):
        hit = kc == k
        rawstar = jnp.where(hit, base_raw[:, None, :, k], rawstar)
        dispstar = jnp.where(hit, base_disp[:, None, :, k], dispstar)
    # the modified class's new value; an unmodified draw keeps its display
    a = jnp.where(is_mod, rawstar + log_weights[:, :, None], dispstar)
    excl = jnp.where(
        dispstar == m1[:, None, :],
        jnp.where(c1[:, None, :] >= 2, m1[:, None, :], m2[:, None, :]),
        m1[:, None, :],
    )                                                     # exact max over k != k*
    mx = jnp.maximum(a, excl)
    thr = mx - TIE_TOL
    # count of classes >= thr in the modified display: the candidate's own
    # class compares at `a`, every other class at its base display
    n_ge = jnp.zeros((G, L, T), jnp.int32)
    for k in range(K):
        n_ge = n_ge + (base_disp[:, :, k][:, None, :] >= thr).astype(jnp.int32)
    ties = (a >= thr).astype(jnp.int32) + n_ge - (dispstar >= thr).astype(jnp.int32)
    disp0 = jnp.where(
        is_mod & (kc == 0), a, base_disp[:, :, 0][:, None, :]
    )
    hit0 = (disp0 >= thr) & (valid[:, None, :] > 0)
    return _xi_from_ties(hit0, ties, theta[:, None], K)


def _tables_xi_core(base_raw, base_cnt, empty, valid, theta, num_classes: int):
    """xi from prebuilt belief tables — the cheap final-candidate path.

    ``base_raw``/``base_cnt`` are (G, C, T, K) tables accumulated on the
    host in ascending arm order (the same operand sequence as the mask
    chain in :func:`_masked_xi_core`, hence the same IEEE values); the
    device only pays the empty-class display, the tie histogram and the
    combine. Returns (G, C) f64.
    """
    disp = jnp.where(base_cnt > 0, base_raw, empty[:, None, None, None])
    hit0, ties = _tie_histogram(disp, valid[:, None, :], num_classes)
    return _xi_from_ties(hit0, ties, theta[:, None], num_classes)


xi_from_responses_grouped = functools.partial(
    jax.jit, static_argnames=("num_classes",)
)(_masked_xi_core)

xi_marginal_grouped = functools.partial(
    jax.jit, static_argnames=("num_classes",)
)(_marginal_xi_core)

xi_from_tables_grouped = functools.partial(
    jax.jit, static_argnames=("num_classes",)
)(_tables_xi_core)


class GroupedXiEstimator:
    """The CRN estimator reshaped over G groups for the batched planner.

    Each group g gets exactly the draws the serial :class:`McXiEstimator`
    would sample for it — ``sample_pool_responses(key, p_g, K, theta_g)``
    with the *shared* key — stacked into one ``(G, theta_max, L)`` tensor
    (padded with -1 responses and a 0 ``valid`` mask past each group's own
    theta, ``theta_max`` rounded up to a compile bucket). Mask evaluation
    and the greedy's marginal-gain evaluation are then single dispatches
    covering every group.

    Usage::

        est = GroupedXiEstimator(key, ps, K, thetas)    # ps (G, L)
        vals = est(masks)                               # (G, C) f64
        gains = est.marginal(base_raw, base_cnt)        # (G, L) f64
    """

    def __init__(
        self,
        key: jax.Array,
        ps: np.ndarray,
        num_classes: int,
        thetas,
        p_all: Optional[np.ndarray] = None,
        use_kernel: bool = False,
        tile: int = 256,
    ):
        ps = clip_probs(np.atleast_2d(np.asarray(ps, np.float64)))
        G, L = ps.shape
        self.ps = ps
        self.num_groups = G
        self.num_arms = L
        self.num_classes = int(num_classes)
        self.use_kernel = bool(use_kernel)
        thetas = np.broadcast_to(np.asarray(thetas, np.int64), (G,))
        self.thetas = thetas
        Tp = bucket_size(int(thetas.max()), tile)
        # one dispatch samples every group's draws (prefix-stable rows);
        # rows past each group's own theta are masked to -1 / invalid
        self.responses = np.array(
            sample_pool_responses_grouped(
                key, jnp.asarray(ps, jnp.float32), self.num_classes, Tp
            )
        )
        self.valid = (
            np.arange(Tp)[None, :] < thetas[:, None]
        ).astype(np.float32)
        self.responses[self.valid == 0.0] = -1
        # candidate-major layout for the greedy's marginal evaluation
        self.responses_t = np.ascontiguousarray(
            self.responses.transpose(0, 2, 1)
        )
        # vectorized over groups: `log_weight` is elementwise and the empty
        # belief is a row-min chain, so these are the exact per-group
        # `log_weight(ps[g], K)` / `empty_log_belief(base[g])` bits
        self.log_weights = log_weight(ps, self.num_classes).astype(np.float32)
        base = ps if p_all is None else clip_probs(
            np.broadcast_to(np.atleast_2d(np.asarray(p_all, np.float64)), (G, L))
        )
        p_min = np.min(clip_probs(base), axis=1)
        self.empty = (
            np.log(p_min) - np.log(2.0) - np.log1p(-p_min)
        ).astype(np.float32)
        self.theta_f = thetas.astype(np.float64)

    def __call__(self, masks: np.ndarray) -> np.ndarray:
        """(G, C, L) binary masks -> (G, C) xi estimates (f64 numpy)."""
        masks = np.asarray(masks, np.float32)
        if self.use_kernel:
            from repro.kernels import ops as kernel_ops  # lazy: optional dep

            vals = kernel_ops.mc_correctness_grouped(
                jnp.asarray(self.responses), jnp.asarray(masks),
                jnp.asarray(self.log_weights), jnp.asarray(self.empty),
                jnp.asarray(self.valid),
                jnp.asarray(self.theta_f, jnp.float32), self.num_classes,
            )
            return np.asarray(vals, np.float64)
        # host-accumulated belief tables (ascending arm order = the mask
        # chain's operand sequence), one cheap device pass for the rest
        G, C, L = masks.shape
        T = self.responses.shape[1]
        K = self.num_classes
        raw = np.zeros((G, C, T, K), np.float32)
        cnt = np.zeros((G, C, T, K), np.int32)
        for g in range(G):
            resp = self.responses[g]
            for c in range(C):
                for l in np.flatnonzero(masks[g, c] > 0):
                    col = resp[:, l]
                    rows = np.flatnonzero(col >= 0)
                    raw[g, c, rows, col[rows]] += self.log_weights[g, l]
                    cnt[g, c, rows, col[rows]] += 1
        with enable_x64():
            vals = xi_from_tables_grouped(
                raw, cnt, self.empty, self.valid, self.theta_f,
                num_classes=K,
            )
        return np.asarray(vals)

    def marginal(self, base_raw: np.ndarray, base_cnt: np.ndarray) -> np.ndarray:
        """(G, T, K) current-set belief tables -> (G, L) xi of set ∪ {l}."""
        with enable_x64():
            vals = xi_marginal_grouped(
                self.responses_t, np.asarray(base_raw, np.float32),
                np.asarray(base_cnt, np.int32), self.log_weights, self.empty,
                self.valid, self.theta_f, num_classes=self.num_classes,
            )
        return np.asarray(vals)

    def _accumulate(self, raw: np.ndarray, cnt: np.ndarray, g: int,
                    arms) -> None:
        """Fold ``arms``' response columns of group ``g`` into one (T, K)
        belief table, in the given arm order (one f32 add per draw per arm —
        the same operand sequence on every plane)."""
        resp = self.responses[g]
        t = int(self.thetas[g])
        rows = np.arange(t)
        for l in arms:
            col = resp[:t, int(l)]
            raw[rows, col] += self.log_weights[g, int(l)]
            cnt[rows, col] += 1

    def final_xi(
        self,
        l_stars,
        s1s,
        s2s,
        s1_raw: Optional[np.ndarray] = None,
        s1_cnt: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """xi of the three Alg. 2 candidates per group -> (G, 3) f64.

        The greedy already accumulated each group's s1 belief table
        (``s1_raw``/``s1_cnt``, in pick order) — it is reused as-is; the
        single-arm l* and the gamma set s2 tables are folded on the host in
        ascending arm order, and one grouped device pass scores all 3G
        candidates. The kernel backend evaluates the same three sets from
        their masks instead (mask layout is what the kernel implements).
        """
        G = self.num_groups
        L = self.num_arms
        K = self.num_classes
        if self.use_kernel or s1_raw is None:
            masks = np.zeros((G, 3, L), np.float32)
            for g in range(G):
                masks[g, 0, int(l_stars[g])] = 1.0
                if len(s1s[g]):
                    masks[g, 1, np.asarray(s1s[g], np.int64)] = 1.0
                if len(s2s[g]):
                    masks[g, 2, np.asarray(s2s[g], np.int64)] = 1.0
            return self(masks)
        T = self.responses.shape[1]
        raw = np.zeros((G, 3, T, K), np.float32)
        cnt = np.zeros((G, 3, T, K), np.int32)
        raw[:, 1] = s1_raw
        cnt[:, 1] = s1_cnt
        for g in range(G):
            self._accumulate(raw[g, 0], cnt[g, 0], g, [int(l_stars[g])])
            self._accumulate(raw[g, 2], cnt[g, 2], g, sorted(int(a) for a in s2s[g]))
        with enable_x64():
            vals = xi_from_tables_grouped(
                raw, cnt, self.empty, self.valid, self.theta_f,
                num_classes=K,
            )
        return np.asarray(vals)
