"""Embedding clustering for query-class discovery (Section 3.1).

The paper embeds queries with the OpenAI embeddings API and clusters with
DBSCAN. We are self-contained: blocked K-means (used by the benchmarks for
its predictable cluster count, mirroring the paper's App. B analysis) and a
blocked-O(N^2) DBSCAN faithful to the paper's stated choice.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _pairwise_sq_dists_blocked(x: np.ndarray, y: np.ndarray, block: int = 2048) -> np.ndarray:
    """(N, d) x (M, d) -> (N, M) squared distances, computed in row blocks."""
    n = x.shape[0]
    out = np.empty((n, y.shape[0]), np.float64)
    y_sq = (y * y).sum(axis=1)
    for s in range(0, n, block):
        e = min(s + block, n)
        xb = x[s:e]
        out[s:e] = (xb * xb).sum(axis=1)[:, None] - 2.0 * xb @ y.T + y_sq[None, :]
    return np.maximum(out, 0.0)


def kmeans(
    x: np.ndarray, k: int, iters: int = 50, seed: int = 0, tol: float = 1e-7
) -> Tuple[np.ndarray, np.ndarray]:
    """K-means++ init + Lloyd iterations. Returns (assignments (N,), centroids (k, d))."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    k = min(k, n)
    rng = np.random.default_rng(seed)

    # k-means++ seeding
    centroids = np.empty((k, x.shape[1]), np.float64)
    centroids[0] = x[rng.integers(n)]
    d2 = ((x - centroids[0]) ** 2).sum(axis=1)
    for j in range(1, k):
        probs = d2 / max(d2.sum(), 1e-30)
        centroids[j] = x[rng.choice(n, p=probs)]
        d2 = np.minimum(d2, ((x - centroids[j]) ** 2).sum(axis=1))

    assign = np.zeros(n, np.int64)
    for _ in range(iters):
        d = _pairwise_sq_dists_blocked(x, centroids)
        new_assign = d.argmin(axis=1)
        shift = 0.0
        for j in range(k):
            pts = x[new_assign == j]
            if pts.size:
                c = pts.mean(axis=0)
                shift += float(((c - centroids[j]) ** 2).sum())
                centroids[j] = c
        assign = new_assign
        if shift < tol:
            break
    return assign, centroids


def dbscan(x: np.ndarray, eps: float, min_pts: int = 4, block: int = 2048) -> np.ndarray:
    """DBSCAN over euclidean distance; noise labelled -1.

    Blocked neighbor computation keeps peak memory at O(block * N).
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    eps_sq = eps * eps
    labels = np.full(n, -2, np.int64)  # -2 unvisited, -1 noise
    # Precompute neighbor lists blockwise.
    neighbors = [None] * n
    for s in range(0, n, block):
        e = min(s + block, n)
        d = _pairwise_sq_dists_blocked(x[s:e], x)
        for i in range(s, e):
            neighbors[i] = np.flatnonzero(d[i - s] <= eps_sq)

    cid = 0
    for i in range(n):
        if labels[i] != -2:
            continue
        if neighbors[i].size < min_pts:
            labels[i] = -1
            continue
        labels[i] = cid
        frontier = list(neighbors[i])
        while frontier:
            j = frontier.pop()
            if labels[j] == -1:
                labels[j] = cid
            if labels[j] != -2:
                continue
            labels[j] = cid
            if neighbors[j].size >= min_pts:
                frontier.extend(neighbors[j])
        cid += 1
    return labels


def auto_eps(x: np.ndarray, q: float = 0.15, sample: int = 1024, seed: int = 0) -> float:
    """Heuristic eps: q-quantile of pairwise distances on a subsample."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = rng.choice(n, size=min(sample, n), replace=False)
    d = np.sqrt(_pairwise_sq_dists_blocked(x[idx], x[idx]))
    vals = d[np.triu_indices_from(d, k=1)]
    return float(np.quantile(vals, q)) if vals.size else 1.0
