"""Correctness probability xi(S) (Def. 1) and the surrogate gamma(S) (Eq. 5).

Exact xi enumerates the observation space Omega_S (size K^|S|) with fully
vectorized numpy — used for tests, small ensembles, and as the oracle for the
Monte-Carlo estimator. Ground truth is fixed to class 0 WLOG (Prop. 1).

gamma(S) = 1 - prod_{l in S} (1 - p_l) is the submodular upper bound
(Lemma 3); its marginals are closed-form.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from .belief import empty_log_belief, log_weight
from .types import clip_probs

# Enumeration budget: refuse exact computation beyond this many
# (observation x class) table entries; callers fall back to Monte Carlo.
EXACT_ENUM_CAP = 40_000_000


def gamma(p: np.ndarray) -> float:
    """Surrogate gamma(S) = 1 - prod(1 - p) over the arms in S."""
    p = np.asarray(p, np.float64)
    if p.size == 0:
        return 0.0
    return float(1.0 - np.prod(1.0 - p))


def gamma_marginal(p_new: float, p_chosen: np.ndarray) -> float:
    """gamma(S + l) - gamma(S) = p_l * prod_{S}(1 - p)."""
    return float(p_new * np.prod(1.0 - np.asarray(p_chosen, np.float64)))


def xi_exact_feasible(m: int, num_classes: int, cap: int = EXACT_ENUM_CAP) -> bool:
    if m == 0:
        return True
    return (num_classes ** m) * num_classes <= cap


def enumerate_observations(m: int, num_classes: int) -> np.ndarray:
    """All K^m observations as an (T, m) int array (mixed-radix counting)."""
    T = num_classes ** m
    obs = np.empty((T, m), np.int64)
    idx = np.arange(T)
    for j in range(m):
        obs[:, m - 1 - j] = (idx // (num_classes ** j)) % num_classes
    return obs


def xi_exact(
    p: np.ndarray,
    num_classes: int,
    p_all: Optional[np.ndarray] = None,
    tol: float = 1e-12,
    cap: int = EXACT_ENUM_CAP,
) -> float:
    """Exact correctness probability of the ensemble with success probs ``p``.

    Ties in the argmax-belief prediction are credited fractionally
    (random tie-breaking in expectation). ``p_all`` supplies the pool-wide
    probabilities for the empty-class belief heuristic; defaults to ``p``.
    """
    p = clip_probs(p)
    m = int(p.size)
    K = int(num_classes)
    if m == 0:
        return 1.0 / K
    if not xi_exact_feasible(m, K, cap):
        raise ValueError(
            f"exact xi infeasible for |S|={m}, K={K}; use the MC estimator"
        )
    w = log_weight(p, K)
    empty = empty_log_belief(p if p_all is None else p_all)

    obs = enumerate_observations(m, K)                       # (T, m)
    T = obs.shape[0]
    # Pr[obs | ground truth = 0]  (Eq. 1)
    correct = obs == 0                                       # (T, m)
    logp = np.where(correct, np.log(p)[None, :], np.log1p(-p)[None, :] - np.log(K - 1.0))
    prob = np.exp(logp.sum(axis=1))                          # (T,)

    # Beliefs: one-hot contraction (T, K)
    onehot = np.zeros((T, m, K), np.float64)
    rows = np.repeat(np.arange(T), m)
    cols = np.tile(np.arange(m), T)
    onehot[rows, cols, obs.ravel()] = 1.0
    beliefs = np.einsum("m,tmk->tk", w, onehot)
    counts = onehot.sum(axis=1)
    beliefs = np.where(counts > 0, beliefs, empty)

    mx = beliefs.max(axis=1, keepdims=True)
    is_max = beliefs >= mx - tol
    ties = is_max.sum(axis=1)
    credit = is_max[:, 0] / ties
    return float(np.sum(prob * credit))


def xi_pair(p1: float, p2: float) -> float:
    """Prop. 2: xi({l1, l2}) = max(p1, p2) (used as a test oracle)."""
    return float(max(p1, p2))


def xi_upper_bound_check(p: np.ndarray, num_classes: int) -> bool:
    """Lemma 3 sanity: gamma(S) >= xi(S)."""
    return gamma(p) >= xi_exact(p, num_classes) - 1e-12


def subset_probs(p: np.ndarray, idx: Sequence[int]) -> np.ndarray:
    return np.asarray(p, np.float64)[np.asarray(idx, np.int64)] if len(idx) else np.zeros(0)
