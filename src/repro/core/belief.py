"""Response aggregation by maximum likelihood (paper Section 3.2).

Given responses R(l) of an ensemble S on a K-class query, the belief of
class C_k is (Eq. 4):

    h(C_k | phi) = prod_{l in S(C_k)} p_l (K-1) / (1 - p_l)

and the aggregated prediction is argmax_k h (Fact 1). We work in log space:
``log_weight(p) = log(p) + log(K-1) - log(1-p)`` and beliefs are sums of the
weights of the arms that voted for each class. Classes with no votes receive
the paper's heuristic belief ``p_min / (2 (1 - p_min))``.

Everything here has two forms: a numpy scalar-path for the control plane and
a JAX batched path (one-hot matmul, MXU-friendly) for the serving data plane.
The Pallas kernel in ``repro.kernels.belief_aggregate`` implements the same
contraction with explicit VMEM tiling; ``ref.py`` there delegates to
:func:`aggregate_log_beliefs_batch`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import P_FLOOR, clip_probs

# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------


def log_weight(p: np.ndarray, num_classes: int, floor: float = P_FLOOR) -> np.ndarray:
    """log of p(K-1)/(1-p), the per-arm multiplicative belief weight."""
    p = clip_probs(p, floor)
    return np.log(p) + np.log(num_classes - 1.0) - np.log1p(-p)


def empty_log_belief(p_all: np.ndarray, floor: float = P_FLOOR) -> float:
    """Paper heuristic for classes with no votes: p_min / (2 (1 - p_min))."""
    p_min = float(np.min(clip_probs(p_all, floor)))
    return float(np.log(p_min) - np.log(2.0) - np.log1p(-p_min))


def log_weight_jnp(p: jnp.ndarray, num_classes: int, floor: float = P_FLOOR) -> jnp.ndarray:
    p = jnp.clip(p.astype(jnp.float32), floor, 1.0 - floor)
    return jnp.log(p) + jnp.log(num_classes - 1.0) - jnp.log1p(-p)


# ---------------------------------------------------------------------------
# Aggregation: numpy control-plane path
# ---------------------------------------------------------------------------


def aggregate_log_beliefs(
    responses: np.ndarray,
    weights: np.ndarray,
    num_classes: int,
    empty_belief: float,
) -> np.ndarray:
    """(m,) responses + (m,) log-weights -> (K,) log-beliefs.

    Empty classes (no votes) get ``empty_belief``.
    """
    responses = np.asarray(responses, np.int64)
    beliefs = np.zeros(num_classes, np.float64)
    counts = np.zeros(num_classes, np.int64)
    np.add.at(beliefs, responses, np.asarray(weights, np.float64))
    np.add.at(counts, responses, 1)
    beliefs[counts == 0] = empty_belief
    return beliefs


def tie_break_argmax(
    beliefs: np.ndarray, rng: Optional[np.random.Generator] = None, tol: float = 1e-9
) -> Tuple[np.ndarray, np.ndarray]:
    """argmax over the last axis with uniform tie-breaking within ``tol``.

    The single tie-break rule shared by the per-query path
    (:func:`repro.core.selection.adaptive_invoke`) and the batched serving
    router, so both finalize identically. Accepts (K,) or (B, K) beliefs and
    returns (predictions, n_ties) of matching leading shape.

    With ``rng=None`` the break is deterministic first-max (plain argmax);
    with an rng, a tied class is drawn uniformly. The rng is only consumed
    when at least one row actually has a tie, so tie-free batches stay
    bitwise reproducible across both paths.
    """
    b = np.atleast_2d(np.asarray(beliefs, np.float64))
    mx = b.max(axis=-1, keepdims=True)
    ties = b >= mx - tol
    n_ties = ties.sum(axis=-1)
    if rng is None or not np.any(n_ties > 1):
        pred = np.argmax(b, axis=-1)
    else:
        pred = np.argmax(np.where(ties, rng.random(b.shape), -1.0), axis=-1)
    pred = pred.astype(np.int64)
    if np.asarray(beliefs).ndim == 1:
        return pred[0], n_ties[0]
    return pred, n_ties


def predict_from_beliefs(
    beliefs: np.ndarray, rng: Optional[np.random.Generator] = None, tol: float = 1e-9
) -> Tuple[int, int]:
    """argmax with random tie-break for one (K,) belief vector;
    returns (class, n_ties). Delegates to :func:`tie_break_argmax`."""
    pred, n_ties = tie_break_argmax(np.asarray(beliefs, np.float64), rng, tol)
    return int(pred), int(n_ties)


def aggregate_predict(
    responses: np.ndarray,
    probs: np.ndarray,
    num_classes: int,
    method: str = "ml",
    rng: Optional[np.random.Generator] = None,
    p_all: Optional[np.ndarray] = None,
) -> int:
    """Full aggregation pipeline for one query.

    Args:
      responses: (m,) class ids predicted by the invoked arms.
      probs: (m,) success probabilities of those arms on this query class.
      method: ``"ml"`` (paper, Eq. 4) | ``"weighted"`` (sum of p as vote
        weight) | ``"majority"`` (unweighted) -- the Fig. 14 ablation.
      p_all: pool-wide probs for the empty-class heuristic (defaults to
        ``probs``).
    """
    if len(responses) == 0:
        return int(rng.integers(num_classes)) if rng is not None else 0
    probs = np.asarray(probs, np.float64)
    if method == "ml":
        w = log_weight(probs, num_classes)
        empty = empty_log_belief(probs if p_all is None else p_all)
    elif method == "weighted":
        w = probs
        empty = 0.0
    elif method == "majority":
        w = np.ones_like(probs)
        empty = 0.0
    else:
        raise ValueError(f"unknown aggregation method: {method}")
    beliefs = aggregate_log_beliefs(responses, w, num_classes, empty)
    pred, _ = predict_from_beliefs(beliefs, rng)
    return pred


def top2_beliefs(beliefs: np.ndarray) -> Tuple[float, float, int]:
    """Return (H1, H2, argmax) of a (K,) log-belief vector (Algorithm 3)."""
    order = np.argsort(beliefs)
    h1 = float(beliefs[order[-1]])
    h2 = float(beliefs[order[-2]]) if len(beliefs) > 1 else -np.inf
    return h1, h2, int(order[-1])


# ---------------------------------------------------------------------------
# Aggregation: JAX batched data-plane path
# ---------------------------------------------------------------------------


def aggregate_log_beliefs_batch(
    responses: jnp.ndarray,      # (B, m) int32 class ids; -1 = arm not invoked
    log_weights: jnp.ndarray,    # (m,) or (B, m) float32
    num_classes: int,
    empty_belief: jnp.ndarray | float,  # scalar or (B,)
) -> jnp.ndarray:
    """Batched belief aggregation as a one-hot contraction.

    Returns (B, K) float32 log-beliefs. Arms flagged ``-1`` contribute
    nothing (masked). Votes accumulate as ``onehot(resp) @ diag(w)`` which
    lowers to an MXU matmul on TPU; this function is also the oracle for the
    ``belief_aggregate`` Pallas kernel.
    """
    responses = responses.astype(jnp.int32)
    valid = (responses >= 0)
    safe = jnp.where(valid, responses, 0)
    onehot = jax.nn.one_hot(safe, num_classes, dtype=jnp.float32)      # (B, m, K)
    onehot = onehot * valid[..., None].astype(jnp.float32)
    w = jnp.asarray(log_weights, jnp.float32)
    if w.ndim == 1:
        w = jnp.broadcast_to(w[None, :], responses.shape)
    beliefs = jnp.einsum("bm,bmk->bk", w, onehot)                       # (B, K)
    counts = jnp.einsum("bm,bmk->bk", valid.astype(jnp.float32), onehot)
    empty = jnp.asarray(empty_belief, jnp.float32)
    if empty.ndim == 0:
        empty = jnp.broadcast_to(empty, (responses.shape[0],))
    return jnp.where(counts > 0, beliefs, empty[:, None])


def predict_batch(
    responses: jnp.ndarray,
    log_weights: jnp.ndarray,
    num_classes: int,
    empty_belief: jnp.ndarray | float,
) -> jnp.ndarray:
    """Batched argmax-belief prediction; deterministic first-index tie-break."""
    beliefs = aggregate_log_beliefs_batch(responses, log_weights, num_classes, empty_belief)
    return jnp.argmax(beliefs, axis=-1).astype(jnp.int32)
