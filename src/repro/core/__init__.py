"""ThriftLLM core: correctness probability, surrogate greedy, adaptive selection."""
from .belief import (
    aggregate_log_beliefs,
    aggregate_log_beliefs_batch,
    aggregate_predict,
    empty_log_belief,
    log_weight,
    predict_batch,
    predict_from_beliefs,
    tie_break_argmax,
    top2_beliefs,
)
from .cascade import FrugalCascade, blender_all, random_subset, single_best, topk_weighted
from .clustering import auto_eps, dbscan, kmeans
from .correctness import gamma, gamma_marginal, xi_exact, xi_exact_feasible, xi_pair
from .estimation import (
    ClusterStats,
    SuccessProbEstimator,
    hoeffding_interval,
    median_boost_rounds,
    median_boosted_interval,
    wilson_interval,
)
from .mc import (
    GroupedXiEstimator,
    McXiEstimator,
    sample_pool_responses,
    sample_pool_responses_grouped,
    theta_for,
    xi_from_responses,
    xi_from_responses_grouped,
    xi_marginal_grouped,
)
from .selection import (
    ThriftLLM,
    adaptive_invoke,
    greedy,
    gamma_value_batch,
    sur_greedy,
    sur_greedy_many,
)
from .types import Arm, InvocationResult, QueryClass, SelectionResult, clip_probs

__all__ = [
    "Arm", "QueryClass", "SelectionResult", "InvocationResult", "clip_probs",
    "log_weight", "empty_log_belief", "aggregate_log_beliefs", "aggregate_predict",
    "aggregate_log_beliefs_batch", "predict_batch", "predict_from_beliefs",
    "tie_break_argmax", "top2_beliefs",
    "gamma", "gamma_marginal", "xi_exact", "xi_exact_feasible", "xi_pair",
    "McXiEstimator", "GroupedXiEstimator", "sample_pool_responses",
    "sample_pool_responses_grouped", "theta_for",
    "xi_from_responses", "xi_from_responses_grouped", "xi_marginal_grouped",
    "greedy", "gamma_value_batch", "sur_greedy", "sur_greedy_many",
    "adaptive_invoke", "ThriftLLM",
    "SuccessProbEstimator", "ClusterStats", "hoeffding_interval", "wilson_interval",
    "median_boosted_interval", "median_boost_rounds",
    "kmeans", "dbscan", "auto_eps",
    "FrugalCascade", "blender_all", "topk_weighted", "single_best", "random_subset",
]
