"""Host-side input pipeline: shard-aware batching with background prefetch."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class DataPipeline:
    """Wraps a batch-producing callable with a prefetch thread.

    Args:
      make_batch: ``(step) -> dict of numpy arrays`` (global batch).
      shard_fn: optional ``(batch) -> batch`` slicing to this host's shard
        (multi-host data parallelism); identity by default.
      prefetch: queue depth.
    """

    def __init__(
        self,
        make_batch: Callable[[int], Dict[str, np.ndarray]],
        shard_fn: Optional[Callable] = None,
        prefetch: int = 2,
    ):
        self._make = make_batch
        self._shard = shard_fn or (lambda b: b)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            batch = self._shard(self._make(step))
            step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        # drain so the worker can exit a blocked put
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def host_shard_fn(host_id: int, num_hosts: int) -> Callable:
    """Slice the leading batch dim to this host's contiguous shard."""

    def fn(batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {}
        for k, v in batch.items():
            b = v.shape[0]
            assert b % num_hosts == 0, (k, b, num_hosts)
            per = b // num_hosts
            out[k] = v[host_id * per : (host_id + 1) * per]
        return out

    return fn
