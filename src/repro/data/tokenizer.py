"""Self-contained byte-level tokenizer (no external vocab files).

ids 0..3 are reserved: 0 pad, 1 bos, 2 sep/answer-marker, 3 eos; bytes map
to 4..259. Good enough for the runnable examples; production would swap in
a trained BPE via the same interface.
"""
from __future__ import annotations

from typing import List

import numpy as np

PAD, BOS, SEP, EOS = 0, 1, 2, 3
OFFSET = 4
VOCAB_SIZE = 256 + OFFSET


def encode(text: str, max_len: int = 0) -> np.ndarray:
    ids = [BOS] + [b + OFFSET for b in text.encode("utf-8")] + [EOS]
    if max_len:
        ids = ids[:max_len]
        ids = ids + [PAD] * (max_len - len(ids))
    return np.asarray(ids, np.int32)


def decode(ids) -> str:
    bs = bytes(int(i) - OFFSET for i in ids if int(i) >= OFFSET)
    return bs.decode("utf-8", errors="replace")


def encode_batch(texts: List[str], max_len: int) -> np.ndarray:
    return np.stack([encode(t, max_len) for t in texts])
