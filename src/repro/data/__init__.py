"""Data substrate: synthetic workloads, pipeline, tokenizer."""
from .pipeline import DataPipeline, host_shard_fn
from .synth import OracleWorkload, make_token_task
from .tokenizer import VOCAB_SIZE, decode, encode, encode_batch

__all__ = [
    "OracleWorkload", "make_token_task",
    "DataPipeline", "host_shard_fn",
    "encode", "decode", "encode_batch", "VOCAB_SIZE",
]
