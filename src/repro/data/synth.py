"""Synthetic classification workload generator.

Mirrors the paper's experimental setting (Section 6) without external
datasets: a workload has query *classes* (semantic clusters) and a pool of
arms whose ground-truth success probability varies per class — cheap arms
excel on some clusters, expensive arms dominate on average, exactly the
regime where budget-aware ensemble selection pays off.

Two layers of realism:
  * :class:`OracleWorkload` — arms are Bernoulli oracles with per-class
    success probs (used for the paper-faithful selector benchmarks;
    responses follow Eq. 1's error model).
  * :func:`make_token_task` — token-level sequences whose label is a
    deterministic function of a pattern, for training *real* JAX models as
    arms in the end-to-end example.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class OracleWorkload:
    """Synthetic query-class workload with Bernoulli arms."""

    num_classes: int                # K: label-space size
    num_clusters: int               # query classes
    num_arms: int
    emb_dim: int = 32
    seed: int = 0
    skill_spread: float = 0.25      # how much per-cluster skill varies
    base_low: float = 0.45
    base_high: float = 0.95

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.centers = rng.normal(0, 1, (self.num_clusters, self.emb_dim))
        self.centers /= np.linalg.norm(self.centers, axis=1, keepdims=True)
        # arm quality grows with index (stronger = pricier, Table 4 regime)
        base = np.linspace(self.base_low, self.base_high, self.num_arms)
        skew = rng.normal(0, self.skill_spread, (self.num_clusters, self.num_arms))
        self.p_true = np.clip(base[None, :] + skew, 0.05, 0.995)
        # FLOP-proportional pricing with a spread, mirroring Table 4
        flops = np.geomspace(1.0, 600.0, self.num_arms)
        self.costs = flops * 3.5e-7 * rng.uniform(0.8, 1.25, self.num_arms)

    # ------------------------------------------------------------------
    def sample_queries(
        self, n: int, rng: np.random.Generator
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (cluster_ids (n,), embeddings (n,d), labels (n,))."""
        cid = rng.integers(self.num_clusters, size=n)
        emb = self.centers[cid] + rng.normal(0, 0.08, (n, self.emb_dim))
        labels = rng.integers(self.num_classes, size=n)
        return cid, emb, labels

    def drift_arms(self, arms, p, clusters=None) -> np.ndarray:
        """Shift arms' *true* per-cluster accuracy mid-stream — the
        online-feedback scenario (a provider silently swaps or degrades a
        model; FrugalGPT/MetaLLM's drift setting). Sets
        ``p_true[clusters, arm] = p`` for each arm in ``arms`` (all
        clusters when ``clusters`` is None) and returns the previous
        values, so a benchmark can restore them."""
        arms = np.atleast_1d(np.asarray(arms, np.int64))
        rows = (
            np.arange(self.num_clusters)
            if clusters is None
            else np.atleast_1d(np.asarray(clusters, np.int64))
        )
        old = self.p_true[np.ix_(rows, arms)].copy()
        self.p_true[np.ix_(rows, arms)] = np.clip(p, 0.0, 1.0)
        return old

    def invoke(
        self, arm: int, cluster: int, label: int, rng: np.random.Generator
    ) -> int:
        """Arm response under the paper's error model (Eq. 1)."""
        if rng.random() < self.p_true[cluster, arm]:
            return int(label)
        wrong = rng.integers(self.num_classes - 1)
        return int((label + 1 + wrong) % self.num_classes)

    def invoke_batch(
        self,
        arm: int,
        clusters: np.ndarray,
        labels: np.ndarray,
        rng: np.random.Generator,
    ) -> np.ndarray:
        """Vectorized :meth:`invoke` over (n,) clusters/labels — same error
        model, one rng draw per query instead of a Python loop (the serving
        throughput path; draw order differs from the scalar loop)."""
        return self.invoke_assigned(
            np.full(np.asarray(clusters).shape, arm, np.int64), clusters, labels, rng
        )

    def invoke_assigned(
        self,
        arms: np.ndarray,
        clusters: np.ndarray,
        labels: np.ndarray,
        rng: np.random.Generator,
    ) -> np.ndarray:
        """Heterogeneous-arm vectorized invocation: query i is served by
        ``arms[i]``. One rng draw per query regardless of how many distinct
        arms appear — the serving wavefront's one-call-per-wave fast path."""
        arms = np.asarray(arms, np.int64)
        clusters = np.asarray(clusters, np.int64)
        labels = np.asarray(labels, np.int64)
        p = self.p_true[clusters, arms]
        u = rng.random((2, clusters.size))       # one draw for hit + wrong-class
        hit = u[0] < p
        wrong = np.minimum(
            (u[1] * (self.num_classes - 1)).astype(np.int64), self.num_classes - 2
        )
        return np.where(hit, labels, (labels + 1 + wrong) % self.num_classes)

    def response_table(
        self, n: int, seed: int = 1
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Historical matrix T (n, L) of correctness booleans + embeddings +
        cluster ids (Section 3.1 input)."""
        rng = np.random.default_rng(seed)
        cid, emb, labels = self.sample_queries(n, rng)
        T = np.zeros((n, self.num_arms), np.float64)
        for i in range(n):
            for a in range(self.num_arms):
                T[i, a] = self.invoke(a, cid[i], labels[i], rng) == labels[i]
        return T, emb, cid


# ---------------------------------------------------------------------------
# Token-level task for real-model arms
# ---------------------------------------------------------------------------


def make_token_task(
    num_classes: int,
    seq_len: int,
    vocab: int,
    n: int,
    seed: int = 0,
    noise: float = 0.0,
) -> Dict[str, np.ndarray]:
    """Sequences whose final token must be the class id.

    The class is determined by which `signature` token appears most often in
    the sequence body — learnable by a tiny LM, with capacity controlling
    attainable accuracy (bigger arms really are better).
    """
    rng = np.random.default_rng(seed)
    assert vocab > num_classes + 8
    sig_tokens = np.arange(num_classes) + 4          # reserved signature ids
    body_len = seq_len - 2
    tokens = rng.integers(num_classes + 4, vocab, size=(n, seq_len))
    labels = rng.integers(num_classes, size=n)
    for i in range(n):
        # plant signature occurrences of the true class (+ distractors)
        k_true = rng.integers(4, max(5, body_len // 4))
        pos = rng.choice(body_len, size=k_true, replace=False)
        tokens[i, pos] = sig_tokens[labels[i]]
        distract = rng.integers(num_classes)
        if distract != labels[i]:
            k_d = int(rng.integers(1, max(2, k_true - 1)))   # strictly fewer
            free = np.setdiff1d(np.arange(body_len), pos)    # never overwrite
            if free.size:
                pos_d = rng.choice(free, size=min(k_d, free.size), replace=False)
                tokens[i, pos_d] = sig_tokens[distract]
    tokens[:, -2] = 2                                 # "answer:" marker
    tokens[:, -1] = sig_tokens[labels]                # answer token
    if noise > 0:
        flip = rng.random(n) < noise
        tokens[flip, -1] = sig_tokens[rng.integers(num_classes, size=flip.sum())]
    return {
        "tokens": tokens.astype(np.int32),
        "labels": labels.astype(np.int32),
        "class_token_ids": sig_tokens.astype(np.int32),
    }
