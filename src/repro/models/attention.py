"""GQA attention: direct path (short sequences / decode) and a blocked
flash-style path (online softmax over KV blocks) for long prefill/train.

The blocked path is the pure-jnp oracle of the ``flash_attention`` Pallas
kernel (same tiling, same online-softmax recurrence); on the CPU dry-run the
model lowers this path, on real TPUs the kernel substitutes per-op.

Shapes: q (B, S, H, hd); k, v (B, T, G, hd) with H = G * group_size.
Masking supports causality, sliding windows, and a KV length limit
(ring-buffer decode).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(
    q_pos: jnp.ndarray,       # (S,) absolute positions of queries
    k_pos: jnp.ndarray,       # (T,) absolute positions of keys
    causal: bool,
    window: int,
) -> jnp.ndarray:
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def direct_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jnp.ndarray = 0,
    k_positions: Optional[jnp.ndarray] = None,
    kv_valid: Optional[jnp.ndarray] = None,   # (B, T) bool for ring buffers
) -> jnp.ndarray:
    """Materialized-scores attention; use when S * T is small."""
    B, S, H, hd = q.shape
    T, G = k.shape[1], k.shape[2]
    gs = H // G
    qg = q.reshape(B, S, G, gs, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    q_pos = q_offset + jnp.arange(S)
    k_pos = k_positions if k_positions is not None else jnp.arange(T)
    m = _mask(q_pos, k_pos, causal, window)
    if kv_valid is not None:
        m = m[None] & kv_valid[:, None, :]
        scores = jnp.where(m[:, None, None], scores, NEG_INF)
    else:
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_kv", "q_offset_static")
)
def blocked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_kv: int = 512,
    q_offset_static: int = 0,
) -> jnp.ndarray:
    """Flash-style attention: lax.scan over KV blocks with online softmax.

    All queries are processed in parallel against one KV block per scan step,
    carrying the running (max, normalizer, weighted-accumulator). Peak live
    score tensor is (B, S, H, block_kv) instead of (B, S, H, T).

    Baseline accounting note: the scan visits every KV block and relies on
    masking for causality/window, so compiled FLOPs are ~2x the useful
    causal FLOPs — visible in the roofline MODEL_FLOPS/HLO_FLOPs ratio and
    addressed in the perf iterations (kernel-level block skipping).
    """
    B, S, H, hd = q.shape
    T, G = k.shape[1], k.shape[2]
    gs = H // G
    bk = min(block_kv, T)
    n_blocks = (T + bk - 1) // bk
    pad = n_blocks * bk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = (q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))).reshape(B, S, G, gs, hd)
    q_pos = q_offset_static + jnp.arange(S)
    kb = k.reshape(B, n_blocks, bk, G, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, bk, G, hd).transpose(1, 0, 2, 3, 4)

    def step(carry, inputs):
        m_run, l_run, acc = carry
        kblk, vblk, blk_idx = inputs
        k_pos = blk_idx * bk + jnp.arange(bk)
        s = jnp.einsum("bsgrd,btgd->bsgrt", qg, kblk.astype(jnp.float32))
        mask = jnp.ones((S, bk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        mask &= (k_pos < T)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        # Guard fully-masked prefixes: exp(-inf - -inf) would be NaN.
        safe = m_new > NEG_INF / 2
        alpha = jnp.where(safe, jnp.exp(m_run - jnp.where(safe, m_new, 0.0)), 0.0)
        p = jnp.where(
            mask[None, :, None, None, :],
            jnp.exp(s - jnp.where(safe, m_new, 0.0)[..., None]),
            0.0,
        )
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bsgrt,btgd->bsgrd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((B, S, G, gs), NEG_INF, jnp.float32),
        jnp.zeros((B, S, G, gs), jnp.float32),
        jnp.zeros((B, S, G, gs, hd), jnp.float32),
    )
    (m_run, l_run, acc), _ = jax.lax.scan(
        step, init, (kb, vb, jnp.arange(n_blocks))
    )
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return out.reshape(B, S, H, hd).astype(q.dtype)


def bucketed_causal_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    window: int = 0,
    block_kv: int = 512,
    buckets: int = 8,
) -> jnp.ndarray:
    """Causal self-attention with prefix-length bucketing (perf iteration #1).

    The masked-full baseline visits all T keys for every query block — ~2x
    the useful causal FLOPs. Splitting queries into G contiguous buckets
    where bucket g only scans the first (g+1)/G of the keys keeps all shapes
    static while computing only a (G+1)/(2G) fraction of the full score
    matrix (0.5625 at G=8, vs the causal optimum 0.5 — the residual is the
    intra-bucket triangle, which the Pallas kernel also skips on real TPU).
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    assert S == T, "bucketing assumes self-attention"
    G = buckets
    while S % G != 0 and G > 1:
        G //= 2
    step = S // G
    outs = []
    for g in range(G):
        q_g = q[:, g * step : (g + 1) * step]
        kv_len = (g + 1) * step
        outs.append(
            blocked_attention(
                q_g, k[:, :kv_len], v[:, :kv_len],
                causal=True, window=window,
                block_kv=min(block_kv, kv_len), q_offset_static=g * step,
            )
        )
    return jnp.concatenate(outs, axis=1)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jnp.ndarray = 0,
    k_positions: Optional[jnp.ndarray] = None,
    kv_valid: Optional[jnp.ndarray] = None,
    blocked_threshold: int = 2048,
    block_kv: int = 512,
    causal_buckets: int = 0,
) -> jnp.ndarray:
    """Dispatch: blocked path for long self-attention, direct otherwise.

    ``causal_buckets > 0`` enables the prefix-bucketed causal scan (see
    :func:`bucketed_causal_attention`)."""
    S, T = q.shape[1], k.shape[1]
    if (
        S == T
        and T > blocked_threshold
        and k_positions is None
        and kv_valid is None
        and isinstance(q_offset, int)
        and q_offset == 0
    ):
        if causal and causal_buckets > 0:
            return bucketed_causal_attention(
                q, k, v, window=window, block_kv=block_kv, buckets=causal_buckets
            )
        return blocked_attention(
            q, k, v, causal=causal, window=window, block_kv=block_kv,
            q_offset_static=q_offset,
        )
    return direct_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        k_positions=k_positions, kv_valid=kv_valid,
    )
