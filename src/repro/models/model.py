"""The LM: embedding -> scanned block segments -> logits/loss, with
prefill/decode paths for serving. Mesh-agnostic; sharding is injected via
``repro.distributed.sharding.constrain`` logical-axis annotations.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

from . import blocks as B
from .config import ModelConfig
from .init import init_params, padded_vocab
from .mlp import rmsnorm

Params = Dict[str, Any]
Cache = Dict[str, Any]

IGNORE = -1


def block_window(cfg: ModelConfig) -> int:
    """Window of the attention blocks: hybrid archs use the local window."""
    if "rec" in cfg.block_pattern:
        return cfg.local_window
    return cfg.window


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, key) -> Params:
        return init_params(key, self.cfg)

    def param_shapes(self, key=None) -> Params:
        key = jax.random.key(0) if key is None else key
        return jax.eval_shape(lambda k: init_params(k, self.cfg), key)

    # ------------------------------------------------------------- backbone
    def _apply_unit(self, unit, pt, ct, h, mode, pos, ring_pos):
        cfg = self.cfg
        win = block_window(cfg)
        new_c: Dict[str, Any] = {}
        aux = jnp.float32(0.0)
        for j, btype in enumerate(unit):
            bp = pt[f"u{j}"]
            cj = ct[f"u{j}"] if ct is not None else None
            if btype in ("attn", "moe"):
                if mode == "decode":
                    fn = B.attn_block_decode if btype == "attn" else B.moe_block_decode
                    h, nc = fn(bp, h, cj, cfg, pos, window=win, ring_pos=ring_pos)
                else:
                    fn = B.attn_block if btype == "attn" else B.moe_block
                    h, nc, a = fn(bp, h, cfg, window=win, make_cache=(mode == "prefill"))
                    aux = aux + a
            elif btype == "ssm":
                if mode == "decode":
                    h, nc = B.ssm_block_decode(bp, h, cj, cfg, pos)
                else:
                    h, nc, _ = B.ssm_block(bp, h, cfg, make_cache=(mode == "prefill"))
            elif btype == "rec":
                if mode == "decode":
                    h, nc = B.rec_block_decode(bp, h, cj, cfg, pos)
                else:
                    h, nc, _ = B.rec_block(bp, h, cfg, make_cache=(mode == "prefill"))
            else:
                raise ValueError(btype)
            h = constrain(h, "batch", "seq", "embed")
            if nc is not None:
                new_c[f"u{j}"] = nc
        return h, new_c, aux

    def backbone(
        self,
        params: Params,
        h: jnp.ndarray,
        mode: str = "full",
        caches: Optional[list] = None,
        pos: jnp.ndarray | int = 0,
        ring_pos: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, list, jnp.ndarray]:
        """Run all segments. Returns (h, new_caches_per_segment, aux_loss)."""
        cfg = self.cfg
        new_caches = []
        aux_total = jnp.float32(0.0)
        for si, (unit, repeats) in enumerate(cfg.segments()):
            seg_p = params[f"seg{si}"]
            seg_c = caches[si] if caches is not None else None

            def body(h, xs, unit=unit):
                pt, ct = xs
                h, nc, aux = self._apply_unit(unit, pt, ct, h, mode, pos, ring_pos)
                return h, (nc, aux)

            if cfg.remat and mode != "decode":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                )
            xs = (seg_p, seg_c if seg_c is not None else _none_like(seg_p))
            h, (nc, auxs) = jax.lax.scan(body, h, xs)
            new_caches.append(nc if (mode != "full") else None)
            aux_total = aux_total + jnp.sum(auxs)
        return h, new_caches, aux_total

    # --------------------------------------------------------------- embed
    def embed(
        self, params: Params, tokens: jnp.ndarray,
        frontend_embeds: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        h = jnp.take(params["embed"]["tok"], tokens, axis=0)
        if frontend_embeds is not None:
            h = jnp.concatenate([frontend_embeds.astype(h.dtype), h], axis=1)
        return constrain(h, "batch", "seq", "embed")

    def logits(self, params: Params, h: jnp.ndarray) -> jnp.ndarray:
        h = rmsnorm(h, params["final_norm"], self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            logits = jnp.einsum("...d,vd->...v", h, params["embed"]["tok"])
        else:
            logits = jnp.einsum("...d,dv->...v", h, params["head"]["w"])
        if self.cfg.logits_softcap > 0:
            c = self.cfg.logits_softcap
            logits = jnp.tanh(logits / c) * c
        return constrain(logits, "batch", "seq", "vocab")

    # ----------------------------------------------------------------- loss
    def loss(self, params: Params, batch: Dict[str, jnp.ndarray]):
        """Next-token LM loss. ``batch`` has tokens (B, S_tok) and, for
        frontend archs, frontend_embeds (B, Lf, D)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        fe = batch.get("frontend_embeds")
        h = self.embed(params, tokens, fe)
        Bsz, S = h.shape[0], h.shape[1]
        Lf = 0 if fe is None else fe.shape[1]

        targets = jnp.full((Bsz, S), IGNORE, jnp.int32)
        if Lf > 0:
            targets = jax.lax.dynamic_update_slice(targets, tokens.astype(jnp.int32), (0, Lf - 1))
        else:
            targets = targets.at[:, : S - 1].set(tokens[:, 1:].astype(jnp.int32))

        h, _, aux = self.backbone(params, h, "full")

        if cfg.loss_chunk and cfg.loss_chunk < S:
            nloss, ncount = self._chunked_xent(params, h, targets)
        else:
            logits = self.logits(params, h)
            nloss, ncount = _xent_sum(logits, targets, cfg.vocab_size)
        loss = nloss / jnp.maximum(ncount, 1.0)
        if cfg.num_experts:
            loss = loss + 0.01 * aux / max(len(cfg.layer_types), 1)
        return loss, {"nll": nloss / jnp.maximum(ncount, 1.0), "aux": aux}

    def _chunked_xent(self, params, h, targets):
        cfg = self.cfg
        Bsz, S, D = h.shape
        c = cfg.loss_chunk
        n = S // c
        hs = h[:, : n * c].reshape(Bsz, n, c, D).transpose(1, 0, 2, 3)
        ts = targets[:, : n * c].reshape(Bsz, n, c).transpose(1, 0, 2)

        def step(carry, xs):
            nl, nc = carry
            hc, tc = xs
            logits = self.logits(params, hc)
            l, k = _xent_sum(logits, tc, cfg.vocab_size)
            return (nl + l, nc + k), None

        (nloss, ncount), _ = jax.lax.scan(
            step, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ts)
        )
        if n * c < S:  # remainder
            logits = self.logits(params, h[:, n * c :])
            l, k = _xent_sum(logits, targets[:, n * c :], cfg.vocab_size)
            nloss, ncount = nloss + l, ncount + k
        return nloss, ncount

    # ------------------------------------------------------------- forward
    def forward(
        self, params: Params, tokens: jnp.ndarray,
        frontend_embeds: Optional[jnp.ndarray] = None,
    ) -> jnp.ndarray:
        h = self.embed(params, tokens, frontend_embeds)
        h, _, _ = self.backbone(params, h, "full")
        return self.logits(params, h)

    # ------------------------------------------------------------- serving
    def attn_cache_len(self, seq_len: int) -> int:
        types = set(self.cfg.layer_types)
        if not (types & {"attn", "moe"}):
            return 0
        w = block_window(self.cfg)
        return min(w, seq_len) if w > 0 else seq_len

    def prefill(
        self, params: Params, tokens: jnp.ndarray,
        frontend_embeds: Optional[jnp.ndarray] = None,
        extra_slots: int = 1,
    ) -> Tuple[jnp.ndarray, Cache]:
        """Returns (next-token logits (B, V), cache ready for decode).

        Full-attention caches are padded with ``extra_slots`` empty positions
        for subsequent decode steps; windowed caches are ring buffers and
        need no padding.
        """
        h = self.embed(params, tokens, frontend_embeds)
        S = h.shape[1]
        h, seg_caches, _ = self.backbone(params, h, "prefill")
        logits = self.logits(params, h[:, -1:])[:, 0]
        windowed = block_window(self.cfg) > 0
        T = self.attn_cache_len(S)
        ring = None
        if T:
            if windowed:
                s = np.arange(T)
                ring = jnp.asarray((S - 1) - ((S - 1 - s) % T), jnp.int32)
                seg_caches = [
                    {
                        uk: (
                            {k: _ring_permute(v, S=S, T=T) for k, v in uc.items()}
                            if "k" in uc else uc
                        )
                        for uk, uc in seg.items()
                    }
                    for seg in seg_caches
                ]
            else:
                ring = jnp.concatenate(
                    [jnp.arange(S, dtype=jnp.int32),
                     jnp.full((extra_slots,), -1, jnp.int32)]
                )
                seg_caches = [
                    {
                        uk: (
                            {k: _pad_slots(v, extra_slots) for k, v in uc.items()}
                            if "k" in uc else uc
                        )
                        for uk, uc in seg.items()
                    }
                    for seg in seg_caches
                ]
            if self.cfg.kv_quant == "int8":
                from .blocks import quantize_kv

                def _quant(uc):
                    kq, ks = quantize_kv(uc["k"])
                    vq, vs = quantize_kv(uc["v"])
                    return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}

                seg_caches = [
                    {uk: (_quant(uc) if "k" in uc else uc) for uk, uc in seg.items()}
                    for seg in seg_caches
                ]
        cache = {"pos": jnp.int32(S), "ring": ring, "segs": seg_caches}
        return logits, cache

    def decode_step(
        self, params: Params, cache: Cache, tokens: jnp.ndarray
    ) -> Tuple[jnp.ndarray, Cache]:
        """One token step: tokens (B, 1) -> (logits (B, V), updated cache)."""
        pos = cache["pos"]
        ring = cache["ring"]
        h = self.embed(params, tokens)
        h, new_segs, _ = self.backbone(params, h, "decode", cache["segs"], pos, ring)
        logits = self.logits(params, h)[:, 0]
        new_ring = ring
        if ring is not None:
            T = ring.shape[0]
            w = block_window(self.cfg)
            slot = pos % T if w > 0 else jnp.minimum(pos, T - 1)
            new_ring = jnp.where(jnp.arange(T) == slot, pos, ring)
        return logits, {"pos": pos + 1, "ring": new_ring, "segs": new_segs}

    def init_cache(self, batch: int, cache_len: int, prefilled: int = 0) -> Cache:
        """Concrete zeroed cache (ring positions consistent with ``prefilled``)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        T = self.attn_cache_len(cache_len)
        G, hd, K = cfg.num_kv_heads, cfg.head_dim, cfg.ssm_conv
        segs = []
        for unit, repeats in cfg.segments():
            seg: Dict[str, Any] = {}
            for j, btype in enumerate(unit):
                if btype in ("attn", "moe"):
                    if cfg.kv_quant == "int8":
                        seg[f"u{j}"] = {
                            "k": jnp.zeros((repeats, batch, T, G, hd), jnp.int8),
                            "v": jnp.zeros((repeats, batch, T, G, hd), jnp.int8),
                            "k_scale": jnp.zeros((repeats, batch, T, G, 1), jnp.float32),
                            "v_scale": jnp.zeros((repeats, batch, T, G, 1), jnp.float32),
                        }
                    else:
                        seg[f"u{j}"] = {
                            "k": jnp.zeros((repeats, batch, T, G, hd), dt),
                            "v": jnp.zeros((repeats, batch, T, G, hd), dt),
                        }
                elif btype == "ssm":
                    seg[f"u{j}"] = {
                        "conv": jnp.zeros((repeats, batch, K - 1, cfg.d_inner), dt),
                        "h": jnp.zeros((repeats, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
                    }
                elif btype == "rec":
                    seg[f"u{j}"] = {
                        "conv": jnp.zeros((repeats, batch, K - 1, cfg.rnn_width), dt),
                        "h": jnp.zeros((repeats, batch, cfg.rnn_width), jnp.float32),
                    }
            segs.append(seg)
        ring = None
        if T:
            s = np.arange(T)
            rp = (prefilled - 1) - ((prefilled - 1 - s) % T)
            rp = np.where((rp >= 0) & (rp < prefilled), rp, -1)
            ring = jnp.asarray(rp, jnp.int32)
        return {"pos": jnp.int32(prefilled), "ring": ring, "segs": segs}


def _ring_permute(leaf, S: int, T: int):
    """Reorder a (n, B, T, ...) prefill cache from sequence order to ring
    (position % T) order."""
    if leaf.ndim >= 3 and leaf.shape[2] == T:
        s = np.arange(T)
        src = (S - 1) - ((S - 1 - s) % T) - (S - T)
        return leaf[:, :, src]
    return leaf


def _pad_slots(leaf, extra: int):
    """Append ``extra`` zero slots along the cache-time axis (dim 2)."""
    pad = [(0, 0)] * leaf.ndim
    pad[2] = (0, extra)
    return jnp.pad(leaf, pad)


def _none_like(tree):
    return jax.tree.map(lambda _: None, tree, is_leaf=lambda x: x is None)


def _xent_sum(logits: jnp.ndarray, targets: jnp.ndarray, vocab: int):
    """Sum of masked next-token cross-entropies + valid count (fp32)."""
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    if V > vocab:  # mask padded vocab slots
        pad_mask = jnp.arange(V) >= vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    safe_t = jnp.maximum(targets, 0)
    picked = jnp.take_along_axis(logits, safe_t[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = (targets != IGNORE).astype(jnp.float32)
    return jnp.sum(nll * mask), jnp.sum(mask)
