"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Diagonal gated linear recurrence:
    r_t = sigmoid(x_t W_r)                  (recurrence gate)
    i_t = sigmoid(x_t W_i)                  (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)  (per-channel decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is elementwise-diagonal, so training/prefill uses
``jax.lax.associative_scan`` over time (parallel prefix, log-depth) — the
TPU-native equivalent of the paper's fused GPU scan kernel. Decode is a
single fused step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

RGLRU_C = 8.0


def rglru_gates(
    x: jnp.ndarray, wr: jnp.ndarray, wi: jnp.ndarray, br: jnp.ndarray,
    bi: jnp.ndarray, lam: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (log_a, gated_input), both (..., Dr) float32."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...d,df->...f", x32, wr.astype(jnp.float32)) + br)
    i = jax.nn.sigmoid(jnp.einsum("...d,df->...f", x32, wi.astype(jnp.float32)) + bi)
    log_a = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i * x32
    return log_a, gated


def rglru_scan(
    log_a: jnp.ndarray,     # (B, S, Dr)
    gated: jnp.ndarray,     # (B, S, Dr)
    h0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Associative scan of h_t = a_t h_{t-1} + u_t. Returns (h (B,S,Dr), h_last)."""
    if h0 is not None:
        # fold the carried state into the first input
        gated = gated.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 + a2, u1 * jnp.exp(a2) + u2

    a_cum, h = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
    del a_cum
    return h, h[:, -1]


def rglru_decode_step(
    x: jnp.ndarray, wr: jnp.ndarray, wi: jnp.ndarray, br: jnp.ndarray,
    bi: jnp.ndarray, lam: jnp.ndarray, h: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrence step: x (B, Dr), h (B, Dr) -> (y, h_new)."""
    log_a, gated = rglru_gates(x, wr, wi, br, bi, lam)
    h_new = jnp.exp(log_a) * h + gated
    return h_new.astype(x.dtype), h_new
