"""Mamba-1 selective state-space block (falcon-mamba family).

TPU adaptation: the original CUDA kernel is a fused sequential scan in SRAM.
We use the *chunked* formulation — split the sequence into chunks of
``ssm_chunk``; within a chunk the recurrence is unrolled into dense cumsum /
einsum form (MXU work, (B, c, Din, N) working set bounded by the chunk), and
a lax.scan carries the (B, Din, N) state across chunks. This is the standard
hardware-efficient reformulation (cf. Mamba-2 SSD) of the same math.

Recurrence (per channel d, state n):
    h_t = exp(dt_t * A[d,n]) * h_{t-1} + dt_t * B_t[n] * x_t[d]
    y_t = sum_n C_t[n] * h_t[d,n] + D[d] * x_t[d]
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def causal_conv1d(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, state: jnp.ndarray | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv along time. x (B, S, D), w (D, K), b (D,).

    Returns (y (B, S, D), new_state (B, K-1, D)). ``state`` carries the last
    K-1 inputs for streaming decode.
    """
    B, S, D = x.shape
    K = w.shape[1]
    if state is None:
        state = jnp.zeros((B, K - 1, D), x.dtype)
    xt = jnp.concatenate([state, x], axis=1)                 # (B, S+K-1, D)
    # K is tiny (4): unrolled shifted multiply-adds
    y = sum(
        xt[:, i : i + S, :].astype(jnp.float32) * w[:, i][None, None, :]
        for i in range(K)
    )
    y = y + b[None, None, :]
    new_state = xt[:, S:, :] if K > 1 else state
    return y.astype(x.dtype), new_state


def _chunk_scan(
    log_a: jnp.ndarray,   # (B, c, Din, N) log decay per step
    bx: jnp.ndarray,      # (B, c, Din, N) input contribution per step
    Cc: jnp.ndarray,      # (B, c, N) output projections per step
    h0: jnp.ndarray,      # (B, Din, N) incoming state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact intra-chunk recurrence h_t = exp(log_a_t) h_{t-1} + bx_t with
    on-the-fly output contraction y_t = C_t . h_t.

    A cumsum factorization (h_t = e^{cum_t}(h0 + sum e^{-cum_j} bx_j)) looks
    parallel but overflows for strong decay (e^{-cum_j} unbounded), so we run
    the recurrence sequentially inside the chunk and contract against C_t per
    step — state stays (B, Din, N) and only (B, c, Din) outputs materialize.
    The TPU production path is the fused Pallas scan kernel; this is its
    stable jnp reference.
    """

    def step(h, xs):
        la, b, c_t = xs                               # (B,Din,N),(B,Din,N),(B,N)
        h = jnp.exp(la) * h + b
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        log_a.transpose(1, 0, 2, 3),
        bx.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2),
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h_last              # (B, c, Din), (B, Din, N)


def selective_scan(
    x: jnp.ndarray,        # (B, S, Din) post-conv activations
    dt: jnp.ndarray,       # (B, S, Din) softplus'd step sizes
    A: jnp.ndarray,        # (Din, N) negative real
    Bmat: jnp.ndarray,     # (B, S, N)
    Cmat: jnp.ndarray,     # (B, S, N)
    Dskip: jnp.ndarray,    # (Din,)
    h0: jnp.ndarray | None = None,
    chunk: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked selective scan. Returns (y (B, S, Din), h_last (B, Din, N))."""
    B, S, Din = x.shape
    N = A.shape[1]
    c = min(chunk, S)
    n_chunks = (S + c - 1) // c
    pad = n_chunks * c - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    if h0 is None:
        h0 = jnp.zeros((B, Din, N), jnp.float32)

    xs = x.reshape(B, n_chunks, c, Din).transpose(1, 0, 2, 3).astype(jnp.float32)
    dts = dt.reshape(B, n_chunks, c, Din).transpose(1, 0, 2, 3).astype(jnp.float32)
    Bs = Bmat.reshape(B, n_chunks, c, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    Cs = Cmat.reshape(B, n_chunks, c, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    A32 = A.astype(jnp.float32)

    def step(h, inputs):
        xc, dtc, Bc, Cc = inputs                                # (B,c,...)
        log_a = dtc[..., None] * A32[None, None]                # (B,c,Din,N)
        bx = (dtc * xc)[..., None] * Bc[:, :, None, :]          # (B,c,Din,N)
        yc, h_last = _chunk_scan(log_a, bx, Cc, h)              # (B,c,Din)
        return h_last, yc

    step = jax.checkpoint(step)  # recompute intra-chunk states in backward
    h_last, ys = jax.lax.scan(step, h0, (xs, dts, Bs, Cs))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * c, Din)[:, :S]
    y = y + x[:, :S].astype(jnp.float32) * Dskip[None, None, :].astype(jnp.float32)
    return y.astype(x.dtype), h_last


def ssm_decode_step(
    x: jnp.ndarray,        # (B, Din) single-step post-conv activation
    dt: jnp.ndarray,       # (B, Din)
    A: jnp.ndarray,        # (Din, N)
    Bvec: jnp.ndarray,     # (B, N)
    Cvec: jnp.ndarray,     # (B, N)
    Dskip: jnp.ndarray,    # (Din,)
    h: jnp.ndarray,        # (B, Din, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single recurrence step for serving. Returns (y (B, Din), h_new)."""
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    a = jnp.exp(dt32[..., None] * A.astype(jnp.float32)[None])     # (B,Din,N)
    h_new = a * h + (dt32 * x32)[..., None] * Bvec[:, None, :].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h_new, Cvec.astype(jnp.float32))
    y = y + x32 * Dskip[None].astype(jnp.float32)
    return y.astype(x.dtype), h_new
