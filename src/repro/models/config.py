"""Model configuration for every architecture family in the pool.

A model is a sequence of *blocks*; the per-layer block type is derived from
``block_pattern`` cycled over ``num_layers``. For compile-time economy the
forward pass scans over repeats of the pattern unit (``segments()``), so an
80-layer dense model lowers a single block body once.

Block types:
  ``attn``   dense attention block (GQA + RoPE [+ sliding window]) + SwiGLU
  ``moe``    attention block whose MLP is a top-k mixture of experts
  ``ssm``    Mamba-1 selective-state-space block (attention-free)
  ``rec``    RG-LRU recurrent block (RecurrentGemma / Griffin)
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                  # query heads; 0 for attention-free archs
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # default d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    expert_capacity_factor: float = 1.25
    moe_ep: bool = False            # shard_map expert parallelism (perf #2)
    # --- SSM (Mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0            # default ceil(d_model / 16)
    ssm_chunk: int = 256            # chunked-scan length
    # --- RG-LRU (hybrid) ---
    rnn_width: int = 0              # default d_model
    # --- attention details ---
    window: int = 0                 # sliding-window size; 0 = full attention
    local_window: int = 2048        # window of 'attn' blocks in hybrid pattern
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    mlp_variant: str = "swiglu"     # swiglu (3 mats) | gelu (2 mats)
    attn_buckets: int = 0           # >0: prefix-bucketed causal scan (perf #1)
    kv_quant: str = "none"          # none | int8 (decode KV cache, perf #3)
    block_pattern: Tuple[str, ...] = ("attn",)
    # --- embeddings / head ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # --- frontend stub (vlm / audio) ---
    frontend: str = "none"          # none | vision | audio
    frontend_len: int = 0           # prepended embedding positions
    # --- numerics / training ---
    dtype: str = "bfloat16"         # activation/param dtype for the big runs
    remat: bool = True
    num_microbatches: int = 1
    loss_chunk: int = 0             # 0 = unchunked softmax-xent
    logits_softcap: float = 0.0

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_dt_rank == 0 and self.ssm_state > 0:
            object.__setattr__(self, "ssm_dt_rank", math.ceil(self.d_model / 16))
        if self.rnn_width == 0 and "rec" in self.block_pattern:
            object.__setattr__(self, "rnn_width", self.d_model)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def layer_types(self) -> List[str]:
        pat = self.block_pattern
        return [pat[i % len(pat)] for i in range(self.num_layers)]

    def segments(self) -> List[Tuple[Tuple[str, ...], int]]:
        """Split layers into (pattern_unit, n_repeats) scan segments.

        ``num_layers = 38`` with pattern (rec, rec, attn) becomes
        ``[(('rec','rec','attn'), 12), (('rec','rec'), 1)]``.
        """
        unit = self.block_pattern
        u = len(unit)
        full, rem = divmod(self.num_layers, u)
        segs: List[Tuple[Tuple[str, ...], int]] = []
        if full:
            segs.append((tuple(unit), full))
        if rem:
            segs.append((tuple(unit[:rem]), 1))
        return segs

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count (embedding included once if tied)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        H, G, hd = self.num_heads, self.num_kv_heads, self.head_dim
        total = V * D                                   # embed
        if not self.tie_embeddings:
            total += V * D
        total += D                                      # final norm
        for t in self.layer_types:
            if t in ("attn", "moe"):
                total += D                              # ln1
                total += D * (H * hd) + 2 * D * (G * hd) + (H * hd) * D
                if self.qkv_bias:
                    total += H * hd + 2 * G * hd
                total += D                              # ln2
                n_mats = 3 if self.mlp_variant == "swiglu" else 2
                if t == "attn":
                    total += n_mats * D * F
                else:
                    total += D * self.num_experts       # router
                    total += self.num_experts * n_mats * D * F
            elif t == "ssm":
                Din, N, R = self.d_inner, self.ssm_state, self.ssm_dt_rank
                total += D                              # ln
                total += D * 2 * Din                    # in_proj
                total += Din * self.ssm_conv + Din      # conv
                total += Din * (R + 2 * N)              # x_proj
                total += R * Din + Din                  # dt_proj
                total += Din * N + Din                  # A_log, D skip
                total += Din * D                        # out_proj
            elif t == "rec":
                Dr = self.rnn_width
                total += D                              # ln
                total += 2 * D * Dr                     # wx, wy
                total += Dr * self.ssm_conv + Dr        # temporal conv
                total += 2 * Dr * Dr + 2 * Dr           # input & recurrence gates
                total += Dr                             # lambda
                total += Dr * D                         # out proj
                total += D                              # ln2
                total += (3 if self.mlp_variant == "swiglu" else 2) * D * F
            else:
                raise ValueError(t)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        n_mats = 3 if self.mlp_variant == "swiglu" else 2
        dense_equiv = self.param_count()
        dead = (self.num_experts - self.experts_per_token) * n_mats * D * F
        return dense_equiv - dead * sum(1 for t in self.layer_types if t == "moe")

    def flops_per_token(self, seq_len: int = 1) -> float:
        """~6 * N_active * 1 fwd+bwd per token (fwd only: /3). Attention
        quadratic term added for honesty at long seq."""
        n = self.active_param_count()
        fl = 2.0 * n  # forward multiply-adds
        # attention score+value flops per token at context length seq_len
        H, hd = self.num_heads, self.head_dim
        attn_layers = sum(1 for t in self.layer_types if t in ("attn", "moe"))
        ctx = seq_len if self.window == 0 else min(seq_len, self.window)
        fl += attn_layers * 4.0 * H * hd * ctx
        return fl


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (SSM / hybrid / SWA)."""
    if shape.name != "long_500k":
        return True
    sub_quadratic = (
        all(t in ("ssm", "rec") for t in set(cfg.layer_types))
        or (cfg.window > 0)
        or (set(cfg.block_pattern) <= {"rec", "attn"} and "rec" in cfg.block_pattern)
    )
    return sub_quadratic
