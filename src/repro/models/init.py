"""Parameter initialization. All init functions are traceable (usable under
``jax.eval_shape`` for the allocation-free dry-run)."""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def padded_vocab(cfg: ModelConfig, pad_to: int = 256) -> int:
    return ((cfg.vocab_size + pad_to - 1) // pad_to) * pad_to


def _dense(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)


def init_block_params(key, btype: str, cfg: ModelConfig, stack: int) -> Dict:
    """Init one block type with a leading ``stack`` (scan) dimension."""
    D, F = cfg.d_model, cfg.d_ff
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = _dtype(cfg)
    keys = iter(jax.random.split(key, 32))
    p: Dict = {}

    def dense(shape, fan_in):
        return _dense(next(keys), (stack, *shape), fan_in, dt)

    def zeros(shape, dtype=jnp.float32):
        return jnp.zeros((stack, *shape), dtype)

    if btype in ("attn", "moe"):
        p["ln1"] = zeros((D,))
        p["wq"] = dense((D, H * hd), D)
        p["wk"] = dense((D, G * hd), D)
        p["wv"] = dense((D, G * hd), D)
        p["wo"] = dense((H * hd, D), H * hd)
        if cfg.qkv_bias:
            p["bq"] = zeros((H * hd,), dt)
            p["bk"] = zeros((G * hd,), dt)
            p["bv"] = zeros((G * hd,), dt)
        p["ln2"] = zeros((D,))
        gated = cfg.mlp_variant == "swiglu"
        if btype == "attn":
            p["wg"] = dense((D, F), D)
            if gated:
                p["wu"] = dense((D, F), D)
            p["wd"] = dense((F, D), F)
        else:
            E = cfg.num_experts
            p["router"] = dense((D, E), D)
            p["ewg"] = dense((E, D, F), D)
            if gated:
                p["ewu"] = dense((E, D, F), D)
            p["ewd"] = dense((E, F, D), F)
    elif btype == "ssm":
        Din, N, R, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank, cfg.ssm_conv
        p["ln"] = zeros((D,))
        p["w_in"] = dense((D, 2 * Din), D)
        p["conv_w"] = dense((Din, K), K)
        p["conv_b"] = zeros((Din,))
        p["w_x"] = dense((Din, R + 2 * N), Din)
        p["w_dt"] = dense((R, Din), R)
        p["b_dt"] = zeros((Din,))
        # S4-style A init: -[1..N] per channel, stored as log
        a = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (Din, 1))
        p["a_log"] = jnp.tile(jnp.log(a)[None], (stack, 1, 1))
        p["d_skip"] = jnp.ones((stack, Din), jnp.float32)
        p["w_out"] = dense((Din, D), Din)
    elif btype == "rec":
        Dr, K = cfg.rnn_width, cfg.ssm_conv
        p["ln"] = zeros((D,))
        p["wy"] = dense((D, Dr), D)
        p["wx"] = dense((D, Dr), D)
        p["conv_w"] = dense((Dr, K), K)
        p["conv_b"] = zeros((Dr,))
        p["wr"] = dense((Dr, Dr), Dr)
        p["br"] = zeros((Dr,))
        p["wi"] = dense((Dr, Dr), Dr)
        p["bi"] = zeros((Dr,))
        # lambda init so decay a^c is in (0.9, 0.999) as in Griffin
        u = jax.random.uniform(next(keys), (stack, Dr), jnp.float32, 0.9, 0.999)
        p["lam"] = jnp.log(jnp.exp(-jnp.log(u) / 8.0) - 1.0)  # softplus^-1
        p["w_out"] = dense((Dr, D), Dr)
        p["ln2"] = zeros((D,))
        p["wg"] = dense((D, F), D)
        if cfg.mlp_variant == "swiglu":
            p["wu"] = dense((D, F), D)
        p["wd"] = dense((F, D), F)
    else:
        raise ValueError(btype)
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    """Full parameter pytree: embed + per-segment stacked blocks + head."""
    V = padded_vocab(cfg)
    D = cfg.d_model
    dt = _dtype(cfg)
    keys = jax.random.split(key, 4 + len(cfg.segments()))
    params: Dict = {
        "embed": {"tok": (jax.random.normal(keys[0], (V, D), jnp.float32) * 0.02).astype(dt)},
        "final_norm": jnp.zeros((D,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": _dense(keys[1], (D, V), D, dt)}
    for si, (unit, repeats) in enumerate(cfg.segments()):
        seg_key = jax.random.split(keys[3 + si], len(unit))
        params[f"seg{si}"] = {
            f"u{j}": init_block_params(seg_key[j], btype, cfg, repeats)
            for j, btype in enumerate(unit)
        }
    return params
