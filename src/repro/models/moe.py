"""Top-k mixture-of-experts MLP with capacity-bounded sort dispatch.

Dispatch is the MegaBlocks/Switch-style static-shape formulation:
  1. router logits -> top-k experts + normalized combine weights per token,
  2. tokens are ranked within their expert (cumulative count) and dropped
     beyond ``capacity = ceil(T * k / E * capacity_factor)``,
  3. gather tokens into an (E, C, D) buffer, run a batched expert matmul
     (E, C, D) x (E, D, F) — MXU-friendly and EP-shardable on the expert
     axis, then scatter-add back weighted by the combine weights.

Under expert-parallel sharding (experts split over the ``model`` mesh axis)
the gather/scatter lower to all-to-all collectives via GSPMD.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def router_topk(
    logits: jnp.ndarray, k: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(T, E) -> ((T, k) expert ids, (T, k) softmax-renormalized weights)."""
    weights, idx = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(weights.astype(jnp.float32), axis=-1)
    return idx, weights


def capacity_for(tokens: int, num_experts: int, k: int, factor: float) -> int:
    cap = int(math.ceil(tokens * k / num_experts * factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU-lane alignment


def moe_mlp(
    x: jnp.ndarray,          # (T, D) flattened tokens
    router_w: jnp.ndarray,   # (D, E)
    wg: jnp.ndarray,         # (E, D, F)
    wu: jnp.ndarray,         # (E, D, F)
    wd: jnp.ndarray,         # (E, F, D)
    k: int,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (T, D), aux load-balancing loss scalar)."""
    T, D = x.shape
    E = router_w.shape[1]
    C = capacity_for(T, E, k, capacity_factor)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32))
    logits = constrain(logits, "batch", None)
    expert_idx, combine_w = router_topk(logits, k)            # (T, k)

    # Position of each (token, slot) within its expert: rank by arrival order.
    flat_expert = expert_idx.reshape(-1)                      # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)          # running count
    slot = jnp.take_along_axis(pos_in_expert, flat_expert[:, None], axis=1)[:, 0]
    keep = slot < C                                           # capacity drop

    # Scatter token features into the (E, C, D) dispatch buffer.
    buf_index = flat_expert * C + slot
    buf_index = jnp.where(keep, buf_index, E * C)             # dropped -> scratch row
    token_of = jnp.repeat(jnp.arange(T), k)
    dispatch = jnp.zeros((E * C + 1, D), x.dtype).at[buf_index].set(x[token_of])
    dispatch = dispatch[: E * C].reshape(E, C, D)
    dispatch = constrain(dispatch, "experts", "batch", None)

    # Batched expert FFN (EP-shardable on the leading expert axis).
    if wu is not None:  # SwiGLU
        gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", dispatch, wg))
        up = jnp.einsum("ecd,edf->ecf", dispatch, wu)
        hidden = gate * up
    else:               # GELU
        hidden = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", dispatch, wg))
    expert_out = jnp.einsum("ecf,efd->ecd", hidden, wd)       # (E, C, D)
    expert_out = constrain(expert_out, "experts", "batch", None)

    # Gather back + combine. ``token_of`` is repeat(arange(T), k), so the
    # combine "scatter-add" is exactly a (T, k, D) reshape + sum over k —
    # expressing it that way keeps it shard-local under GSPMD instead of
    # a replicate+all-reduce scatter (perf iteration #2b).
    flat_out = expert_out.reshape(E * C, D)
    gathered = jnp.where(
        keep[:, None], flat_out[jnp.where(keep, buf_index, 0)], 0.0
    )                                                          # (T*k, D)
    w = combine_w.reshape(-1)[:, None].astype(x.dtype)
    out = (gathered * w).reshape(T, k, D).sum(axis=1)
    out = constrain(out, "batch", None)

    # Switch-style load-balance auxiliary loss.
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


# ---------------------------------------------------------------------------
# Expert-parallel MoE via shard_map (perf iteration #2: proper EP)
# ---------------------------------------------------------------------------


def _rank_within(group: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """Arrival-order rank of each element within its group id."""
    onehot = jax.nn.one_hot(group, n_groups, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    return jnp.take_along_axis(pos, group[:, None], axis=1)[:, 0]


def moe_mlp_ep(
    x: jnp.ndarray,          # (T, D) GLOBAL tokens (sharded over batch axes)
    router_w: jnp.ndarray,   # (D, E) replicated
    wg: jnp.ndarray,         # (E, D, F) sharded over the expert axis
    wu,                      # (E, D, F) or None
    wd: jnp.ndarray,         # (E, F, D)
    k: int,
    capacity_factor: float,
    mesh,
    batch_axes: Tuple[str, ...],
    expert_axis: str = "model",
):
    """Shard-local MoE dispatch with explicit all-to-all over the expert axis.

    GSPMD lowers the pjit dispatch scatters by replicating the (E, C, D)
    buffers and all-reducing them — gigabytes of wire per layer (verified in
    the dry-run HLO as 'involuntary full rematerialization' all-reduces).
    Inside shard_map every scatter is shard-LOCAL; the only collectives are
    two token-sized all-to-alls (dispatch + return), which is the minimal
    communication MoE requires. Two-stage capacity: C_s per destination
    shard at dispatch, C_e per local expert after the exchange (same drop
    semantics as the dense path under balanced load).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    E = router_w.shape[1]
    n_shards = mesh.shape[expert_axis]
    assert E % n_shards == 0, (E, n_shards)
    E_local = E // n_shards
    gated = wu is not None

    def local_fn(x_l, rw, wg_l, wu_l, wd_l):
        T_l, D = x_l.shape
        logits = jnp.einsum(
            "td,de->te", x_l.astype(jnp.float32), rw.astype(jnp.float32)
        )
        expert_idx, combine_w = router_topk(logits, k)          # (T_l, k)
        flat_e = expert_idx.reshape(-1)
        token_of = jnp.repeat(jnp.arange(T_l), k)
        dest = flat_e // E_local                                 # target shard

        # --- stage 1: pack per-destination-shard send buffers (local scatter)
        C_s = capacity_for(T_l, n_shards, k, capacity_factor)
        slot = _rank_within(dest, n_shards)
        keep = slot < C_s
        send_idx = jnp.where(keep, dest * C_s + slot, n_shards * C_s)
        send = (
            jnp.zeros((n_shards * C_s + 1, D), x_l.dtype)
            .at[send_idx].set(x_l[token_of])[: n_shards * C_s]
            .reshape(n_shards, C_s, D)
        )
        send_e = (
            jnp.full((n_shards * C_s + 1,), -1, jnp.int32)
            .at[send_idx].set((flat_e % E_local).astype(jnp.int32))[: n_shards * C_s]
            .reshape(n_shards, C_s)
        )

        # --- exchange: tokens travel to their experts' shard
        recv = jax.lax.all_to_all(send, expert_axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, expert_axis, 0, 0, tiled=True)
        rows = recv.reshape(n_shards * C_s, D)
        re = recv_e.reshape(-1)

        # --- stage 2: local dispatch to per-expert buffers (local scatter)
        C_e = capacity_for(n_shards * C_s, E_local, 1, capacity_factor)
        valid = re >= 0
        slot2 = _rank_within(jnp.where(valid, re, 0), E_local)
        keep2 = valid & (slot2 < C_e)
        buf_idx = jnp.where(keep2, re * C_e + slot2, E_local * C_e)
        buf = (
            jnp.zeros((E_local * C_e + 1, D), x_l.dtype)
            .at[buf_idx].set(rows)[: E_local * C_e]
            .reshape(E_local, C_e, D)
        )
        if gated:
            hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg_l)) * jnp.einsum(
                "ecd,edf->ecf", buf, wu_l
            )
        else:
            hidden = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wg_l))
        eout = jnp.einsum("ecf,efd->ecd", hidden, wd_l).reshape(E_local * C_e, D)

        # --- return trip: same slots back to the source shard
        back_rows = jnp.where(keep2[:, None], eout[jnp.where(keep2, buf_idx, 0)], 0.0)
        back = jax.lax.all_to_all(
            back_rows.reshape(n_shards, C_s, D), expert_axis, 0, 0, tiled=True
        ).reshape(n_shards * C_s, D)

        gathered = jnp.where(keep[:, None], back[jnp.where(keep, send_idx, 0)], 0.0)
        w = combine_w.reshape(-1)[:, None].astype(x_l.dtype)
        y = (gathered * w).reshape(T_l, k, D).sum(axis=1)

        # load-balance aux (pmean over every mesh axis -> replicated scalar)
        probs = jax.nn.softmax(logits, axis=-1)
        frac_tok = jnp.mean(
            jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0
        )
        aux = E * jnp.sum(frac_tok * jnp.mean(probs, axis=0))
        for ax in mesh.axis_names:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    baxes = tuple(a for a in batch_axes if a in mesh.shape)
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(bspec, None), P(None, None),
            P(expert_axis, None, None),
            P(expert_axis, None, None) if gated else P(None),
            P(expert_axis, None, None),
        ),
        out_specs=(P(bspec, None), P()),
        check_rep=False,
    )(x, router_w, wg, wu if gated else jnp.zeros((1,), x.dtype), wd)
