"""Per-block-type forward functions (full-sequence and single-step decode).

Every block type exposes:
  * ``<type>_forward(params, x, cfg, *, cache=None, pos=0, ...)`` over a
    (B, S, D) sequence, optionally producing a prefill cache, and
  * ``<type>_decode(params, x, cache, cfg, pos, ...)`` for one (B, 1, D) step.

Caches are dict pytrees with static shapes (ring buffers for windowed
attention) so the decode step lowers to a fixed-shape XLA program.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention, direct_attention
from .config import ModelConfig
from .mlp import mlp_apply, rmsnorm
from .moe import moe_mlp
from .rglru import rglru_decode_step, rglru_gates, rglru_scan
from .rotary import apply_rope
from .ssm import causal_conv1d, selective_scan, ssm_decode_step

Cache = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Attention sub-block (shared by 'attn' and 'moe' block types)
# ---------------------------------------------------------------------------


def _attn_proj(params, x, cfg: ModelConfig):
    B, S, D = x.shape
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, params["wq"])
    k = jnp.einsum("bsd,df->bsf", x, params["wk"])
    v = jnp.einsum("bsd,df->bsf", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, G, hd),
        v.reshape(B, S, G, hd),
    )


def attn_sublayer(
    params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    window: int,
    cache: Optional[Cache] = None,
    pos: jnp.ndarray | int = 0,
    ring_pos: Optional[jnp.ndarray] = None,
    make_cache: bool = False,
) -> Tuple[jnp.ndarray, Optional[Cache]]:
    """Full-sequence attention. If ``make_cache``, also return the KV cache."""
    B, S, D = x.shape
    q, k, v = _attn_proj(params, x, cfg)
    positions = pos + jnp.arange(S)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = attention(
        q, k, v, causal=True, window=window, q_offset=0,
        causal_buckets=cfg.attn_buckets,
    )
    out = jnp.einsum(
        "bsf,fd->bsd", out.reshape(B, S, cfg.num_heads * cfg.head_dim), params["wo"]
    )
    new_cache = None
    if make_cache:
        W = window if window > 0 else S
        W = min(W, S)
        new_cache = {"k": k[:, S - W :], "v": v[:, S - W :]}
    return out, new_cache


KV_SCALE_EPS = 1e-8


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, T, G, hd) -> (int8 values, (B, T, G, 1) fp32 scales)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0 + KV_SCALE_EPS
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def attn_sublayer_decode(
    params,
    x: jnp.ndarray,            # (B, 1, D)
    cfg: ModelConfig,
    cache: Cache,              # {"k": (B, T, G, hd), "v": ...} (+scales if int8)
    pos: jnp.ndarray,          # scalar absolute position of this token
    window: int,
    ring_pos: jnp.ndarray,     # (T,) absolute position stored in each slot
) -> Tuple[jnp.ndarray, Cache]:
    B = x.shape[0]
    T = cache["k"].shape[1]
    q, k, v = _attn_proj(params, x, cfg)
    q = apply_rope(q, pos + jnp.zeros((1,), jnp.int32), cfg.rope_theta)
    k = apply_rope(k, pos + jnp.zeros((1,), jnp.int32), cfg.rope_theta)
    slot = jnp.where(window > 0, pos % T, jnp.minimum(pos, T - 1))

    quant = cfg.kv_quant == "int8"
    if quant:
        # perf iteration #3: the decode memory term is dominated by KV-cache
        # reads; int8 storage halves that traffic (and residency) at the
        # cost of cheap dequant VPU work + <0.5% quantization error.
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=1),
            "k_scale": jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], ks, slot, axis=1),
            "v_scale": jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], vs, slot, axis=1),
        }
        k_cache = dequantize_kv(new_cache["k"], new_cache["k_scale"], k.dtype)
        v_cache = dequantize_kv(new_cache["v"], new_cache["v_scale"], v.dtype)
    else:
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1),
        }
        k_cache, v_cache = new_cache["k"], new_cache["v"]

    k_pos = jnp.where(jnp.arange(T) == slot, pos, ring_pos)
    kv_valid = (k_pos >= 0) & (k_pos <= pos)
    out = direct_attention(
        q, k_cache, v_cache, causal=True, window=window,
        q_offset=pos, k_positions=k_pos,
        kv_valid=jnp.broadcast_to(kv_valid[None], (B, T)),
    )
    out = jnp.einsum(
        "bsf,fd->bsd", out.reshape(B, 1, cfg.num_heads * cfg.head_dim), params["wo"]
    )
    return out, new_cache


# ---------------------------------------------------------------------------
# Block types
# ---------------------------------------------------------------------------


def attn_block(params, x, cfg: ModelConfig, *, window, make_cache=False):
    h, cache = attn_sublayer(
        params, rmsnorm(x, params["ln1"], cfg.norm_eps), cfg,
        window=window, make_cache=make_cache,
    )
    x = x + h
    x = x + mlp_apply(rmsnorm(x, params["ln2"], cfg.norm_eps), params, cfg.mlp_variant)
    return x, cache, jnp.float32(0.0)


def attn_block_decode(params, x, cache, cfg: ModelConfig, pos, *, window, ring_pos):
    h, new_cache = attn_sublayer_decode(
        params, rmsnorm(x, params["ln1"], cfg.norm_eps), cfg, cache, pos, window, ring_pos
    )
    x = x + h
    x = x + mlp_apply(rmsnorm(x, params["ln2"], cfg.norm_eps), params, cfg.mlp_variant)
    return x, new_cache


def _moe_ffn(params, flat, cfg: ModelConfig):
    """Dispatch to dense-pjit or shard_map expert-parallel MoE."""
    from repro.distributed.sharding import active_rules
    from .moe import moe_mlp_ep

    rules = active_rules()
    if cfg.moe_ep and rules is not None and "model" in rules.mesh.shape:
        return moe_mlp_ep(
            flat, params["router"], params["ewg"], params.get("ewu"),
            params["ewd"], cfg.experts_per_token, cfg.expert_capacity_factor,
            rules.mesh, batch_axes=("pod", "data"), expert_axis="model",
        )
    return moe_mlp(
        flat, params["router"], params["ewg"], params.get("ewu"), params["ewd"],
        cfg.experts_per_token, cfg.expert_capacity_factor,
    )


def moe_block(params, x, cfg: ModelConfig, *, window, make_cache=False):
    h, cache = attn_sublayer(
        params, rmsnorm(x, params["ln1"], cfg.norm_eps), cfg,
        window=window, make_cache=make_cache,
    )
    x = x + h
    B, S, D = x.shape
    flat = rmsnorm(x, params["ln2"], cfg.norm_eps).reshape(B * S, D)
    out, aux = _moe_ffn(params, flat, cfg)
    return x + out.reshape(B, S, D), cache, aux


def moe_block_decode(params, x, cache, cfg: ModelConfig, pos, *, window, ring_pos):
    h, new_cache = attn_sublayer_decode(
        params, rmsnorm(x, params["ln1"], cfg.norm_eps), cfg, cache, pos, window, ring_pos
    )
    x = x + h
    B, S, D = x.shape
    flat = rmsnorm(x, params["ln2"], cfg.norm_eps).reshape(B * S, D)
    out, _ = _moe_ffn(params, flat, cfg)
    return x + out.reshape(B, S, D), new_cache


def _ssm_inner(params, xn, cfg: ModelConfig, conv_state, h_state):
    """Shared Mamba mixer; sequence length may be 1 (decode) or S."""
    B, S, D = xn.shape
    Din, N, R = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    xz = jnp.einsum("bsd,df->bsf", xn, params["w_in"])
    xpart, z = jnp.split(xz, 2, axis=-1)                       # (B,S,Din) each
    xconv, new_conv = causal_conv1d(xpart, params["conv_w"], params["conv_b"], conv_state)
    xconv = jax.nn.silu(xconv)
    proj = jnp.einsum("bsf,fr->bsr", xconv, params["w_x"])     # (B,S,R+2N)
    dt_r, Bmat, Cmat = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rf->bsf", dt_r, params["w_dt"]) + params["b_dt"]
    )
    A = -jnp.exp(params["a_log"].astype(jnp.float32))          # (Din,N), negative
    if S == 1 and h_state is not None:
        y, h_new = ssm_decode_step(
            xconv[:, 0], dt[:, 0], A, Bmat[:, 0], Cmat[:, 0], params["d_skip"], h_state
        )
        y = y[:, None]
    else:
        y, h_new = selective_scan(
            xconv, dt, A, Bmat, Cmat, params["d_skip"], h0=h_state, chunk=cfg.ssm_chunk
        )
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, params["w_out"])
    return out, new_conv, h_new


def ssm_block(params, x, cfg: ModelConfig, *, make_cache=False):
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    B = x.shape[0]
    h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32) if make_cache else None
    out, new_conv, h_new = _ssm_inner(params, xn, cfg, conv_state=None, h_state=h0)
    cache = {"conv": new_conv, "h": h_new} if make_cache else None
    return x + out, cache, jnp.float32(0.0)


def ssm_block_decode(params, x, cache, cfg: ModelConfig, pos):
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    out, new_conv, h_new = _ssm_inner(
        params, xn, cfg, conv_state=cache["conv"], h_state=cache["h"]
    )
    return x + out, {"conv": new_conv, "h": h_new}


def rec_block(params, x, cfg: ModelConfig, *, make_cache=False):
    """RG-LRU recurrent block (Griffin): gated dual-branch."""
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    B, S, D = xn.shape
    y = jax.nn.gelu(jnp.einsum("bsd,df->bsf", xn, params["wy"]))
    xb = jnp.einsum("bsd,df->bsf", xn, params["wx"])           # (B,S,Dr)
    xb, new_conv = causal_conv1d(xb, params["conv_w"], params["conv_b"], None)
    log_a, gated = rglru_gates(
        xb, params["wr"], params["wi"], params["br"], params["bi"], params["lam"]
    )
    h, h_last = rglru_scan(log_a, gated)
    out = jnp.einsum("bsf,fd->bsd", (h.astype(x.dtype) * y), params["w_out"])
    x = x + out
    x = x + mlp_apply(rmsnorm(x, params["ln2"], cfg.norm_eps), params, cfg.mlp_variant)
    cache = {"conv": new_conv, "h": h_last} if make_cache else None
    return x, cache, jnp.float32(0.0)


def rec_block_decode(params, x, cache, cfg: ModelConfig, pos):
    xn = rmsnorm(x, params["ln"], cfg.norm_eps)
    B = xn.shape[0]
    y = jax.nn.gelu(jnp.einsum("bsd,df->bsf", xn, params["wy"]))
    xb = jnp.einsum("bsd,df->bsf", xn, params["wx"])
    xb, new_conv = causal_conv1d(xb, params["conv_w"], params["conv_b"], cache["conv"])
    h_out, h_new = rglru_decode_step(
        xb[:, 0], params["wr"], params["wi"], params["br"], params["bi"],
        params["lam"], cache["h"],
    )
    out = jnp.einsum("bsf,fd->bsd", h_out[:, None] * y, params["w_out"])
    x = x + out
    x = x + mlp_apply(rmsnorm(x, params["ln2"], cfg.norm_eps), params, cfg.mlp_variant)
    return x, {"conv": new_conv, "h": h_new}
