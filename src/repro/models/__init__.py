"""Model substrate: configs, layers, and the scanned-LM assembly."""
from .config import ModelConfig, ShapeConfig, SHAPES, shape_applicable
from .init import init_params, padded_vocab
from .model import LM, block_window

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shape_applicable",
    "init_params", "padded_vocab", "LM", "block_window",
]
