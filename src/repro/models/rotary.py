"""Rotary position embeddings (RoPE)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotate pairs of features by position-dependent angles.

    Args:
      x: (B, S, H, hd) queries or keys.
      positions: (B, S) or (S,) absolute token positions.
    """
    B, S, H, hd = x.shape
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    pos = jnp.asarray(positions, jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    angles = pos[:, :, None] * inv[None, None, :]           # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]                    # (B, S, 1, hd/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.astype(x.dtype)
