"""Gated (SwiGLU) feed-forward block."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swiglu(x: jnp.ndarray, wg: jnp.ndarray, wu: jnp.ndarray, wd: jnp.ndarray) -> jnp.ndarray:
    """x (..., D) -> (..., D) via silu(x wg) * (x wu) wd."""
    gate = jax.nn.silu(jnp.einsum("...d,df->...f", x, wg))
    up = jnp.einsum("...d,df->...f", x, wu)
    return jnp.einsum("...f,fd->...d", gate * up, wd)


def gelu_mlp(x: jnp.ndarray, wg: jnp.ndarray, wd: jnp.ndarray) -> jnp.ndarray:
    """Non-gated 2-matrix FFN (starcoder2 / musicgen style)."""
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, wg))
    return jnp.einsum("...f,fd->...d", h, wd)


def mlp_apply(x: jnp.ndarray, params, variant: str) -> jnp.ndarray:
    if variant == "swiglu":
        return swiglu(x, params["wg"], params["wu"], params["wd"])
    return gelu_mlp(x, params["wg"], params["wd"])


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)
