"""Shard-aware checkpointing (npz-based, no orbax).

Layout: ``<dir>/step_<n>/shard_<host>.npz`` + ``meta.json``; writes go to a
``.tmp`` sibling then atomic-rename, so a crash mid-save can never corrupt
the latest checkpoint. ``restore_latest`` walks steps downward until a
complete checkpoint is found — the restart path after a node failure.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        leaves.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, [l for _, l in zip(paths, leaves)])


class CheckpointManager:
    """Periodic checkpointing with retention GC and crash-safe writes."""

    def __init__(self, directory: str, keep_last: int = 3, host_id: int = 0,
                 num_hosts: int = 1):
        self.dir = directory
        self.keep_last = keep_last
        self.host_id = host_id
        self.num_hosts = num_hosts
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra_meta: Optional[Dict] = None):
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"), **_flatten(state))
        if self.host_id == 0:
            meta = {"step": step, "num_hosts": self.num_hosts}
            meta.update(extra_meta or {})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
        # single-host: rename is the commit point
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    # ------------------------------------------------------------------
    def restore(self, step: int, template: Any) -> Any:
        path = os.path.join(self.dir, f"step_{step:09d}", f"shard_{self.host_id}.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_into(template, flat)

    def restore_latest(self, template: Any) -> Tuple[Optional[int], Any]:
        """Returns (step, state) of the newest complete checkpoint, or
        (None, template) when none exists."""
        for step in reversed(self.list_steps()):
            try:
                return step, self.restore(step, template)
            except Exception:
                continue  # incomplete/corrupt: fall back to the previous one
        return None, template
