"""Pallas TPU kernel: Monte-Carlo correctness-probability estimation.

The selector's hot spot (paper Section 4.3): evaluate xi-hat for C candidate
subsets over theta shared response draws. Reformulated for the MXU as a
one-hot contraction per theta-tile:

    beliefs[c, t, k] = sum_l (mask[c,l] * w[l]) * onehot(resp[t,l])[k]

Grid: one dimension over theta tiles; every tile accumulates its partial
fractional-credit sums into the (C,) output block (TPU sequential-grid
revisiting pattern; the first tile initializes). VMEM residency per tile:
the (Tt, L, K) one-hot cube + the (C, L) mask matrix; Tt is chosen so the
cube fits comfortably (Tt=256, L<=32, K<=128 -> 4 MB fp32).

``ref.py:mc_correctness_ref`` is the pure-jnp oracle (same math as
``repro.core.mc.xi_from_responses``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TIE_TOL = 1e-6


def _kernel(resp_ref, maskw_ref, mask_ref, empty_ref, out_ref, *, num_classes, theta_total):
    """One theta-tile.

    resp_ref:  (Tt, L) int32 responses (ground truth = class 0)
    maskw_ref: (C, L) f32 mask * log-weight
    mask_ref:  (C, L) f32 subset indicator
    empty_ref: (1, 1) f32 empty-class log belief
    out_ref:   (1, C) f32 accumulated xi estimates
    """
    i = pl.program_id(0)

    resp = resp_ref[...]                                   # (Tt, L)
    Tt, L = resp.shape
    K = num_classes

    # one-hot cube via iota comparison: (Tt, L, K)
    classes = jax.lax.broadcasted_iota(jnp.int32, (Tt, L, K), 2)
    onehot = (resp[:, :, None] == classes).astype(jnp.float32)

    maskw = maskw_ref[...]                                 # (C, L)
    mask = mask_ref[...]
    flat = onehot.transpose(1, 0, 2).reshape(L, Tt * K)    # (L, Tt*K)
    # beliefs/counts: (C, Tt, K) — contraction over L lowers to MXU dots
    dn = (((1,), (0,)), ((), ()))
    beliefs = jax.lax.dot_general(
        maskw, flat, dn, preferred_element_type=jnp.float32
    ).reshape(-1, Tt, K)
    counts = jax.lax.dot_general(
        mask, flat, dn, preferred_element_type=jnp.float32
    ).reshape(-1, Tt, K)

    empty = empty_ref[0, 0]
    beliefs = jnp.where(counts > 0, beliefs, empty)

    mx = jnp.max(beliefs, axis=-1, keepdims=True)
    is_max = (beliefs >= mx - TIE_TOL).astype(jnp.float32)
    ties = jnp.sum(is_max, axis=-1)                        # (C, Tt)
    credit = is_max[:, :, 0] / ties
    partial = jnp.sum(credit, axis=-1) / theta_total       # (C,)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, :] += partial


@functools.partial(
    jax.jit, static_argnames=("num_classes", "tile", "interpret")
)
def mc_correctness_pallas(
    responses: jnp.ndarray,    # (theta, L) int32
    masks: jnp.ndarray,        # (C, L) float32
    log_weights: jnp.ndarray,  # (L,) float32
    empty_belief: jnp.ndarray, # scalar f32
    num_classes: int,
    tile: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    theta, L = responses.shape
    C = masks.shape[0]
    tile = min(tile, theta)
    n = (theta + tile - 1) // tile
    pad = n * tile - theta
    if pad:  # padded rows: response -1 matches no class -> all-empty -> 1/K
        responses = jnp.concatenate(
            [responses, jnp.full((pad, L), -1, jnp.int32)], axis=0
        )
    maskw = masks * log_weights[None, :]
    empty = jnp.asarray(empty_belief, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, num_classes=num_classes, theta_total=float(theta)),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((tile, L), lambda i: (i, 0)),
            pl.BlockSpec((C, L), lambda i: (0, 0)),
            pl.BlockSpec((C, L), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, C), jnp.float32),
        interpret=interpret,
    )(responses, maskw, masks, empty)
    # padded rows contributed 1/K each (all-empty tie credit); subtract
    correction = pad * (1.0 / num_classes) / float(theta)
    return out[0] - correction


# ---------------------------------------------------------------------------
# Grouped-mask layout: the batched planner's (G, theta, L) draws
# ---------------------------------------------------------------------------


def _grouped_kernel(resp_ref, maskw_ref, mask_ref, empty_ref, valid_ref,
                    theta_ref, out_ref, *, num_classes):
    """One (group, theta-tile) cell.

    resp_ref:  (1, Tt, L) int32 responses of the cell's group
    maskw_ref: (1, C, L) f32 mask * log-weight
    mask_ref:  (1, C, L) f32 subset indicator
    empty_ref: (1, 1) f32 empty-class log belief
    valid_ref: (1, Tt) f32 draw mask (0 past the group's own theta)
    theta_ref: (1, 1) f32 the group's real draw count
    out_ref:   (1, C) f32 accumulated xi estimates (revisited over tiles)
    """
    i = pl.program_id(1)

    resp = resp_ref[0]                                     # (Tt, L)
    Tt, L = resp.shape
    K = num_classes

    classes = jax.lax.broadcasted_iota(jnp.int32, (Tt, L, K), 2)
    onehot = (resp[:, :, None] == classes).astype(jnp.float32)

    maskw = maskw_ref[0]                                   # (C, L)
    mask = mask_ref[0]
    flat = onehot.transpose(1, 0, 2).reshape(L, Tt * K)    # (L, Tt*K)
    dn = (((1,), (0,)), ((), ()))
    beliefs = jax.lax.dot_general(
        maskw, flat, dn, preferred_element_type=jnp.float32
    ).reshape(-1, Tt, K)
    counts = jax.lax.dot_general(
        mask, flat, dn, preferred_element_type=jnp.float32
    ).reshape(-1, Tt, K)

    empty = empty_ref[0, 0]
    beliefs = jnp.where(counts > 0, beliefs, empty)

    mx = jnp.max(beliefs, axis=-1, keepdims=True)
    is_max = (beliefs >= mx - TIE_TOL).astype(jnp.float32)
    ties = jnp.sum(is_max, axis=-1)                        # (C, Tt)
    credit = is_max[:, :, 0] / ties * valid_ref[0][None, :]
    partial = jnp.sum(credit, axis=-1) / theta_ref[0, 0]   # (C,)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, :] += partial


@functools.partial(
    jax.jit, static_argnames=("num_classes", "tile", "interpret")
)
def mc_correctness_grouped_pallas(
    responses: jnp.ndarray,    # (G, theta, L) int32, -1 = padded draw
    masks: jnp.ndarray,        # (G, C, L) float32
    log_weights: jnp.ndarray,  # (G, L) float32
    empty_belief: jnp.ndarray, # (G,) f32
    valid: jnp.ndarray,        # (G, theta) f32 draw mask
    theta: jnp.ndarray,        # (G,) f32 real draw counts
    num_classes: int,
    tile: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Grouped-mask xi estimation: grid (G, theta tiles), each group row
    accumulated independently. The ragged-theta layout is explicit — padded
    draws carry ``valid`` 0 and contribute nothing, so no post-hoc padding
    correction is needed (unlike the ungrouped kernel). Same flag
    conventions as ``belief_aggregate``: ``interpret`` on CPU, ``tile``
    trades grid steps for VMEM with no effect on results."""
    G, theta_n, L = responses.shape
    C = masks.shape[1]
    tile = min(tile, theta_n)
    n = (theta_n + tile - 1) // tile
    pad = n * tile - theta_n
    if pad:
        responses = jnp.concatenate(
            [responses, jnp.full((G, pad, L), -1, jnp.int32)], axis=1
        )
        valid = jnp.concatenate(
            [valid, jnp.zeros((G, pad), jnp.float32)], axis=1
        )
    maskw = masks * log_weights[:, None, :]
    empty = jnp.asarray(empty_belief, jnp.float32).reshape(G, 1)
    theta = jnp.asarray(theta, jnp.float32).reshape(G, 1)

    return pl.pallas_call(
        functools.partial(_grouped_kernel, num_classes=num_classes),
        grid=(G, n),
        in_specs=[
            pl.BlockSpec((1, tile, L), lambda g, i: (g, i, 0)),
            pl.BlockSpec((1, C, L), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, C, L), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, 1), lambda g, i: (g, 0)),
            pl.BlockSpec((1, tile), lambda g, i: (g, i)),
            pl.BlockSpec((1, 1), lambda g, i: (g, 0)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda g, i: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((G, C), jnp.float32),
        interpret=interpret,
    )(responses, maskw, masks, empty, valid, theta)
