"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` mode (the kernel
body executes in Python per grid step) — set ``REPRO_KERNEL_COMPILE=1`` on a
real TPU to lower them natively. The wrappers handle padding/layout so call
sites never see tiling constraints.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from .belief_aggregate import belief_aggregate_pallas
from .flash_attention import flash_attention_pallas
from .mc_correctness import mc_correctness_grouped_pallas, mc_correctness_pallas
from .rglru_scan import rglru_scan_pallas

_INTERPRET = os.environ.get("REPRO_KERNEL_COMPILE", "0") != "1"


def mc_correctness(responses, masks, log_weights, empty_belief, num_classes: int):
    """(C,) Monte-Carlo xi estimates over shared response draws."""
    return mc_correctness_pallas(
        responses, masks, log_weights, empty_belief, num_classes,
        interpret=_INTERPRET,
    )


def mc_correctness_grouped(responses, masks, log_weights, empty_belief,
                           valid, theta, num_classes: int, tile: int = 256):
    """(G, C) xi estimates over the batched planner's stacked (G, theta, L)
    draws; ragged thetas carried by the ``valid`` mask."""
    return mc_correctness_grouped_pallas(
        responses, masks, log_weights, empty_belief, valid, theta,
        num_classes, tile=tile, interpret=_INTERPRET,
    )


def belief_aggregate(responses, log_weights, empty_belief, num_classes: int,
                     tile: int = 128):
    """Batched router aggregation: (log_beliefs (B,K), predictions (B,)).

    Safe to call from inside traced/jitted code (the serving router
    dispatches it from the jitted wave program); ``tile`` trades grid steps
    for VMEM footprint and does not affect per-row results.
    """
    return belief_aggregate_pallas(
        responses, log_weights, empty_belief, num_classes, tile=tile,
        interpret=_INTERPRET,
    )


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512):
    """(B,S,H,hd) x (B,T,G,hd) -> (B,S,H,hd) with causal block skipping."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, interpret=_INTERPRET,
    )


def rglru_scan(log_a, gated, h0):
    """Diagonal linear recurrence: (h (B,S,D), h_last (B,D))."""
    return rglru_scan_pallas(
        jnp.asarray(log_a, jnp.float32),
        jnp.asarray(gated, jnp.float32),
        jnp.asarray(h0, jnp.float32),
        interpret=_INTERPRET,
    )


def mamba_scan(x, dt, A, Bmat, Cmat, Dskip, h0):
    """Fused Mamba-1 selective scan: (y (B,S,Din), h_last (B,Din,N))."""
    from .mamba_scan import mamba_scan_pallas

    f32 = lambda t: jnp.asarray(t, jnp.float32)
    return mamba_scan_pallas(
        f32(x), f32(dt), f32(A), f32(Bmat), f32(Cmat), f32(Dskip), f32(h0),
        interpret=_INTERPRET,
    )


def kernel_compile_probe() -> dict:
    """Attempt *native* (``interpret=False``) compilation of the serving
    kernels and report what actually happened — the honesty record behind
    ``REPRO_KERNEL_COMPILE=1``.

    Tries ``belief_aggregate`` and ``mc_correctness_grouped`` on tiny
    inputs with interpretation forced off, regardless of the env var, and
    captures the per-kernel outcome::

        {"backend": str, "interpret_default": bool,
         "kernels": {name: {"compiled": bool, "error": str}}}

    Known result on this CPU container (documented Mosaic/Triton gap):
    both kernels raise ``ValueError: Only interpret mode is supported on
    CPU backend.`` — Pallas has no CPU lowering path, so native-kernel
    validation requires a real TPU (Mosaic) or GPU (Triton) runtime.
    """
    import jax
    import numpy as np

    K = 2
    out: dict = {
        "backend": jax.default_backend(),
        "interpret_default": _INTERPRET,
        "kernels": {},
    }

    def attempt(name, fn):
        try:
            res = fn()
            jax.block_until_ready(res)
            out["kernels"][name] = {"compiled": True, "error": ""}
        except Exception as exc:
            out["kernels"][name] = {
                "compiled": False, "error": f"{type(exc).__name__}: {exc}"
            }

    attempt(
        "belief_aggregate",
        lambda: belief_aggregate_pallas(
            jnp.zeros((2, 2), jnp.int32),
            jnp.zeros((2, 2), jnp.float32),
            jnp.zeros(2, jnp.float32),
            K, tile=2, interpret=False,
        ),
    )
    attempt(
        "mc_correctness_grouped",
        lambda: mc_correctness_grouped_pallas(
            jnp.zeros((1, 2, 2), jnp.int32),
            jnp.zeros((1, 1, 2), jnp.float32),
            jnp.zeros((1, 2), jnp.float32),
            jnp.zeros(1, jnp.float32),
            jnp.asarray(np.ones((1, 2), np.float32)),
            jnp.ones(1, jnp.float32),
            K, tile=2, interpret=False,
        ),
    )
    return out
