"""Pallas TPU kernel: Mamba-1 selective-state-space scan.

The CUDA original fuses the recurrence in SRAM; the TPU adaptation keeps
the (block_ch, N) state resident in VMEM while streaming the sequence:

    h_t[d, n] = exp(dt_t[d] * A[d, n]) * h_{t-1}[d, n] + dt_t[d] x_t[d] B_t[n]
    y_t[d]    = sum_n h_t[d, n] C_t[n] + D[d] x_t[d]

Grid (B, n_ch, n_s): channels blocked over lanes, sequence streamed in
blocks with the (bc, N) state carried in VMEM scratch; each step is a VPU
outer-product update plus an (bc, N) x (N,) contraction. The op is
bandwidth-bound (state never leaves VMEM; x/dt/B/C stream once), which is
the entire point of fusing it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref, y_ref, hlast_ref,
            h_scr, *, block_s, n_s):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    a = a_ref[...]                                   # (bc, N)
    dskip = d_ref[...]                               # (bc,)

    def step(t, h):
        dt = dt_ref[0, t, :]                         # (bc,)
        x = x_ref[0, t, :]                           # (bc,)
        bv = b_ref[0, t, :]                          # (N,)
        cv = c_ref[0, t, :]                          # (N,)
        decay = jnp.exp(dt[:, None] * a)             # (bc, N)
        h = decay * h + (dt * x)[:, None] * bv[None, :]
        y = jnp.sum(h * cv[None, :], axis=1) + dskip * x
        # dynamic-index store via ref indexing: pl.store rejects plain-int
        # axis indices on this Pallas version, __setitem__ normalizes them
        y_ref[0, pl.dslice(t, 1), :] = y[None]
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h

    @pl.when(si == n_s - 1)
    def _final():
        hlast_ref[0] = h


@functools.partial(jax.jit, static_argnames=("block_ch", "block_s", "interpret"))
def mamba_scan_pallas(
    x: jnp.ndarray,      # (B, S, Din) post-conv activations (fp32)
    dt: jnp.ndarray,     # (B, S, Din) softplus'd step sizes
    A: jnp.ndarray,      # (Din, N) negative
    Bmat: jnp.ndarray,   # (B, S, N)
    Cmat: jnp.ndarray,   # (B, S, N)
    Dskip: jnp.ndarray,  # (Din,)
    h0: jnp.ndarray,     # (B, Din, N)
    block_ch: int = 512,
    block_s: int = 128,
    interpret: bool = True,
):
    """Returns (y (B, S, Din), h_last (B, Din, N))."""
    B, S, Din = x.shape
    N = A.shape[1]
    bc = min(block_ch, Din)
    bs = min(block_s, S)
    assert Din % bc == 0 and S % bs == 0, "pad channels/sequence to block multiples"
    n_ch, n_s = Din // bc, S // bs

    y, h_last = pl.pallas_call(
        functools.partial(_kernel, block_s=bs, n_s=n_s),
        grid=(B, n_ch, n_s),
        in_specs=[
            pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),     # x
            pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),     # dt
            pl.BlockSpec((bc, N), lambda b, c, s: (c, 0)),            # A
            pl.BlockSpec((1, bs, N), lambda b, c, s: (b, s, 0)),      # B
            pl.BlockSpec((1, bs, N), lambda b, c, s: (b, s, 0)),      # C
            pl.BlockSpec((bc,), lambda b, c, s: (c,)),                # D
            pl.BlockSpec((1, bc, N), lambda b, c, s: (b, c, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, bc, N), lambda b, c, s: (b, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Din), jnp.float32),
            jax.ShapeDtypeStruct((B, Din, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bc, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bmat, Cmat, Dskip, h0)
    return y, h_last
