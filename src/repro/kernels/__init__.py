"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), a jit'd wrapper
in ops.py, and a pure-jnp oracle in ref.py; tests sweep shapes/dtypes and
assert allclose against the oracle in interpret mode.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
