"""Pallas TPU kernel: batched belief aggregation for the serving router.

For a batch of requests, combine per-arm responses into per-class log
beliefs (paper Eq. 4) and the argmax prediction:

    beliefs[b, k] = sum_m w[b, m] * onehot(resp[b, m])[k]   (empty -> const)

Grid over request tiles; the (Bt, M, K) one-hot cube lives in VMEM and the
contraction over M is an MXU batched dot. Arms flagged -1 are masked (not
invoked for that request — adaptive early-stopped wavefronts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(resp_ref, w_ref, empty_ref, bel_ref, pred_ref, *, num_classes):
    resp = resp_ref[...]                                    # (Bt, M) int32
    Bt, M = resp.shape
    K = num_classes
    w = w_ref[...]                                          # (Bt, M)
    valid = (resp >= 0).astype(jnp.float32)

    classes = jax.lax.broadcasted_iota(jnp.int32, (Bt, M, K), 2)
    onehot = (resp[:, :, None] == classes).astype(jnp.float32)

    beliefs = jnp.einsum("bm,bmk->bk", w * valid, onehot,
                         preferred_element_type=jnp.float32)
    counts = jnp.einsum("bm,bmk->bk", valid, onehot,
                        preferred_element_type=jnp.float32)
    empty = empty_ref[...]                                  # (Bt, 1) per-row
    beliefs = jnp.where(counts > 0, beliefs, empty)
    bel_ref[...] = beliefs
    pred_ref[...] = jnp.argmax(beliefs, axis=-1).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("num_classes", "tile", "interpret"))
def belief_aggregate_pallas(
    responses: jnp.ndarray,    # (B, M) int32, -1 = not invoked
    log_weights: jnp.ndarray,  # (B, M) or (M,) float32
    empty_belief: jnp.ndarray, # scalar or (B,) per-row empty-class belief
    num_classes: int,
    tile: int = 128,
    interpret: bool = True,
):
    """Returns (log_beliefs (B, K), predictions (B,))."""
    B, M = responses.shape
    w = jnp.asarray(log_weights, jnp.float32)
    if w.ndim == 1:
        w = jnp.broadcast_to(w[None, :], (B, M))
    empty = jnp.asarray(empty_belief, jnp.float32)
    if empty.ndim == 0:
        empty = jnp.broadcast_to(empty, (B,))
    tile = min(tile, B)
    n = (B + tile - 1) // tile
    pad = n * tile - B
    if pad:
        responses = jnp.concatenate(
            [responses, jnp.full((pad, M), -1, jnp.int32)], axis=0
        )
        w = jnp.concatenate([w, jnp.zeros((pad, M), jnp.float32)], axis=0)
        empty = jnp.concatenate([empty, jnp.zeros(pad, jnp.float32)])
    empty = empty[:, None]

    beliefs, preds = pl.pallas_call(
        functools.partial(_kernel, num_classes=num_classes),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((tile, M), lambda i: (i, 0)),
            pl.BlockSpec((tile, M), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, num_classes), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * tile, num_classes), jnp.float32),
            jax.ShapeDtypeStruct((n * tile, 1), jnp.int32),
        ],
        interpret=interpret,
    )(responses, w, empty)
    return beliefs[:B], preds[:B, 0]
