"""Pallas TPU kernel: flash attention (prefill/train hot path).

Grid (B, H, nq, nk): innermost dimension streams KV blocks while the
(block_q, head_dim) accumulator and (block_q,) running max/normalizer live
in VMEM scratch across nk iterations. Causal block skipping: blocks
strictly above the diagonal are not computed (this is where the kernel
beats the masked-full jnp baseline by ~2x on FLOPs — see EXPERIMENTS.md
§Perf). GQA folds the KV-head index into the grid via the index map.

Tiling: block_q x head_dim and block_kv x head_dim tiles are MXU-aligned
(multiples of (8, 128) for fp32); defaults (512, 512, 128) keep the score
tile (512, 512) and both operand tiles within a few MB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, block_q, block_kv, n_kv, causal, window, scale,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv
    # block-level skip: above-diagonal (causal) and out-of-window KV blocks
    # are never computed — the FLOP saving over the masked-full baseline.
    pred = jnp.bool_(True)
    if causal:
        pred &= k_start <= q_start + block_q - 1
    if window > 0:
        pred &= k_start + block_kv - 1 >= q_start - window + 1

    @pl.when(pred)
    def _compute():
        q = q_ref[0, 0] * scale                       # (bq, hd)
        k = k_ref[0, 0]                               # (bkv, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                             # (bq, bkv)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        safe = m_new > NEG_INF / 2
        alpha = jnp.where(safe, jnp.exp(m_prev - jnp.where(safe, m_new, 0.0)), 0.0)
        p = jnp.where(mask, jnp.exp(s - jnp.where(safe, m_new, 0.0)[:, None]), 0.0)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jnp.ndarray,            # (B, S, H, hd)
    k: jnp.ndarray,            # (B, T, G, hd)
    v: jnp.ndarray,            # (B, T, G, hd)
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, H, hd = q.shape
    T, G = k.shape[1], k.shape[2]
    group = H // G
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    assert S % bq == 0 and T % bkv == 0, "pad sequence to block multiples"
    nq, nk = S // bq, T // bkv
    scale = 1.0 / (hd ** 0.5)

    # layout: (B, H, S, hd) blocks; kv head index = h // group
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(
            _kernel, block_q=bq, block_kv=bkv, n_kv=nk,
            causal=causal, window=window, scale=scale,
        ),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
