"""Pallas TPU kernel: RG-LRU diagonal linear recurrence (hybrid-arch hot path).

h_t = exp(log_a_t) * h_{t-1} + u_t, elementwise over the channel dim.

Grid (B, n_ch, n_s): the channel axis is blocked over lanes, the sequence is
streamed in blocks with the (block_ch,) state vector held in VMEM scratch
across sequence blocks; the recurrence inside a block is a fori_loop of
VPU multiply-adds (the op is memory-bound — one load + one store per
element — so the kernel's job is keeping the state resident and the
streams contiguous, not MXU utilization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(la_ref, u_ref, h0_ref, y_ref, hlast_ref, h_scr, *, block_s, n_s):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    def step(t, h):
        h = jnp.exp(la_ref[0, t, :]) * h + u_ref[0, t, :]
        # dynamic-index store via ref indexing: pl.store rejects plain-int
        # axis indices on this Pallas version, __setitem__ normalizes them
        y_ref[0, pl.dslice(t, 1), :] = h[None]
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h

    @pl.when(si == n_s - 1)
    def _final():
        hlast_ref[0] = h


@functools.partial(
    jax.jit, static_argnames=("block_ch", "block_s", "interpret")
)
def rglru_scan_pallas(
    log_a: jnp.ndarray,   # (B, S, D) float32
    gated: jnp.ndarray,   # (B, S, D) float32
    h0: jnp.ndarray,      # (B, D) float32
    block_ch: int = 512,
    block_s: int = 256,
    interpret: bool = True,
):
    """Returns (h (B, S, D), h_last (B, D))."""
    B, S, D = log_a.shape
    bc = min(block_ch, D)
    bs = min(block_s, S)
    assert D % bc == 0 and S % bs == 0, "pad channels/sequence to block multiples"
    n_ch, n_s = D // bc, S // bs

    y, h_last = pl.pallas_call(
        functools.partial(_kernel, block_s=bs, n_s=n_s),
        grid=(B, n_ch, n_s),
        in_specs=[
            pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, bc), lambda b, c, s: (b, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bc), lambda b, c, s: (b, s, c)),
            pl.BlockSpec((1, bc), lambda b, c, s: (b, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        interpret=interpret,
    )(log_a, gated, h0)
    return y, h_last
