"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.experimental import enable_x64

from repro.core.mc import xi_from_responses, xi_from_responses_grouped
from repro.core.belief import aggregate_log_beliefs_batch
from repro.models.attention import blocked_attention, direct_attention


def mc_correctness_ref(responses, masks, log_weights, empty_belief, num_classes):
    """(C,) xi estimates — delegates to the core estimator math."""
    return xi_from_responses(
        responses, masks, log_weights, jnp.float32(empty_belief), num_classes
    )


def mc_correctness_grouped_ref(responses, masks, log_weights, empty_belief,
                               valid, theta, num_classes):
    """(G, C) xi estimates — delegates to the batched planner's bit-stable
    grouped core (f64 out; compare with a float32 tolerance)."""
    with enable_x64():
        vals = xi_from_responses_grouped(
            responses, masks, log_weights, empty_belief, valid,
            jnp.asarray(theta, jnp.float64), num_classes=num_classes,
        )
    return vals.astype(jnp.float32)


def belief_aggregate_ref(responses, log_weights, empty_belief, num_classes):
    """Returns (log_beliefs (B, K), predictions (B,)); ``empty_belief`` may
    be a scalar or a (B,) per-row vector."""
    beliefs = aggregate_log_beliefs_batch(
        responses, log_weights, num_classes, jnp.asarray(empty_belief, jnp.float32)
    )
    return beliefs, jnp.argmax(beliefs, axis=-1).astype(jnp.int32)


def flash_attention_ref(q, k, v, causal=True, window=0):
    """Direct softmax attention in fp32 (no blocking)."""
    return direct_attention(q, k, v, causal=causal, window=window)


def rglru_scan_ref(log_a, gated, h0):
    """Sequential reference for the diagonal recurrence."""

    def step(h, xs):
        la, u = xs
        h = jnp.exp(la) * h + u
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0, (log_a.transpose(1, 0, 2), gated.transpose(1, 0, 2))
    )
    return hs.transpose(1, 0, 2), h_last


def mamba_scan_ref(x, dt, A, Bmat, Cmat, Dskip, h0):
    """Delegates to the model substrate's chunked selective scan."""
    from repro.models.ssm import selective_scan

    y, h_last = selective_scan(x, dt, A, Bmat, Cmat, Dskip, h0=h0, chunk=64)
    return y.astype(jnp.float32), h_last
