"""AST / call-graph core for thriftlint.

Parses every module under ``src/repro``, finds the *traced roots* — code
that executes under a JAX trace rather than as plain Python:

* functions decorated with ``@jax.jit`` (bare, or via ``partial``),
* functions wrapped by a ``jax.jit(fn)`` / ``partial(jax.jit, ...)(fn)``
  call expression (the ``mc.py`` module-level wrapper idiom),
* kernels handed to ``pl.pallas_call``,
* bodies handed to ``lax.scan`` / ``lax.while_loop`` / ``lax.cond`` /
  ``lax.fori_loop`` / ``jax.vmap`` and friends,

and computes the transitive closure of functions reachable from those
roots through ordinary calls, lexical nesting, and cross-module imports.
Rules consume this: "jit-reachable" in a rule means *a member of that
closure*, which is exactly the code where host-side effects, key reuse,
or dtype drift silently break the repro's bit-match contracts.

Everything here is static and name-based.  Dynamic dispatch through
instance attributes (``jax.jit(self.model.forward)``) is out of scope and
deliberately ignored rather than guessed at.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

# HOFs whose function-valued operands execute under a trace.
TRACED_HOFS = {
    "jax.lax.scan",
    "jax.lax.while_loop",
    "jax.lax.fori_loop",
    "jax.lax.cond",
    "jax.lax.switch",
    "jax.lax.map",
    "jax.lax.associative_scan",
    "jax.vmap",
    "jax.pmap",
    "jax.grad",
    "jax.value_and_grad",
    "jax.checkpoint",
    "jax.remat",
}

JIT_NAMES = {"jax.jit"}
PARTIAL_NAMES = {"functools.partial", "partial"}
PALLAS_CALL_NAMES = {
    "jax.experimental.pallas.pallas_call",
    "pallas.pallas_call",
}


@dataclass
class FunctionInfo:
    """One ``def`` (top-level, method, or nested) in the scanned tree."""

    module: str
    path: str
    qualname: str
    node: ast.FunctionDef
    parent: "FunctionInfo | None" = None
    class_name: str = ""
    children: dict[str, "FunctionInfo"] = field(default_factory=dict)

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def key(self) -> tuple[str, str]:
        return (self.path, self.qualname)

    def __hash__(self):
        return hash(self.key)

    def __eq__(self, other):
        return isinstance(other, FunctionInfo) and self.key == other.key


@dataclass
class CallSite:
    """A ``Call`` node plus where it syntactically lives."""

    node: ast.Call
    module: str
    path: str
    enclosing: FunctionInfo | None   # innermost def, None at module scope
    loop_depth: int                  # For/While ancestors inside `enclosing`


@dataclass
class JitEntry:
    """One jit wrapper: the wrapped function plus its static-arg spec."""

    fn: FunctionInfo | None
    static_argnames: tuple[str, ...]
    static_argnums: tuple[int, ...]
    site: CallSite | None            # None for decorator form
    wrapper_name: str = ""           # module-level alias, when assigned


@dataclass
class PallasSite:
    """One ``pl.pallas_call(...)`` call expression."""

    call: CallSite
    kernel: FunctionInfo | None


class _ModuleScanner(ast.NodeVisitor):
    """Single pass over one module: functions, imports, calls, globals."""

    def __init__(self, module: str, path: str):
        self.module = module
        self.path = path
        self.imports: dict[str, str] = {}
        self.functions: dict[str, FunctionInfo] = {}
        self.calls: list[CallSite] = []
        self.top_assign_counts: dict[str, int] = {}
        self.global_decl_stores: set[str] = set()
        self.top_aug_assigns: set[str] = set()
        self._fn_stack: list[FunctionInfo] = []
        self._class_stack: list[str] = []
        self._loop_depth = 0

    # -- imports ----------------------------------------------------------
    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            self.imports[alias.asname or alias.name.split(".")[0]] = (
                alias.name if alias.asname else alias.name.split(".")[0]
            )
            if alias.asname:
                self.imports[alias.asname] = alias.name

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.level:
            parts = self.module.split(".")
            base = ".".join(parts[: len(parts) - node.level])
        else:
            base = ""
        mod = ".".join(p for p in (base, node.module or "") if p)
        for alias in node.names:
            target = f"{mod}.{alias.name}" if mod else alias.name
            self.imports[alias.asname or alias.name] = target

    # -- definitions ------------------------------------------------------
    def _visit_def(self, node):
        prefix = ""
        if self._fn_stack:
            prefix = self._fn_stack[-1].qualname + ".<locals>."
        elif self._class_stack:
            prefix = ".".join(self._class_stack) + "."
        info = FunctionInfo(
            module=self.module,
            path=self.path,
            qualname=prefix + node.name,
            node=node,
            parent=self._fn_stack[-1] if self._fn_stack else None,
            class_name=self._class_stack[-1] if self._class_stack else "",
        )
        self.functions[info.qualname] = info
        if info.parent is not None:
            info.parent.children[node.name] = info
        for dec in node.decorator_list:
            self.visit(dec)
        self._fn_stack.append(info)
        outer_loops, self._loop_depth = self._loop_depth, 0
        for stmt in node.body:
            self.visit(stmt)
        self._loop_depth = outer_loops
        self._fn_stack.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def

    def visit_ClassDef(self, node: ast.ClassDef):
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    # -- calls ------------------------------------------------------------
    def visit_Call(self, node: ast.Call):
        self.calls.append(
            CallSite(
                node=node,
                module=self.module,
                path=self.path,
                enclosing=self._fn_stack[-1] if self._fn_stack else None,
                loop_depth=self._loop_depth,
            )
        )
        self.generic_visit(node)

    def _visit_loop(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_For = _visit_loop
    visit_While = _visit_loop

    # -- module-level state -----------------------------------------------
    def visit_Assign(self, node: ast.Assign):
        if not self._fn_stack and not self._class_stack:
            for tgt in node.targets:
                for name in _target_names(tgt):
                    self.top_assign_counts[name] = (
                        self.top_assign_counts.get(name, 0) + 1
                    )
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        if not self._fn_stack and not self._class_stack:
            for name in _target_names(node.target):
                self.top_aug_assigns.add(name)
        self.generic_visit(node)

    def visit_Global(self, node: ast.Global):
        self.global_decl_stores.update(node.names)


def _target_names(tgt: ast.expr) -> list[str]:
    if isinstance(tgt, ast.Name):
        return [tgt.id]
    if isinstance(tgt, (ast.Tuple, ast.List)):
        out = []
        for elt in tgt.elts:
            out.extend(_target_names(elt))
        return out
    return []


@dataclass
class ModuleInfo:
    name: str
    path: str
    text: str
    tree: ast.Module
    scan: _ModuleScanner


class Project:
    """All parsed modules plus the traced-roots reachability closure."""

    def __init__(
        self,
        src_root: Path,
        package: str = "repro",
        critical_prefixes: tuple[str, ...] | None = None,
    ):
        self.src_root = Path(src_root)
        self.package = package
        # the modules whose traced reductions carry the serial==batched
        # bit-match contract (see docs/analysis.md)
        self.critical_prefixes = critical_prefixes or (
            f"{package}.core",
            f"{package}.serving",
        )
        self.modules: dict[str, ModuleInfo] = {}
        self.jit_entries: list[JitEntry] = []
        self.pallas_sites: list[PallasSite] = []
        self.kernels: set[FunctionInfo] = set()
        self.reachable: set[FunctionInfo] = set()
        self._load()
        self._find_roots()
        self._close_reachability()

    # -- loading ----------------------------------------------------------
    def _load(self):
        pkg_dir = self.src_root / self.package
        for path in sorted(pkg_dir.rglob("*.py")):
            rel = path.relative_to(self.src_root)
            mod = ".".join(rel.with_suffix("").parts)
            if mod.endswith(".__init__"):
                mod = mod[: -len(".__init__")]
            text = path.read_text()
            tree = ast.parse(text, filename=str(path))
            scan = _ModuleScanner(mod, str(rel.as_posix()))
            scan.visit(tree)
            self.modules[mod] = ModuleInfo(
                name=mod, path=str(rel.as_posix()), text=text, tree=tree,
                scan=scan,
            )

    # -- name resolution --------------------------------------------------
    def dotted(self, expr: ast.expr, module: str) -> str | None:
        """Expand an attribute chain to a fully qualified dotted name,
        resolving the leading alias through the module's imports
        (``jnp.sum`` -> ``jax.numpy.sum``)."""
        parts: list[str] = []
        node = expr
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        info = self.modules.get(module)
        head = node.id
        if info is not None and head in info.scan.imports:
            head = info.scan.imports[head]
        parts.append(head)
        return ".".join(reversed(parts))

    def resolve_function(
        self,
        expr: ast.expr,
        module: str,
        enclosing: FunctionInfo | None,
    ) -> FunctionInfo | None:
        """Resolve a function-valued expression to a FunctionInfo, looking
        through lexical scope, the module, sibling ``repro`` modules, and
        ``functools.partial`` wrapping."""
        if isinstance(expr, ast.Call):  # partial(fn, ...)
            fq = self.dotted(expr.func, module)
            if fq in PARTIAL_NAMES and expr.args:
                return self.resolve_function(expr.args[0], module, enclosing)
            return None
        info = self.modules.get(module)
        if info is None:
            return None
        if isinstance(expr, ast.Name):
            cur = enclosing
            while cur is not None:
                if expr.id in cur.children:
                    return cur.children[expr.id]
                cur = cur.parent
            if (
                enclosing is not None
                and enclosing.class_name
                and expr.id in info.scan.functions
            ):
                pass  # fall through to module scope below
            if expr.id in info.scan.functions:
                return info.scan.functions[expr.id]
            target = info.scan.imports.get(expr.id)
            if target:
                return self._lookup_qualified(target)
            return None
        if isinstance(expr, ast.Attribute):
            # self.method() within a class
            if (
                isinstance(expr.value, ast.Name)
                and expr.value.id in ("self", "cls")
                and enclosing is not None
                and enclosing.class_name
            ):
                qual = f"{enclosing.class_name}.{expr.attr}"
                return info.scan.functions.get(qual)
            fq = self.dotted(expr, module)
            if fq:
                return self._lookup_qualified(fq)
        return None

    def _lookup_qualified(self, fq: str) -> FunctionInfo | None:
        """``repro.core.mc.bucket_size`` -> its FunctionInfo, if ours."""
        if not fq.startswith(self.package + ".") and fq != self.package:
            return None
        parts = fq.split(".")
        for split in range(len(parts), 0, -1):
            mod = ".".join(parts[:split])
            if mod in self.modules:
                rest = ".".join(parts[split:])
                if not rest:
                    return None
                return self.modules[mod].scan.functions.get(rest)
        return None

    # -- traced roots -----------------------------------------------------
    def _decorator_jit(self, fn: FunctionInfo) -> JitEntry | None:
        for dec in fn.node.decorator_list:
            fq = self.dotted(dec, fn.module)
            if fq in JIT_NAMES:
                return JitEntry(fn, (), (), None)
            if isinstance(dec, ast.Call):
                cfq = self.dotted(dec.func, fn.module)
                if cfq in JIT_NAMES:
                    return JitEntry(fn, *_static_spec(dec), None)
                if cfq in PARTIAL_NAMES and dec.args:
                    inner = self.dotted(dec.args[0], fn.module)
                    if inner in JIT_NAMES:
                        return JitEntry(fn, *_static_spec(dec), None)
        return None

    def _find_roots(self):
        roots: set[FunctionInfo] = set()
        for mod in self.modules.values():
            for fn in mod.scan.functions.values():
                entry = self._decorator_jit(fn)
                if entry is not None:
                    self.jit_entries.append(entry)
                    roots.add(fn)
            for site in mod.scan.calls:
                node = site.node
                fq = self.dotted(node.func, mod.name)
                # jax.jit(fn, ...) as an expression
                if fq in JIT_NAMES:
                    fn = (
                        self.resolve_function(
                            node.args[0], mod.name, site.enclosing
                        )
                        if node.args
                        else None
                    )
                    entry = JitEntry(fn, *_static_spec(node), site)
                    self.jit_entries.append(entry)
                    if fn is not None:
                        roots.add(fn)
                    continue
                # partial(jax.jit, ...)(fn) — outer call whose func is the
                # partial application
                if isinstance(node.func, ast.Call):
                    pfq = self.dotted(node.func.func, mod.name)
                    if pfq in PARTIAL_NAMES and node.func.args:
                        inner = self.dotted(node.func.args[0], mod.name)
                        if inner in JIT_NAMES:
                            fn = (
                                self.resolve_function(
                                    node.args[0], mod.name, site.enclosing
                                )
                                if node.args
                                else None
                            )
                            entry = JitEntry(
                                fn, *_static_spec(node.func), site
                            )
                            self.jit_entries.append(entry)
                            if fn is not None:
                                roots.add(fn)
                            continue
                if fq in PALLAS_CALL_NAMES or (
                    fq is not None and fq.endswith(".pallas_call")
                ):
                    kern = (
                        self.resolve_function(
                            node.args[0], mod.name, site.enclosing
                        )
                        if node.args
                        else None
                    )
                    self.pallas_sites.append(PallasSite(site, kern))
                    if kern is not None:
                        self.kernels.add(kern)
                        roots.add(kern)
                    continue
                if fq in TRACED_HOFS:
                    for arg in node.args:
                        fn = self.resolve_function(
                            arg, mod.name, site.enclosing
                        )
                        if fn is not None:
                            roots.add(fn)
        self._roots = roots
        # name jit wrappers assigned at module level (mc.py idiom):
        # `_masked = partial(jax.jit, ...)(core)` — find the Assign target
        for mod in self.modules.values():
            for stmt in mod.tree.body:
                if isinstance(stmt, ast.Assign) and isinstance(
                    stmt.value, ast.Call
                ):
                    for entry in self.jit_entries:
                        if (
                            entry.site is not None
                            and entry.site.node is stmt.value
                            and len(stmt.targets) == 1
                            and isinstance(stmt.targets[0], ast.Name)
                        ):
                            entry.wrapper_name = stmt.targets[0].id

    def _close_reachability(self):
        work = list(self._roots)
        seen: set[FunctionInfo] = set(work)
        # call sites indexed by enclosing function for fast lookup
        by_fn: dict[FunctionInfo, list[CallSite]] = {}
        for mod in self.modules.values():
            for site in mod.scan.calls:
                if site.enclosing is not None:
                    by_fn.setdefault(site.enclosing, []).append(site)
        while work:
            fn = work.pop()
            self.reachable.add(fn)
            nxt: list[FunctionInfo] = list(fn.children.values())
            for site in by_fn.get(fn, ()):
                callee = self.resolve_function(
                    site.node.func, site.module, fn
                )
                if callee is not None:
                    nxt.append(callee)
                fq = self.dotted(site.node.func, site.module)
                if fq in TRACED_HOFS:
                    for arg in site.node.args:
                        hof_fn = self.resolve_function(
                            arg, site.module, fn
                        )
                        if hof_fn is not None:
                            nxt.append(hof_fn)
            for callee in nxt:
                if callee not in seen:
                    seen.add(callee)
                    work.append(callee)

    # -- conveniences for rules -------------------------------------------
    def is_reachable(self, fn: FunctionInfo) -> bool:
        return fn in self.reachable

    def iter_reachable(self):
        return sorted(self.reachable, key=lambda f: (f.path, f.qualname))

    def iter_functions(self):
        for mod in self.modules.values():
            yield from mod.scan.functions.values()

    def mutated_globals(self, module: str) -> set[str]:
        """Module-level names that are rebound after their first binding —
        the closure-over-mutable-global hazard for jitted programs."""
        info = self.modules.get(module)
        if info is None:
            return set()
        scan = info.scan
        out = {n for n, c in scan.top_assign_counts.items() if c > 1}
        out |= scan.top_aug_assigns
        out |= scan.global_decl_stores & set(scan.top_assign_counts)
        out |= scan.global_decl_stores
        return out

    def jitted_symbols(self) -> dict[str, JitEntry]:
        """Callable names (function or wrapper alias) that hit XLA."""
        out: dict[str, JitEntry] = {}
        for entry in self.jit_entries:
            if entry.fn is not None and "." not in entry.fn.qualname:
                out[entry.fn.name] = entry
            if entry.wrapper_name:
                out[entry.wrapper_name] = entry
        return out


def _static_spec(call: ast.Call) -> tuple[tuple[str, ...], tuple[int, ...]]:
    names: tuple[str, ...] = ()
    nums: tuple[int, ...] = ()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names = tuple(_const_strs(kw.value))
        elif kw.arg == "static_argnums":
            nums = tuple(_const_ints(kw.value))
    return names, nums


def _const_strs(node: ast.expr) -> list[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return out
    return []


def _const_ints(node: ast.expr) -> list[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
        return out
    return []
