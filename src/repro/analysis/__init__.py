"""thriftlint: static analysis + runtime sentinels for the repro's
jit/determinism contracts.

Static half: an AST/call-graph walker (`walker.Project`) resolves the
code reachable from every jit / lax-control-flow / pallas entry point,
and five rule passes enforce the invariants the equivalence tests rely
on (purity under trace, single-use PRNG keys, explicit f64 accumulation,
bounded compile buckets, pallas store/grid/interpret contracts).

Runtime half: `CompileSentinel` counts real XLA compilations per entry
point so tests assert bucket budgets, and the tracer-leak guard runs the
tier-1 suite under `jax.check_tracer_leaks`.

CLI: ``python scripts/lint.py`` — see docs/analysis.md.
"""
from .findings import BAD_SUPPRESSION, Finding, Suppression
from .linter import Linter, LintReport, run_lint
from .rules import ALL_RULES
from .sentinel import (
    CompileSentinel,
    compile_cache_size,
    install_tracer_guard,
    tracer_guard_enabled,
    tracer_leak_guard,
)
from .walker import Project

__all__ = [
    "ALL_RULES",
    "BAD_SUPPRESSION",
    "CompileSentinel",
    "Finding",
    "LintReport",
    "Linter",
    "Project",
    "Suppression",
    "compile_cache_size",
    "install_tracer_guard",
    "run_lint",
    "tracer_guard_enabled",
    "tracer_leak_guard",
]
