"""Finding model and the inline-suppression grammar for thriftlint.

A finding is one violation of one rule at one source location.  The only
sanctioned way to silence a true-but-intentional finding is an inline
comment on the flagged line:

    # thriftlint: ignore[rule-name] why this is safe here

The reason text is mandatory — a bare ``ignore[rule]`` is itself reported
as a ``bad-suppression`` finding, and ``bad-suppression`` cannot be
suppressed.  There is no file- or config-level allowlist on purpose: every
exemption must sit next to the code it exempts, with its justification,
where the next editor will see both.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field


# matches `<tool>: ignore[rule-a,rule-b] reason text` comments, where the
# tool name is spelled out to avoid this very pattern self-matching docs
_SUPPRESS_RE = re.compile(
    r"#\s*thriftlint:\s*ignore\[(?P<rules>[a-z0-9,\-\s]*)\]\s*(?P<reason>.*)$"
)

# Rule id for malformed suppressions; not suppressible by design.
BAD_SUPPRESSION = "bad-suppression"


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str            # repo-relative path
    line: int            # 1-indexed, matches the suppression comment line
    message: str
    symbol: str = ""     # qualified function name when known

    def format(self) -> str:
        where = f"{self.path}:{self.line}"
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{where}: {self.rule}{sym}: {self.message}"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "symbol": self.symbol,
            "message": self.message,
        }


@dataclass
class Suppression:
    """A parsed ``# thriftlint: ignore[...]`` comment."""

    path: str
    line: int
    rules: tuple[str, ...]
    reason: str
    used_by: list[Finding] = field(default_factory=list)

    @property
    def has_reason(self) -> bool:
        return bool(self.reason.strip())

    def covers(self, finding: Finding) -> bool:
        if finding.rule == BAD_SUPPRESSION:
            return False
        if finding.path != self.path or finding.line != self.line:
            return False
        return finding.rule in self.rules or "*" in self.rules


def parse_suppressions(path: str, text: str) -> list[Suppression]:
    """Extract every suppression comment in ``text`` (one per line max).

    Real COMMENT tokens only — the same spelling inside a docstring or
    string literal (e.g. the examples in this module) is not a
    suppression.
    """
    out: list[Suppression] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return out
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if m is None:
            continue
        lineno = tok.start[0]
        rules = tuple(
            r.strip() for r in m.group("rules").split(",") if r.strip()
        )
        out.append(
            Suppression(
                path=path,
                line=lineno,
                rules=rules,
                reason=m.group("reason").strip(),
            )
        )
    return out


def apply_suppressions(
    findings: list[Finding], suppressions: list[Suppression]
) -> tuple[list[Finding], list[Finding]]:
    """Split ``findings`` into (surviving, suppressed).

    Malformed suppressions (no rule list, or no reason) are appended to the
    surviving list as ``bad-suppression`` findings — a silencing comment
    that does not say *why* is itself a contract violation.
    """
    by_loc: dict[tuple[str, int], list[Suppression]] = {}
    for s in suppressions:
        by_loc.setdefault((s.path, s.line), []).append(s)

    surviving: list[Finding] = []
    suppressed: list[Finding] = []
    for f in findings:
        hit = None
        for s in by_loc.get((f.path, f.line), ()):
            if s.covers(f) and s.has_reason:
                hit = s
                break
        if hit is not None:
            hit.used_by.append(f)
            suppressed.append(f)
        else:
            surviving.append(f)

    for s in suppressions:
        if not s.rules:
            surviving.append(
                Finding(
                    rule=BAD_SUPPRESSION,
                    path=s.path,
                    line=s.line,
                    message="suppression lists no rules: use "
                    "`# thriftlint: ignore[rule] reason`",
                )
            )
        elif not s.has_reason:
            surviving.append(
                Finding(
                    rule=BAD_SUPPRESSION,
                    path=s.path,
                    line=s.line,
                    message=f"suppression of {list(s.rules)} gives no "
                    "reason — the justification is mandatory",
                )
            )
    surviving.sort(key=lambda f: (f.path, f.line, f.rule))
    return surviving, suppressed
