"""thriftlint orchestration: walk → rules → suppressions → report."""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path

from .findings import (
    Finding,
    Suppression,
    apply_suppressions,
    parse_suppressions,
)
from .rules import ALL_RULES
from .walker import Project


@dataclass
class LintReport:
    findings: list[Finding]            # surviving (incl. bad-suppression)
    suppressed: list[Finding]          # silenced by a reasoned inline comment
    suppressions: list[Suppression]
    rules_run: tuple[str, ...]
    files_scanned: int

    @property
    def ok(self) -> bool:
        return not self.findings

    def by_rule(self) -> dict[str, list[Finding]]:
        out: dict[str, list[Finding]] = {}
        for f in self.findings:
            out.setdefault(f.rule, []).append(f)
        return out

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "rules": list(self.rules_run),
            "files_scanned": self.files_scanned,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
        }


@dataclass
class Linter:
    src_root: Path = Path("src")
    package: str = "repro"
    rules: tuple[str, ...] = ()
    critical_prefixes: tuple[str, ...] | None = None
    _project: Project | None = field(default=None, repr=False)

    @property
    def project(self) -> Project:
        if self._project is None:
            self._project = Project(
                self.src_root,
                self.package,
                critical_prefixes=self.critical_prefixes,
            )
        return self._project

    def run(self) -> LintReport:
        project = self.project
        names = self.rules or tuple(ALL_RULES)
        unknown = [n for n in names if n not in ALL_RULES]
        if unknown:
            raise ValueError(
                f"unknown rule(s) {unknown}; known: {sorted(ALL_RULES)}"
            )
        raw: list[Finding] = []
        for name in names:
            raw.extend(ALL_RULES[name](project))

        suppressions: list[Suppression] = []
        for mod in project.modules.values():
            suppressions.extend(parse_suppressions(mod.path, mod.text))
        surviving, suppressed = apply_suppressions(raw, suppressions)
        return LintReport(
            findings=surviving,
            suppressed=suppressed,
            suppressions=suppressions,
            rules_run=names,
            files_scanned=len(project.modules),
        )


def run_lint(
    src_root: str | Path = "src",
    package: str = "repro",
    rules: tuple[str, ...] = (),
    critical_prefixes: tuple[str, ...] | None = None,
) -> LintReport:
    return Linter(
        Path(src_root), package, tuple(rules), critical_prefixes
    ).run()
