"""pallas-contract: kernel invocation invariants.

Three checks, each tied to a bug class this repo has actually hit or
designed around:

* **store indexing** — a traced scalar used directly as a store index
  (``o_ref[t] = v``) silently lowers to the wrong op on some backends
  (the PR 2 ``pl.store`` integer-indexing bug); dynamic store positions
  must go through ``pl.dslice``/``pl.ds``.  Loads are exempt: only the
  store path miscompiled.
* **grid/BlockSpec agreement** — every ``BlockSpec`` index_map must take
  exactly one argument per grid axis (default-valued extras are allowed,
  the ``flash_attention`` closure idiom).  A mismatch is a runtime error
  only on the first *compiled* run, which CPU-interpret CI never takes.
* **interpret plumbing** — every ``pl.pallas_call`` must thread an
  ``interpret=`` flag from a parameter or module switch; omitting it (or
  hard-coding a bool) strands the kernel on one backend and breaks the
  ``REPRO_KERNEL_COMPILE`` toggle.
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..walker import Project

RULE = "pallas-contract"

_DSLICE_NAMES = {"dslice", "ds"}


def _index_elements(sl: ast.expr) -> list[ast.expr]:
    if isinstance(sl, ast.Tuple):
        return list(sl.elts)
    return [sl]


def _store_index_ok(elt: ast.expr) -> bool:
    if isinstance(elt, ast.Constant):  # literal int, Ellipsis, None
        return True
    if isinstance(elt, ast.Slice):
        return True
    if isinstance(elt, ast.UnaryOp) and isinstance(elt.operand, ast.Constant):
        return True
    if isinstance(elt, ast.Call):
        f = elt.func
        name = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else ""
        )
        return name in _DSLICE_NAMES
    return False


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []

    # -- store indexing inside kernels ------------------------------------
    for kern in sorted(project.kernels, key=lambda f: (f.path, f.qualname)):
        for node in ast.walk(kern.node):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            for tgt in targets:
                if not (
                    isinstance(tgt, ast.Subscript)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id.endswith("_ref")
                ):
                    continue
                for elt in _index_elements(tgt.slice):
                    if not _store_index_ok(elt):
                        findings.append(
                            Finding(
                                rule=RULE,
                                path=kern.path,
                                line=tgt.lineno,
                                symbol=kern.qualname,
                                message=f"store into `{tgt.value.id}` "
                                "indexes with a traced scalar: wrap "
                                "dynamic store positions in "
                                "pl.dslice(i, 1) (PR 2 store bug class)",
                            )
                        )
                        break

    # -- pallas_call site checks ------------------------------------------
    for site in project.pallas_sites:
        call = site.call.node
        where = site.call
        sym = where.enclosing.qualname if where.enclosing else "<module>"

        kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}
        interp = kwargs.get("interpret")
        if interp is None:
            findings.append(
                Finding(
                    rule=RULE,
                    path=where.path,
                    line=call.lineno,
                    symbol=sym,
                    message="pallas_call without interpret= plumbing: "
                    "thread the interpret flag from the wrapper/module "
                    "switch so CPU CI and compiled runs share one path",
                )
            )
        elif isinstance(interp, ast.Constant) and isinstance(
            interp.value, bool
        ):
            findings.append(
                Finding(
                    rule=RULE,
                    path=where.path,
                    line=interp.lineno,
                    symbol=sym,
                    message=f"pallas_call hard-codes interpret="
                    f"{interp.value}: the REPRO_KERNEL_COMPILE toggle "
                    "cannot reach this kernel",
                )
            )

        grid = kwargs.get("grid")
        grid_len = None
        if isinstance(grid, ast.Tuple):
            grid_len = len(grid.elts)
        elif isinstance(grid, ast.Constant) and isinstance(grid.value, int):
            grid_len = 1
        if grid_len is None:
            continue
        for spec_kw in ("in_specs", "out_specs", "out_spec"):
            spec = kwargs.get(spec_kw)
            if spec is None:
                continue
            for sub in ast.walk(spec):
                if not isinstance(sub, ast.Call):
                    continue
                fname = (
                    sub.func.attr
                    if isinstance(sub.func, ast.Attribute)
                    else (sub.func.id if isinstance(sub.func, ast.Name) else "")
                )
                if fname != "BlockSpec":
                    continue
                for arg in list(sub.args) + [k.value for k in sub.keywords]:
                    if isinstance(arg, ast.Lambda):
                        a = arg.args
                        required = len(a.posonlyargs) + len(a.args) - len(
                            a.defaults
                        )
                        if required != grid_len:
                            findings.append(
                                Finding(
                                    rule=RULE,
                                    path=where.path,
                                    line=arg.lineno,
                                    symbol=sym,
                                    message=f"BlockSpec index_map takes "
                                    f"{required} grid args but the grid "
                                    f"has {grid_len} axes: the mismatch "
                                    "only errors on the first compiled "
                                    "(non-interpret) run",
                                )
                            )
    return findings
