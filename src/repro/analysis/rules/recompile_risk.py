"""recompile-risk: patterns that churn or poison the XLA compile cache.

The serving plane keeps latency flat by confining every jitted program to
a small set of shape buckets (`bucket_size` / `_bucket`).  Three static
patterns defeat that:

* a jitted function closing over a module global that is *rebound* later
  — the staged constant goes stale (the program keeps the old value) or,
  with static args, silently splits the cache;
* constructing a jit wrapper per call (inside a function or loop) — every
  wrapper owns a fresh cache, so nothing is ever warm;
* feeding ``static_argnames``/``static_argnums`` an unhashable literal
  (TypeError at call time) or a raw ``len(...)``/``.shape`` scalar that
  bypasses the bucket quantisation — one compile per distinct length;
* an explicit device transfer (``jax.device_put`` / ``jax.device_get``)
  inside jit-reachable code — under trace it stages a cross-device copy
  into the compiled program (or poisons the cache with per-device
  committed-array shardings when the pinned device varies per call).
  Transfers belong at the dispatch seam, host-side, *before* the jitted
  entry (`PendingRoute._dispatch_jit` is the sanctioned spot: it pins the
  padded wave tables to a worker's device and then calls the jit).
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..walker import Project
from .base import free_loads

RULE = "recompile-risk"

_UNHASHABLE = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
               ast.SetComp)


def _mentions_raw_length(node: ast.expr) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name):
            if sub.func.id == "len":
                return True
        if isinstance(sub, ast.Attribute) and sub.attr == "shape":
            return True
    return False


def _mentions_bucket(node: ast.expr) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            fname = ""
            if isinstance(sub.func, ast.Name):
                fname = sub.func.id
            elif isinstance(sub.func, ast.Attribute):
                fname = sub.func.attr
            if "bucket" in fname:
                return True
    return False


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []

    # 1. jit entries closing over rebound module globals
    for entry in project.jit_entries:
        fn = entry.fn
        if fn is None:
            continue
        mutated = project.mutated_globals(fn.module)
        if not mutated:
            continue
        hits = sorted(free_loads(fn) & mutated)
        for name in hits:
            findings.append(
                Finding(
                    rule=RULE,
                    path=fn.path,
                    line=fn.node.lineno,
                    symbol=fn.qualname,
                    message=f"jitted function closes over module global "
                    f"`{name}` that is rebound elsewhere: the compiled "
                    "program stages the old value — pass it as an "
                    "argument instead",
                )
            )

    # 2. jit wrappers constructed per call / per loop iteration
    for entry in project.jit_entries:
        site = entry.site
        if site is None or site.enclosing is None:
            continue
        if site.loop_depth > 0:
            findings.append(
                Finding(
                    rule=RULE,
                    path=site.path,
                    line=site.node.lineno,
                    symbol=site.enclosing.qualname,
                    message="jax.jit wrapper constructed inside a loop: "
                    "each wrapper owns a fresh compile cache, so every "
                    "iteration recompiles — hoist the wrapper out",
                )
            )
            continue
        if _assigned_to_self_attr(site.enclosing.node, site.node):
            continue  # engine idiom: one wrapper per instance, cached
        findings.append(
            Finding(
                rule=RULE,
                path=site.path,
                line=site.node.lineno,
                symbol=site.enclosing.qualname,
                message="jax.jit wrapper constructed per call: hoist it "
                "to module scope or cache it on the instance "
                "(`self._fn = jax.jit(...)`)",
            )
        )

    # 3. static-arg hazards at call sites of known jitted symbols
    jitted = project.jitted_symbols()
    for mod in project.modules.values():
        for site in mod.scan.calls:
            name = None
            f = site.node.func
            if isinstance(f, ast.Name):
                name = f.id
            elif isinstance(f, ast.Attribute):
                name = f.attr
            entry = jitted.get(name or "")
            if entry is None:
                continue
            static_exprs: list[tuple[str, ast.expr]] = []
            for kw in site.node.keywords:
                if kw.arg in entry.static_argnames:
                    static_exprs.append((kw.arg, kw.value))
            for idx in entry.static_argnums:
                if idx < len(site.node.args):
                    static_exprs.append((f"argnum {idx}", site.node.args[idx]))
            if entry.fn is not None and entry.static_argnames:
                # positional args matched against the wrapped signature
                params = [
                    p.arg
                    for p in entry.fn.node.args.posonlyargs
                    + entry.fn.node.args.args
                ]
                for i, arg in enumerate(site.node.args):
                    if i < len(params) and params[i] in entry.static_argnames:
                        static_exprs.append((params[i], arg))
            for label, expr in static_exprs:
                if isinstance(expr, _UNHASHABLE):
                    findings.append(
                        Finding(
                            rule=RULE,
                            path=site.path,
                            line=expr.lineno,
                            symbol=site.enclosing.qualname
                            if site.enclosing
                            else "<module>",
                            message=f"unhashable literal for static "
                            f"argument `{label}` of `{name}`: static "
                            "args must hash — use a tuple",
                        )
                    )
                elif _mentions_raw_length(expr) and not _mentions_bucket(expr):
                    findings.append(
                        Finding(
                            rule=RULE,
                            path=site.path,
                            line=expr.lineno,
                            symbol=site.enclosing.qualname
                            if site.enclosing
                            else "<module>",
                            message=f"static argument `{label}` of "
                            f"`{name}` derives from a raw length/shape: "
                            "one compile per distinct value — quantise "
                            "through bucket_size()/_bucket() first",
                        )
                    )

    # 4. explicit device transfers inside traced (jit-reachable) code
    for mod in project.modules.values():
        for site in mod.scan.calls:
            fn = site.enclosing
            if fn is None or not project.is_reachable(fn):
                continue
            name = project.dotted(site.node.func, site.module)
            if name not in ("jax.device_put", "jax.device_get"):
                continue
            findings.append(
                Finding(
                    rule=RULE,
                    path=site.path,
                    line=site.node.lineno,
                    symbol=fn.qualname,
                    message=f"`{name.split('.')[-1]}` inside jit-reachable "
                    "code: under trace this stages an implicit cross-device "
                    "transfer into the compiled program (and a varying "
                    "pinned device splits the compile cache per device) — "
                    "move the transfer host-side to the dispatch seam, "
                    "before the jitted entry",
                )
            )
    return findings


def _assigned_to_self_attr(scope: ast.AST, call: ast.Call) -> bool:
    for node in ast.walk(scope):
        if isinstance(node, ast.Assign) and node.value is call:
            for tgt in node.targets:
                if (
                    isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"
                ):
                    return True
    return False
