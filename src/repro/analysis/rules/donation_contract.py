"""donation-contract: callers of donating jit wrappers must not keep the
donated buffers alive.

``donate_argnums`` hands a buffer's storage to XLA: after the call the
input array is deleted (device inputs) and any host-side re-read of a
donated *device* array raises ``RuntimeError: Array has been deleted``.
The contract is caller-side and purely conventional — nothing in jax
checks it statically — so this rule does:

* a call site of a donating wrapper whose caller *re-reads* a donated
  argument after the call (same enclosing function, no intervening
  re-assignment) is one refactor away from a runtime crash;
* donating an argument that aliases a *cached* buffer — a module-level
  table, an ``self.<attr>`` instance cache, or a subscript of a
  module-level container — donates storage the caller does not own for
  this call; the next caller reads a deleted array.

Passing throwaway locals (the ``_dispatch_jit`` / ``sur_greedy_many``
idiom: staged numpy tables that die at the call) is the sanctioned
pattern and never fires.  Calls routed through a local alias
(``scan_fn = _wave_scan if ... else ...``) are not resolved — the rule
only matches direct calls by wrapper or decorated-function name.
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..walker import JitEntry, Project

RULE = "donation-contract"


def _donate_spec(entry: JitEntry) -> tuple[int, ...]:
    """Donated positional indices declared on a jit entry, () if none."""
    kw_nodes: list[ast.keyword] = []
    if entry.site is not None:
        node = entry.site.node
        if isinstance(node.func, ast.Call):      # partial(jax.jit, ...)(fn)
            kw_nodes = node.func.keywords
        else:                                    # jax.jit(fn, ...)
            kw_nodes = node.keywords
    elif entry.fn is not None:                   # decorator form
        for dec in entry.fn.node.decorator_list:
            if isinstance(dec, ast.Call):
                kw_nodes = dec.keywords
                break
    for kw in kw_nodes:
        if kw.arg != "donate_argnums":
            continue
        try:
            val = ast.literal_eval(kw.value)
        except (ValueError, SyntaxError):
            return ()
        if isinstance(val, int):
            return (val,)
        if isinstance(val, (tuple, list)):
            return tuple(int(v) for v in val)
    return ()


def _donating_symbols(project: Project) -> dict[str, tuple[JitEntry, tuple[int, ...]]]:
    """Callable names whose direct calls donate: wrapper aliases for the
    assignment idiom, the function's own name for the decorator form.
    The bare core-function name of a wrapper idiom is *not* donating —
    calling the core directly bypasses the jit and its donation."""
    out: dict[str, tuple[JitEntry, tuple[int, ...]]] = {}
    for entry in project.jit_entries:
        spec = _donate_spec(entry)
        if not spec:
            continue
        if entry.wrapper_name:
            out[entry.wrapper_name] = (entry, spec)
        elif entry.site is None and entry.fn is not None:
            out[entry.fn.name] = (entry, spec)
    return out


def _is_cached_buffer(arg: ast.expr, module_globals: set[str]) -> str | None:
    """Human-readable description if `arg` aliases storage that outlives
    the call; None for throwaway locals / fresh expressions."""
    if isinstance(arg, ast.Name) and arg.id in module_globals:
        return f"module-level buffer `{arg.id}`"
    if (
        isinstance(arg, ast.Attribute)
        and isinstance(arg.value, ast.Name)
        and arg.value.id in ("self", "cls")
    ):
        return f"instance-cached buffer `self.{arg.attr}`"
    if isinstance(arg, ast.Subscript):
        base = arg.value
        if isinstance(base, ast.Name) and base.id in module_globals:
            return f"entry of module-level container `{base.id}`"
    return None


def _reread_line(
    scope: ast.AST, name: str, after_line: int
) -> int | None:
    """First Load of `name` in `scope` strictly after `after_line` that is
    not preceded by a re-assignment (Store) of the same name."""
    first_store = None
    loads: list[int] = []
    for node in ast.walk(scope):
        if isinstance(node, ast.Name) and node.id == name:
            if node.lineno <= after_line:
                continue
            if isinstance(node.ctx, ast.Store):
                if first_store is None or node.lineno < first_store:
                    first_store = node.lineno
            elif isinstance(node.ctx, ast.Load):
                loads.append(node.lineno)
    for line in sorted(loads):
        if first_store is None or line < first_store:
            return line
    return None


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    donating = _donating_symbols(project)
    if not donating:
        return findings

    for mod in project.modules.values():
        module_globals = set(mod.scan.top_assign_counts)
        for site in mod.scan.calls:
            f = site.node.func
            name = None
            if isinstance(f, ast.Name):
                name = f.id
            elif isinstance(f, ast.Attribute):
                name = f.attr
            hit = donating.get(name or "")
            if hit is None:
                continue
            _entry, spec = hit
            caller = site.enclosing.qualname if site.enclosing else "<module>"
            for idx in spec:
                if idx >= len(site.node.args):
                    continue
                arg = site.node.args[idx]
                cached = _is_cached_buffer(arg, module_globals)
                if cached is not None:
                    findings.append(
                        Finding(
                            rule=RULE,
                            path=site.path,
                            line=site.node.lineno,
                            symbol=caller,
                            message=f"`{name}` donates {cached} "
                            f"(argnum {idx}): donation hands its storage "
                            "to XLA, so the cached alias is deleted for "
                            "every later reader — stage a throwaway copy "
                            "at the call instead",
                        )
                    )
                    continue
                if not isinstance(arg, ast.Name) or site.enclosing is None:
                    continue
                boundary = site.node.end_lineno or site.node.lineno
                reread = _reread_line(
                    site.enclosing.node, arg.id, boundary
                )
                if reread is not None:
                    findings.append(
                        Finding(
                            rule=RULE,
                            path=site.path,
                            line=site.node.lineno,
                            symbol=caller,
                            message=f"`{arg.id}` is donated to `{name}` "
                            f"(argnum {idx}) but re-read on line "
                            f"{reread}: a donated device array is "
                            "deleted by the call — re-reading it raises "
                            "at runtime; copy it first or drop the "
                            "donation",
                        )
                    )
    return findings
