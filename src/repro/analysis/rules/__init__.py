"""Rule registry for thriftlint.

Every rule module exposes ``RULE`` (the id used in CLI ``--rule`` filters
and ``# thriftlint: ignore[...]`` comments) and ``check(project)``.
"""
from . import (
    donation_contract,
    f64_reduction,
    jit_purity,
    pallas_contract,
    prng_discipline,
    recompile_risk,
)

ALL_RULES = {
    mod.RULE: mod.check
    for mod in (
        jit_purity,
        prng_discipline,
        f64_reduction,
        recompile_risk,
        pallas_contract,
        donation_contract,
    )
}

__all__ = ["ALL_RULES"]
