"""Shared helpers for thriftlint rule passes.

Each rule module exposes ``RULE`` (its id) and ``check(project) ->
list[Finding]``.  Rules never parse source themselves — they consume the
:class:`~repro.analysis.walker.Project` call-graph and report locations
through :class:`~repro.analysis.findings.Finding`.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..walker import CallSite, FunctionInfo, Project


def body_walk(fn: FunctionInfo) -> Iterator[ast.AST]:
    """Walk a function's own statements, *excluding* nested ``def``s —
    nested functions are separate nodes in the call graph and are
    analysed on their own (they would double-report otherwise)."""
    stack: list[ast.AST] = list(fn.node.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def calls_by_function(project: Project) -> dict[FunctionInfo, list[CallSite]]:
    out: dict[FunctionInfo, list[CallSite]] = {}
    for mod in project.modules.values():
        for site in mod.scan.calls:
            if site.enclosing is not None:
                out.setdefault(site.enclosing, []).append(site)
    return out


def param_names(fn: FunctionInfo) -> set[str]:
    a = fn.node.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def local_stores(fn: FunctionInfo) -> set[str]:
    """Names bound inside the function body (assignments, loops, withs)."""
    out: set[str] = set()
    for node in body_walk(fn):
        if isinstance(node, ast.Name) and isinstance(
            node.ctx, (ast.Store, ast.Del)
        ):
            out.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.add(node.name)
    return out


def free_loads(fn: FunctionInfo) -> set[str]:
    """Names read in the function that it neither binds nor receives."""
    bound = param_names(fn) | local_stores(fn)
    loads: set[str] = set()
    for node in ast.walk(fn.node):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            loads.add(node.id)
    return loads - bound


def in_critical_module(project: Project, fn: FunctionInfo) -> bool:
    """Does this function live in the bit-stability-critical plane?"""
    return fn.module.startswith(tuple(project.critical_prefixes))
