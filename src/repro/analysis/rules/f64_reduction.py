"""f64-reduction: determinism-critical reductions must be explicit.

The serial==batched bit-match contract (belief tables, `sur_greedy`
marginal gains, wave-program vote prefixes) holds because every
accumulation on that plane is either (a) an explicit ``dtype=jnp.float64``
fixed-order sum or (b) provably exact in float32 (integer-valued sums
below 2**24, boolean counts).  An unannotated ``jnp.sum``/``einsum`` in a
jit-reachable function of ``repro.core`` / ``repro.serving`` silently
inherits input dtype and XLA's reduction-tree order, which is exactly how
batched and serial plans drift apart in the last bit.

Exact-by-construction operands (comparisons, integer ``astype``) are
skipped; anything else must name its accumulator dtype or carry an inline
suppression explaining why float32 is intended.

Also flagged: accumulation driven by *set* iteration — Python set order
is hash-seed-dependent, so a ``for x in {...}: acc += ...`` loop computes
a different floating-point sum per process.
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..walker import Project
from .base import body_walk, in_critical_module

RULE = "f64-reduction"

_REDUCERS = {
    "sum", "mean", "einsum", "dot", "matmul", "prod", "cumsum",
    "tensordot", "average", "vdot", "inner", "nansum", "nanmean",
}
_JNP_PREFIXES = ("jax.numpy.", "jax.nn.")
_EXPLICIT_KWARGS = {"dtype", "preferred_element_type"}
_EXACT_DTYPES = ("int", "bool", "uint")


def _reducer_name(project: Project, call: ast.Call, module: str) -> str | None:
    dotted = project.dotted(call.func, module)
    if dotted is not None:
        for prefix in _JNP_PREFIXES:
            if dotted.startswith(prefix) and dotted[len(prefix):] in _REDUCERS:
                return dotted[len(prefix):]
    # method form: x.sum(...) — only on the reducer names themselves
    if (
        isinstance(call.func, ast.Attribute)
        and call.func.attr in {"sum", "mean", "dot", "prod", "cumsum"}
    ):
        return call.func.attr
    return None


def _is_exact(node: ast.expr, project: Project, module: str) -> bool:
    """Operand is exactly representable: bool comparison or integer cast."""
    if isinstance(node, ast.Compare):
        return True
    if isinstance(node, ast.Call):
        dotted = project.dotted(node.func, module) or ""
        if isinstance(node.func, ast.Attribute) and node.func.attr == "astype":
            for arg in node.args:
                ad = project.dotted(arg, module) or ""
                if any(t in ad for t in _EXACT_DTYPES):
                    return True
        if dotted.endswith(".asarray") or dotted.endswith(".where"):
            return any(_is_exact(a, project, module) for a in node.args)
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.Mult, ast.BitAnd, ast.BitOr)
    ):
        return _is_exact(node.left, project, module) and _is_exact(
            node.right, project, module
        )
    return False


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for fn in project.iter_reachable():
        if not in_critical_module(project, fn):
            continue
        for node in body_walk(fn):
            if isinstance(node, ast.Call):
                red = _reducer_name(project, node, fn.module)
                if red is None:
                    continue
                if any(
                    kw.arg in _EXPLICIT_KWARGS for kw in node.keywords
                ):
                    continue
                operands = [
                    a
                    for a in node.args
                    if not (
                        isinstance(a, ast.Constant)
                        and isinstance(a.value, str)
                    )  # einsum subscript spec
                ]
                if isinstance(node.func, ast.Attribute) and not operands:
                    operands = [node.func.value]
                if operands and all(
                    _is_exact(a, project, fn.module) for a in operands
                ):
                    continue
                findings.append(
                    Finding(
                        rule=RULE,
                        path=fn.path,
                        line=node.lineno,
                        symbol=fn.qualname,
                        message=f"`{red}` without explicit accumulator "
                        "dtype on the bit-stability-critical plane: pass "
                        "dtype=jnp.float64 (or suppress with the reason "
                        "float32 is exact here)",
                    )
                )
            elif isinstance(node, ast.For):
                it = node.iter
                is_set = isinstance(it, ast.Set) or (
                    isinstance(it, ast.Call)
                    and (project.dotted(it.func, fn.module) or "")
                    in ("set", "frozenset")
                )
                if is_set and any(
                    isinstance(child, ast.AugAssign)
                    for stmt in node.body
                    for child in ast.walk(stmt)
                ):
                    findings.append(
                        Finding(
                            rule=RULE,
                            path=fn.path,
                            line=node.lineno,
                            symbol=fn.qualname,
                            message="accumulation over set iteration: "
                            "set order is hash-seed-dependent, so the "
                            "float sum differs across processes — "
                            "iterate a sorted sequence",
                        )
                    )
    return findings
