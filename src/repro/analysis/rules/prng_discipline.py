"""prng-discipline: every PRNG key is consumed at most once.

The CRN (common-random-numbers) contract that makes batched plans bitwise
equal to serial plans hinges on key flow: a key is *derived* any number
of times (``split`` / ``fold_in`` — that is how ``_draw_rows`` gets its
prefix-stable per-row streams) but *sampled from* at most once.  Two
samplers fed the same key return correlated draws; a key that is both
sampled and split seeds two streams that silently share bits.  Both bugs
pass every shape check and corrupt xi estimates only statistically, which
is why they get a static rule instead of a test.
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..walker import FunctionInfo, Project
from .base import calls_by_function, param_names

RULE = "prng-discipline"

_CONSTRUCTORS = {"key", "PRNGKey", "wrap_key_data"}
_DERIVERS = {"split", "fold_in", "clone"}
_NON_SAMPLERS = _CONSTRUCTORS | _DERIVERS | {"key_data", "key_impl"}


def _jax_random_member(dotted: str | None) -> str | None:
    if dotted and dotted.startswith("jax.random."):
        return dotted.split(".")[-1]
    return None


def _key_param_names(fn: FunctionInfo) -> set[str]:
    return {
        p
        for p in param_names(fn)
        if p == "key" or p == "rng" or p.endswith("_key") or p == "keys"
    }


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    by_fn = calls_by_function(project)
    for fn in sorted(by_fn, key=lambda f: (f.path, f.qualname)):
        sites = by_fn[fn]
        key_vars = _key_param_names(fn)
        # vars assigned from key constructors / derivers are keys too
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Assign) and isinstance(
                node.value, ast.Call
            ):
                member = _jax_random_member(
                    project.dotted(node.value.func, fn.module)
                )
                if member in _CONSTRUCTORS | _DERIVERS:
                    for tgt in node.targets:
                        elts = (
                            tgt.elts
                            if isinstance(tgt, (ast.Tuple, ast.List))
                            else [tgt]
                        )
                        for elt in elts:
                            if isinstance(elt, ast.Name):
                                key_vars.add(elt.id)

        consumed: dict[str, list[int]] = {}
        derived: dict[str, list[int]] = {}
        for site in sites:
            member = _jax_random_member(
                project.dotted(site.node.func, fn.module)
            )
            if member is None:
                continue
            # the key operand is the first positional or the `key=` kwarg
            key_arg = site.node.args[0] if site.node.args else None
            for kw in site.node.keywords:
                if kw.arg == "key":
                    key_arg = kw.value
            if not isinstance(key_arg, ast.Name):
                continue  # derived inline (fold_in(k, t) etc.) — fine
            if key_arg.id not in key_vars:
                continue
            # a consumption inside a loop happens >= twice
            weight = 2 if site.loop_depth > 0 else 1
            if member in _DERIVERS:
                derived.setdefault(key_arg.id, []).extend(
                    [site.node.lineno] * weight
                )
            elif member not in _NON_SAMPLERS:
                consumed.setdefault(key_arg.id, []).extend(
                    [site.node.lineno] * weight
                )

        for var, lines in consumed.items():
            if len(lines) >= 2:
                findings.append(
                    Finding(
                        rule=RULE,
                        path=fn.path,
                        line=lines[1] if len(set(lines)) > 1 else lines[0],
                        symbol=fn.qualname,
                        message=f"key `{var}` sampled more than once "
                        f"(lines {sorted(set(lines))}): reuse correlates "
                        "draws — fold_in/split a fresh subkey per use",
                    )
                )
            if var in derived:
                findings.append(
                    Finding(
                        rule=RULE,
                        path=fn.path,
                        line=lines[0],
                        symbol=fn.qualname,
                        message=f"key `{var}` is both sampled from and "
                        f"split/fold_in-derived (derive at line "
                        f"{derived[var][0]}): the sampler stream aliases "
                        "the derived streams",
                    )
                )
    return findings
