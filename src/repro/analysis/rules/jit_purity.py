"""jit-purity: no host effects inside traced code.

Anything reachable from a jit / scan / while_loop / pallas entry point
executes at *trace time*, once per compile — not once per call.  A
``time.time()`` or ``np.random`` draw there bakes a single host value
into the compiled program (silently wrong), and IO or global mutation
runs on an unpredictable schedule.  The repro's CRN contract additionally
requires that every random bit flow from a traced ``jax.random`` key, so
host RNGs in traced code break bitwise reproducibility even when they
"work".
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..walker import Project
from .base import body_walk

RULE = "jit-purity"

# dotted-prefix -> why it is banned under a trace
_BANNED_PREFIXES = {
    "time": "host clock reads are frozen at trace time",
    "random": "host RNG breaks the CRN contract (use jax.random)",
    "numpy.random": "host RNG breaks the CRN contract (use jax.random)",
    "secrets": "host entropy is untraceable",
    "uuid": "host entropy is untraceable",
    "os.environ": "environment reads are frozen at trace time",
    "os.getenv": "environment reads are frozen at trace time",
}
_BANNED_BUILTINS = {
    "print": "IO side effect at trace time (use jax.debug.print)",
    "open": "file IO inside traced code",
    "input": "blocking IO inside traced code",
}


def _banned(dotted: str | None) -> str | None:
    if dotted is None:
        return None
    if dotted in _BANNED_BUILTINS:
        return _BANNED_BUILTINS[dotted]
    for prefix, why in _BANNED_PREFIXES.items():
        if dotted == prefix or dotted.startswith(prefix + "."):
            return why
    return None


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for fn in project.iter_reachable():
        for node in body_walk(fn):
            if isinstance(node, ast.Call):
                dotted = project.dotted(node.func, fn.module)
                why = _banned(dotted)
                if why is not None:
                    findings.append(
                        Finding(
                            rule=RULE,
                            path=fn.path,
                            line=node.lineno,
                            symbol=fn.qualname,
                            message=f"`{dotted}(...)` in jit-reachable "
                            f"code: {why}",
                        )
                    )
            elif isinstance(node, ast.Global):
                findings.append(
                    Finding(
                        rule=RULE,
                        path=fn.path,
                        line=node.lineno,
                        symbol=fn.qualname,
                        message="`global` mutation in jit-reachable code: "
                        "trace-time writes race with the compile cache",
                    )
                )
    return findings
