"""Runtime sentinels: the dynamic half of thriftlint.

Static rules catch the patterns that *would* churn the compile cache;
:class:`CompileSentinel` proves at runtime that they *didn't* — it reads
each registered jit wrapper's actual XLA cache population before and
after a workload, so a test can assert "routing 50 mixed batches compiled
exactly the bucket programs it declared, and re-routing new content
compiled nothing".

The tracer-leak guard is the second sentinel: `jax.check_tracer_leaks`
turns a leaked tracer (a traced value smuggled into host state — the
failure mode the jit-purity rule bans statically) into an immediate
error.  ``install_tracer_guard()`` is wired into the tier-1 run via
``tests/conftest.py`` and honours ``THRIFTLINT_TRACER_GUARD=0`` for
opt-out profiling runs.
"""
from __future__ import annotations

import contextlib
import os
from dataclasses import dataclass, field
from typing import Callable


def compile_cache_size(fn: Callable) -> int:
    """Number of compiled programs a jit wrapper currently holds."""
    sizer = getattr(fn, "_cache_size", None)
    if sizer is None:
        raise TypeError(
            f"{fn!r} exposes no _cache_size(); CompileSentinel needs a "
            "jax.jit wrapper (not the underlying Python function)"
        )
    return int(sizer())


@dataclass
class CompileSentinel:
    """Counts actual XLA compilations per registered jit entry point.

    Usage::

        sentinel = CompileSentinel({"wave": _wave_scan, "plan": _sur_greedy_scan})
        ...warm-up / steady-state workload...
        sentinel.snapshot()
        ...more traffic confined to warm buckets...
        sentinel.assert_no_new_compiles()          # steady state stayed warm
        sentinel.assert_within({"wave": 4})        # or: bucket budget holds
    """

    entries: dict[str, Callable] = field(default_factory=dict)
    _baseline: dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        for fn in self.entries.values():
            compile_cache_size(fn)  # fail fast on non-jit callables
        self.snapshot()

    def register(self, name: str, fn: Callable) -> None:
        compile_cache_size(fn)
        self.entries[name] = fn
        self._baseline[name] = compile_cache_size(fn)

    def snapshot(self) -> None:
        """Rebase: subsequent deltas count compiles after this point."""
        self._baseline = {
            name: compile_cache_size(fn)
            for name, fn in self.entries.items()
        }

    def compiles(self, name: str) -> int:
        """New compilations of `name` since the last snapshot."""
        return compile_cache_size(self.entries[name]) - self._baseline[name]

    def deltas(self) -> dict[str, int]:
        return {name: self.compiles(name) for name in self.entries}

    def total(self) -> int:
        return sum(self.deltas().values())

    def assert_no_new_compiles(self, detail: str = "") -> None:
        deltas = self.deltas()
        hot = {k: v for k, v in deltas.items() if v}
        assert not hot, (
            f"compile sentinel: unexpected XLA recompilation {hot}"
            + (f" — {detail}" if detail else "")
        )

    def assert_within(self, budgets: dict[str, int], detail: str = "") -> None:
        """Each entry compiled at most its declared bucket budget."""
        over = {
            name: (self.compiles(name), cap)
            for name, cap in budgets.items()
            if self.compiles(name) > cap
        }
        assert not over, (
            "compile sentinel: bucket budget exceeded "
            + ", ".join(
                f"{n}: {got} compiles > budget {cap}"
                for n, (got, cap) in over.items()
            )
            + (f" — {detail}" if detail else "")
        )


# ---------------------------------------------------------------------------
# tracer-leak guard
# ---------------------------------------------------------------------------

_GUARD_ENV = "THRIFTLINT_TRACER_GUARD"


def tracer_guard_enabled() -> bool:
    return os.environ.get(_GUARD_ENV, "1") != "0"


def install_tracer_guard() -> bool:
    """Globally enable jax's tracer-leak checking (tier-1 runs under it).

    Returns True when the guard was installed.  Set
    ``THRIFTLINT_TRACER_GUARD=0`` to opt out (e.g. for profiling runs
    where the extra trace-time bookkeeping is unwanted).
    """
    if not tracer_guard_enabled():
        return False
    import jax

    jax.config.update("jax_check_tracer_leaks", True)
    return True


@contextlib.contextmanager
def tracer_leak_guard():
    """Scoped variant: raise on tracer leaks inside the block."""
    import jax

    with jax.checking_leaks():
        yield
