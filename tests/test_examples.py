"""Examples smoke tests: the documented entry points must actually run.

Runs ``examples/quickstart.py`` and ``examples/budget_sweep.py`` as real
subprocesses (the way the README tells a user to) under a tiny config, so
an API refactor that breaks the public examples fails the suite instead of
rotting silently.
"""
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run_example(script: str, *args: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / script), *args],
        capture_output=True,
        text=True,
        env=env,
        cwd=REPO,
        timeout=600,
    )
    assert proc.returncode == 0, (
        f"{script} exited {proc.returncode}\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
    )
    return proc.stdout


def test_quickstart_runs_tiny():
    out = _run_example("quickstart.py", "--queries", "80", "--history", "300")
    assert "pool costs" in out
    assert "budget" in out and "accuracy" in out
    # the frontier table printed one row per default budget
    assert sum(1 for line in out.splitlines() if line.strip().startswith("1e-")
               or " 1e-" in line or "e-0" in line) >= 1
    assert "ThriftLLM=" in out           # the single-arm comparison ran


def test_budget_sweep_runs_tiny():
    out = _run_example(
        "budget_sweep.py",
        "--queries", "30", "--history", "300", "--budgets", "1e-4", "5e-4",
    )
    assert "Thrift" in out and "cascade" in out
    # one table row per requested budget + the blender footer
    rows = [l for l in out.splitlines() if l.strip().startswith(("1e-04", "5e-04"))]
    assert len(rows) == 2, out
    assert "LLM-Blender-style" in out
