"""Property tests on the online estimation loop (Sec. 3.1 + feedback).

Invariants the serving feedback subsystem leans on:
  * folding feedback is order-invariant and count-consistent — any batch
    interleaving reaches the same estimate;
  * Hoeffding / Wilson / median-boosted intervals always contain p_hat and
    shrink monotonically in n;
  * the estimator version is strictly monotone under any interleaving of
    feedback folds, and plan visibility is exactly what bumps the
    per-cluster plan versions.

Runs on the real ``hypothesis`` engine when installed, else on the
in-repo ``_hypolite`` fallback — scripts/ci.sh fails if these skip.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: see requirements-test.txt
    from _hypolite import given, settings, strategies as st

from repro.core.estimation import (
    SuccessProbEstimator,
    hoeffding_interval,
    median_boost_rounds,
    median_boosted_interval,
    wilson_interval,
)


def _tiny_estimator(L: int, clusters: int = 1, n: int = 8, seed: int = 0):
    """Cheap estimator: `clusters` well-separated clusters of n rows each."""
    rng = np.random.default_rng(seed)
    table = (rng.random((n * clusters, L)) < 0.7).astype(float)
    d = max(clusters, 2)
    emb = np.repeat(np.eye(d)[:clusters], n, axis=0) * 10.0
    cids = np.repeat(np.arange(clusters), n)
    return SuccessProbEstimator(table, emb, cids, min_cluster_size=1)


def _random_feedback(rng, k: int, L: int):
    """k random (successes, attempts, queries) feedback batches over L arms,
    with attempts masked per arm (served traffic observes arms unevenly)."""
    batches = []
    for _ in range(k):
        attempts = rng.integers(0, 4, L).astype(float)
        successes = np.floor(rng.random(L) * (attempts + 1))
        batches.append((successes, attempts, int(attempts.max(initial=0))))
    return batches


# ---------------------------------------------------------------------------
# update: order-invariance + count consistency
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 6), st.integers(1, 5))
def test_update_counts_order_invariant_and_count_consistent(seed, k, L):
    rng = np.random.default_rng(seed)
    batches = _random_feedback(rng, k, L)
    est_fwd = _tiny_estimator(L)
    est_rev = _tiny_estimator(L)
    for succ, att, nq in batches:
        est_fwd.update_counts(0, succ, att, queries=nq)
    for succ, att, nq in batches[::-1]:
        est_rev.update_counts(0, succ, att, queries=nq)
    a, b = est_fwd.clusters[0], est_rev.clusters[0]
    # same estimate whichever order the feedback batches landed in
    np.testing.assert_allclose(a.p_hat, b.p_hat, rtol=0, atol=1e-9)
    # counts are exact bookkeeping, not approximations
    np.testing.assert_array_equal(a.arm_counts, b.arm_counts)
    expect_counts = 8.0 + sum(att for _, att, _ in batches)
    np.testing.assert_array_equal(a.arm_counts, expect_counts)
    assert a.count == b.count == 8 + sum(nq for _, _, nq in batches)
    # and the fold is count-consistent: estimate == total successes / total
    est_ref = _tiny_estimator(L)
    base_succ = est_ref.clusters[0].p_hat * 8.0
    total_succ = base_succ + sum(succ for succ, _, _ in batches)
    np.testing.assert_allclose(
        a.p_hat * a.arm_counts, total_succ, rtol=0, atol=1e-9
    )


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6), st.integers(2, 20), st.integers(2, 4))
def test_update_rows_equals_one_shot_fold(seed, n, L):
    """Folding (n, L) outcome rows one by one == folding them as one batch."""
    rng = np.random.default_rng(seed)
    rows = (rng.random((n, L)) < rng.random(L)).astype(float)
    est_one = _tiny_estimator(L)
    est_many = _tiny_estimator(L)
    est_one.update(0, rows)
    for r in rows:
        est_many.update(0, r)
    np.testing.assert_allclose(
        est_one.clusters[0].p_hat, est_many.clusters[0].p_hat,
        rtol=0, atol=1e-9,
    )
    assert est_one.clusters[0].count == est_many.clusters[0].count


# ---------------------------------------------------------------------------
# intervals: containment + monotone shrink in n
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(
    st.lists(st.floats(0.0, 1.0), min_size=1, max_size=6),
    st.integers(1, 500),
    st.integers(1, 500),
    st.floats(0.001, 0.2),
)
def test_hoeffding_wilson_contain_and_shrink(ps, n1, n2, delta):
    p = np.asarray(ps)
    n_small, n_big = min(n1, n2), max(n1, n2)
    for fn in (hoeffding_interval, wilson_interval):
        lo_s, hi_s = fn(p, n_small, delta)
        lo_b, hi_b = fn(p, n_big, delta)
        # always contain p_hat (1e-9: clipping noise at the 0/1 endpoints)
        assert (lo_s - 1e-9 <= p).all() and (p <= hi_s + 1e-9).all()
        assert (lo_b - 1e-9 <= p).all() and (p <= hi_b + 1e-9).all()
        # width shrinks monotonically in n
        assert ((hi_b - lo_b) <= (hi_s - lo_s) + 1e-9).all()


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.floats(0.0, 1.0), min_size=2, max_size=5),
    st.floats(0.001, 0.2),
)
def test_intervals_vectorized_counts_match_scalar(ps, delta):
    """Per-arm array n (the feedback path) == stacking scalar calls."""
    p = np.asarray(ps)
    ns = np.arange(1, p.size + 1) * 7
    for fn in (hoeffding_interval, wilson_interval):
        lo_v, hi_v = fn(p, ns, delta)
        for i, n in enumerate(ns):
            lo_i, hi_i = fn(p[i : i + 1], int(n), delta)
            np.testing.assert_allclose(lo_v[i], lo_i[0], rtol=0, atol=1e-12)
            np.testing.assert_allclose(hi_v[i], hi_i[0], rtol=0, atol=1e-12)
    # n = 0 entries degrade to the vacuous interval, not a division error
    lo, hi = hoeffding_interval(p, np.zeros(p.size), delta)
    assert (lo == 0).all() and (hi == 1).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.integers(8, 64), st.integers(2, 5))
def test_median_boosted_contains_and_bound_shrinks(seed, n, L):
    rng = np.random.default_rng(seed)
    table = (rng.random((n, L)) < rng.random(L)).astype(float)
    delta, delta_l = 0.05, 0.25
    p, lo, hi = median_boosted_interval(table, delta, seed=seed)
    # the reported interval always contains the reported estimate
    assert (lo - 1e-9 <= p).all() and (p <= hi + 1e-9).all()
    # realized width never exceeds the subsample Hoeffding bound, and that
    # bound shrinks monotonically in n (the estimator is randomized, so the
    # *bound* is the monotone object)
    def bound(m):
        sub = max(1, int(m * 0.5))
        return 2.0 * np.sqrt(np.log(2.0 / delta_l) / (2.0 * sub))

    assert ((hi - lo) <= bound(n) + 1e-9).all()
    assert bound(2 * n) <= bound(n) + 1e-12
    # Lemma 5 repetition count grows as the failure target tightens
    assert median_boost_rounds(L, delta / 10, delta_l) >= median_boost_rounds(
        L, delta, delta_l
    )


# ---------------------------------------------------------------------------
# estimator version: strictly monotone under any interleaving
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 12), st.integers(2, 3))
def test_version_strictly_monotone_under_interleaving(seed, k, clusters):
    rng = np.random.default_rng(seed)
    L = 3
    est = _tiny_estimator(L, clusters=clusters)
    assert est.version == 0 and est.plan_version == 0
    seen = [0]
    for _ in range(k):
        cid = int(rng.integers(clusters))
        plan_visible = bool(rng.integers(2))
        if rng.integers(2):
            est.update(cid, (rng.random((2, L)) < 0.5).astype(float))
            plan_visible = True  # direct updates are always plan-visible
        else:
            succ, att, nq = _random_feedback(rng, 1, L)[0]
            est.update_counts(cid, succ, att, queries=nq,
                              plan_visible=plan_visible)
        # strictly monotone: every fold bumps, regardless of interleaving
        assert est.version == seen[-1] + 1
        seen.append(est.version)
        if plan_visible:
            assert est.clusters[cid].version == est.version
            assert est.plan_version == est.version
        # cluster/plan versions never outrun the global version
        assert all(c.version <= est.version for c in est.clusters.values())
        assert est.plan_version <= est.version


def test_plan_visibility_gates_plan_version():
    """Confirming feedback (plan_visible=False) advances the estimator
    version but leaves the plan version — and the plan snapshot — put."""
    est = _tiny_estimator(3)
    st0 = est.clusters[0]
    snap_p = st0.plan_p_hat
    est.update_counts(0, np.ones(3), np.full(3, 2.0), queries=2,
                      plan_visible=False)
    assert est.version == 1 and est.plan_version == 0
    assert est.clusters[0].version == 0
    assert est.clusters[0].plan_p_hat is snap_p      # snapshot untouched
    est.update_counts(0, np.ones(3), np.full(3, 2.0), queries=2,
                      plan_visible=True)
    assert est.version == 2 and est.plan_version == 2
    assert est.clusters[0].version == 2
    assert est.clusters[0].plan_p_hat is est.clusters[0].p_hat
