"""Success-probability estimation, clustering, data pipeline, tokenizer."""
import numpy as np
import pytest

from repro.core.clustering import auto_eps, dbscan, kmeans
from repro.core.estimation import (
    SuccessProbEstimator,
    hoeffding_interval,
    median_boost_rounds,
    median_boosted_interval,
    wilson_interval,
)
from repro.data import (
    DataPipeline,
    OracleWorkload,
    decode,
    encode,
    host_shard_fn,
    make_token_task,
)


class TestIntervals:
    def test_hoeffding_coverage(self):
        rng = np.random.default_rng(0)
        p_true, n, delta = 0.7, 200, 0.05
        misses = 0
        for _ in range(200):
            x = rng.random(n) < p_true
            lo, hi = hoeffding_interval(np.array([x.mean()]), n, delta)
            misses += not (lo[0] <= p_true <= hi[0])
        assert misses / 200 <= delta + 0.02

    def test_wilson_tighter_than_hoeffding(self):
        p_hat = np.array([0.8])
        lo_h, hi_h = hoeffding_interval(p_hat, 50, 0.05)
        lo_w, hi_w = wilson_interval(p_hat, 50, 0.05)
        assert (hi_w - lo_w) < (hi_h - lo_h)

    def test_median_boost_rounds_formula(self):
        lam = median_boost_rounds(12, 0.01, 0.25)
        assert lam == int(np.ceil(6 * np.log(12 / 0.01) / 0.25))

    def test_median_boosted_interval_contains_truth(self):
        rng = np.random.default_rng(1)
        table = (rng.random((400, 5)) < np.array([0.5, 0.6, 0.7, 0.8, 0.9])).astype(float)
        p_hat, lo, hi = median_boosted_interval(table, delta=0.01)
        truth = np.array([0.5, 0.6, 0.7, 0.8, 0.9])
        assert ((lo <= truth) & (truth <= hi)).all()


class TestClustering:
    def test_kmeans_recovers_separated_clusters(self):
        rng = np.random.default_rng(0)
        centers = np.array([[0, 0], [10, 0], [0, 10]], float)
        x = np.concatenate([c + rng.normal(0, 0.3, (50, 2)) for c in centers])
        assign, cents = kmeans(x, 3, seed=1)
        # each true block should be a single cluster
        for blk in range(3):
            ids = assign[blk * 50 : (blk + 1) * 50]
            assert (ids == ids[0]).all()

    def test_dbscan_finds_clusters_and_noise(self):
        rng = np.random.default_rng(2)
        a = rng.normal(0, 0.1, (40, 2))
        b = rng.normal(5, 0.1, (40, 2)) + np.array([5, 0])
        outlier = np.array([[50.0, 50.0]])
        x = np.concatenate([a, b, outlier])
        labels = dbscan(x, eps=1.0, min_pts=4)
        assert labels[-1] == -1
        assert len(set(labels[:40])) == 1
        assert len(set(labels[40:80])) == 1
        assert labels[0] != labels[40]

    def test_auto_eps_positive(self):
        rng = np.random.default_rng(3)
        assert auto_eps(rng.normal(0, 1, (100, 4))) > 0


class TestEstimator:
    def test_per_cluster_estimates_close_to_truth(self):
        wl = OracleWorkload(num_classes=3, num_clusters=4, num_arms=6, seed=7)
        T, emb, cid = wl.response_table(2000)
        est = SuccessProbEstimator(T, emb, cid)  # true cluster ids
        errs = []
        for c in range(4):
            errs.append(np.abs(est.clusters[c].p_hat - wl.p_true[c]).mean())
        assert np.mean(errs) < 0.06

    def test_lookup_maps_to_right_cluster(self):
        wl = OracleWorkload(num_classes=3, num_clusters=4, num_arms=6, seed=7)
        T, emb, cid = wl.response_table(800)
        est = SuccessProbEstimator(T, emb, cid)
        rng = np.random.default_rng(0)
        tc, temb, _ = wl.sample_queries(100, rng)
        got = est.lookup_batch(temb)
        assert (got == tc).mean() > 0.95

    def test_alpha_interval_override(self):
        wl = OracleWorkload(num_classes=3, num_clusters=2, num_arms=4, seed=1)
        T, emb, cid = wl.response_table(300)
        est = SuccessProbEstimator(T, emb, cid)
        qc = est.query_class(emb[0], 3, alpha=0.1)
        assert np.all(qc.hi - qc.lo <= 0.1 + 1e-12)


class TestData:
    def test_pipeline_prefetch_and_shard(self):
        def make(step):
            return {"x": np.full((8, 2), step)}

        pipe = DataPipeline(make, shard_fn=host_shard_fn(1, 2), prefetch=2)
        b = next(pipe)
        assert b["x"].shape == (4, 2)
        pipe.close()

    def test_tokenizer_roundtrip(self):
        s = "hello ThriftLLM"
        assert decode(encode(s)) == s

    def test_token_task_signature_dominates(self):
        d = make_token_task(num_classes=4, seq_len=64, vocab=512, n=200, seed=0)
        toks, labs, sig = d["tokens"], d["labels"], d["class_token_ids"]
        assert (toks[:, -1] == sig[labs]).all()
        # true signature occurs strictly more often than any distractor
        ok = 0
        for i in range(200):
            counts = [(toks[i, :-2] == s).sum() for s in sig]
            ok += int(np.argmax(counts) == labs[i])
        assert ok / 200 > 0.95


class TestOnlineUpdate:
    def test_streaming_update_converges_to_truth(self):
        wl = OracleWorkload(num_classes=3, num_clusters=2, num_arms=4, seed=5)
        T, emb, cid = wl.response_table(60)   # thin history: noisy estimates
        est = SuccessProbEstimator(T, emb, cid)
        rng = np.random.default_rng(0)
        before = np.abs(est.clusters[0].p_hat - wl.p_true[0]).mean()
        # stream 2000 labeled outcomes for cluster 0
        for _ in range(20):
            batch = np.stack([
                [wl.invoke(a, 0, 1, rng) == 1 for a in range(4)]
                for _ in range(100)
            ]).astype(float)
            est.update(0, batch)
        after = np.abs(est.clusters[0].p_hat - wl.p_true[0]).mean()
        assert after < before
        assert after < 0.05
        # CI tightened with the extra samples
        st = est.clusters[0]
        assert (st.hi - st.lo).mean() < 0.2

    def test_update_is_exact_streaming_mean(self):
        wl = OracleWorkload(num_classes=2, num_clusters=1, num_arms=3, seed=1)
        T, emb, cid = wl.response_table(50)
        est = SuccessProbEstimator(T, emb, cid)
        extra = (np.random.default_rng(2).random((30, 3)) < 0.5).astype(float)
        est.update(0, extra)
        idx = np.flatnonzero(cid == 0)
        expect = np.concatenate([T[idx], extra]).mean(axis=0)
        np.testing.assert_allclose(est.clusters[0].p_hat, expect, atol=1e-12)
