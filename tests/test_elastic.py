"""Elastic re-mesh end-to-end: train on a 2x4 mesh, 'lose' a data row,
restore the checkpoint under the shrunk 1x4 mesh with a rebatched global
batch, and continue training — the full node-failure recovery path."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_smoke_config
    from repro.distributed.fault import plan_elastic_remesh, rebatch_for_mesh
    from repro.distributed.sharding import AxisRules, batch_specs, param_specs, use_rules
    from repro.models import LM
    from repro.training import OptimizerConfig, init_train_state, make_train_step

    cfg0 = get_smoke_config("smollm-135m")
    cfg = type(cfg0)(**{**cfg0.__dict__, "num_microbatches": 1})
    model = LM(cfg)
    rng = np.random.default_rng(0)
    mk = lambda b: {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 16)), jnp.int32)}
    ckdir = tempfile.mkdtemp()
    mgr = CheckpointManager(ckdir)
    params, opt = init_train_state(model, jax.random.key(0))
    losses = []

    def run(mesh_shape, global_batch, state, steps):
        mesh = jax.make_mesh(tuple(mesh_shape.values()), tuple(mesh_shape.keys()))
        rules = AxisRules(mesh)
        p, o = state
        p_sh = param_specs(jax.eval_shape(lambda: p), rules)
        o_sh = param_specs(jax.eval_shape(lambda: o), rules)
        with use_rules(rules), mesh:
            step_fn = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-3)),
                              in_shardings=(p_sh, o_sh, batch_specs(mk(global_batch), rules)))
            p = jax.device_put(p, p_sh)
            o = jax.device_put(o, o_sh)
            for s in range(steps):
                b = jax.device_put(mk(global_batch), batch_specs(mk(global_batch), rules))
                p, o, m = step_fn(p, o, b)
                losses.append(float(m["loss"]))
        return jax.device_get(p), jax.device_get(o)

    # phase 1: healthy 2x4 mesh, global batch 8
    shape1 = {"data": 2, "model": 4}
    params, opt = run(shape1, 8, (params, opt), steps=3)
    mgr.save(2, {"params": params, "opt": opt})

    # failure: lose one host in a data row -> plan shrink + rebatch
    new_shape = plan_elastic_remesh(shape1, failed_hosts=[1], hosts_per_data_row=1)
    new_batch = rebatch_for_mesh(8, shape1["data"], new_shape["data"])
    step, state = mgr.restore_latest({"params": params, "opt": opt})
    params, opt = run(new_shape, new_batch, (state["params"], state["opt"]), steps=3)

    print(json.dumps({
        "restored_step": step,
        "new_mesh": new_shape, "new_batch": new_batch,
        "losses_finite": bool(np.isfinite(losses).all()),
        "n_steps": len(losses),
    }))
    """
)


def test_elastic_remesh_restart():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)), timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["restored_step"] == 2
    assert res["new_mesh"] == {"data": 1, "model": 4}
    assert res["new_batch"] == 4
    assert res["losses_finite"] and res["n_steps"] == 6
