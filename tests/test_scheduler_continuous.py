"""Continuous-batching front-end: equivalence with one-shot routing, the
cost-aware speculation switch, wave-stepped future completion, SLO-aware
admission, and stats consistency under interleaved submits.

Determinism comes from tabular arms (as in test_router_batched): each arm's
response to query j is precomputed, so admission order, budget grouping and
speculative gathering cannot change what any arm answers — continuous-mode
results must therefore be *exactly* the one-shot ``route_batch`` results on
the same request stream.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import BatchScheduler, PoolEngine, Request, ThriftRouter


@dataclasses.dataclass
class TabularArm:
    """Deterministic arm: response to query j is the precomputed resp[j]."""

    name: str
    cost: float
    resp: np.ndarray
    metered: bool = False

    def classify_batch(self, queries) -> np.ndarray:
        return self.resp[np.asarray(queries, np.int64)]

    def latency_s(self, batch: int) -> float:
        return 1e-6 * self.cost * batch


def _make_pool(K=4, L=8, clusters=5, B=96, seed=3, metered=False):
    wl = OracleWorkload(num_classes=K, num_clusters=clusters, num_arms=L, seed=seed)
    T, emb, _ = wl.response_table(60 * clusters, seed=seed + 1)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(seed + 2)
    qcid, qemb, qlab = wl.sample_queries(B, rng)
    R = np.stack(
        [
            wl.invoke_batch(a, qcid, qlab, np.random.default_rng(seed + 100 + a))
            for a in range(L)
        ]
    )
    engine = PoolEngine(
        [TabularArm(f"t{a}", float(wl.costs[a]), R[a], metered=metered)
         for a in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return engine, router, qemb


def _oneshot_stream(router, qemb, budgets, max_batch):
    """The one-shot equivalent of the continuous pipeline: FIFO admission
    chunks of ``max_batch``, split into budget groups in first-occurrence
    order, each group routed by a plain ``route_batch`` call."""
    B = budgets.shape[0]
    preds = np.zeros(B, np.int64)
    costs = np.zeros(B, np.float64)
    stop_waves = np.zeros(B, np.int64)
    for s in range(0, B, max_batch):
        rows = np.arange(s, min(s + max_batch, B))
        chunk_budgets = budgets[rows]
        if (chunk_budgets == chunk_budgets[0]).all():
            groups = [rows]
        else:
            _, first = np.unique(chunk_budgets, return_index=True)
            groups = [
                rows[chunk_budgets == chunk_budgets[i]] for i in np.sort(first)
            ]
        for g in groups:
            res = router.route_batch(g, qemb[g], budgets[g])
            preds[g] = res.predictions
            costs[g] = res.costs
            stop_waves[g] = res.stop_waves
    return preds, costs, stop_waves


@pytest.mark.parametrize("hetero", [False, True])
def test_continuous_matches_oneshot_stream(hetero):
    engine, router, qemb = _make_pool(B=96)
    B = qemb.shape[0]
    rng = np.random.default_rng(11)
    levels = np.quantile(engine.costs, [0.4, 0.8]) * 2.5
    budgets = (
        rng.choice(levels, size=B) if hetero
        else np.full(B, float(levels[1]))
    )

    sched = BatchScheduler(router, max_batch=32, max_wait_s=0.0)
    futs = [
        sched.submit(Request(payload=j, embedding=qemb[j], budget=budgets[j]))
        for j in range(B)
    ]
    sched.drain()

    # a second identical pool routed one-shot must reproduce every output
    _, router2, _ = _make_pool(B=96)
    preds, costs, stop_waves = _oneshot_stream(router2, qemb, budgets, 32)

    assert all(f.done() for f in futs)
    results = [f.result() for f in futs]
    np.testing.assert_array_equal([r.prediction for r in results], preds)
    np.testing.assert_allclose(
        [r.cost for r in results], costs, rtol=1e-12, atol=0
    )
    np.testing.assert_array_equal([r.stop_wave for r in results], stop_waves)
    assert all(r.mode == "jit" for r in results)  # unmetered pool speculates


def test_saturation_coalescing_matches_oneshot_and_caps_admission():
    """coalesce > 1: a saturated backlog is admitted in up-to
    ``coalesce * max_batch`` chunks; results still exactly match the
    one-shot stream at that effective chunking, and flush() never grows."""
    engine, router, qemb = _make_pool(B=96)
    _, router2, _ = _make_pool(B=96)
    budget = float(np.quantile(engine.costs, 0.6)) * 2

    sched = BatchScheduler(router, max_batch=16, max_wait_s=0.0, coalesce=3)
    blk = sched.submit_many(np.arange(96), qemb, budget)
    sched.drain()
    # backlog of 96 > 16 -> admissions of 48: two flushes, not six
    assert sched.stats["flushes"] == 2

    preds, costs, _ = _oneshot_stream(
        router2, qemb, np.full(96, budget), 48
    )
    np.testing.assert_array_equal(blk.predictions, preds)
    np.testing.assert_allclose(blk.costs, costs, rtol=1e-12, atol=0)

    # the legacy one-shot flush() API never coalesces
    sched2 = BatchScheduler(router, max_batch=16, max_wait_s=0.0, coalesce=3)
    sched2.submit_many(np.arange(96), qemb, budget)
    (batch, res) = sched2.flush()[0]
    assert len(batch) == 16 and res.predictions.shape[0] == 16


def test_block_submission_matches_single_submits():
    engine, router, qemb = _make_pool(B=64)
    _, router2, _ = _make_pool(B=64)
    budget = float(np.quantile(engine.costs, 0.6)) * 2

    sched1 = BatchScheduler(router, max_batch=16, max_wait_s=0.0)
    futs = [
        sched1.submit(Request(payload=j, embedding=qemb[j], budget=budget))
        for j in range(64)
    ]
    sched1.drain()

    sched2 = BatchScheduler(router2, max_batch=16, max_wait_s=0.0)
    blk = sched2.submit_many(np.arange(64), qemb, budget)
    sched2.drain()

    np.testing.assert_array_equal(
        blk.predictions, [f.result().prediction for f in futs]
    )
    np.testing.assert_allclose(
        blk.costs, [f.result().cost for f in futs], rtol=1e-12, atol=0
    )
    np.testing.assert_array_equal(
        blk.stop_waves, [f.result().stop_wave for f in futs]
    )
    assert blk.done() and blk.result() is blk


def test_speculation_switch_metered_vs_oracle():
    """auto mode: cheap unmetered pool -> speculative jit plane; metered
    pool -> compacting reference plane; identical predictions either way."""
    _, router_free, qemb = _make_pool(B=48, metered=False)
    engine_m, router_m, _ = _make_pool(B=48, metered=True)
    budget = float(np.quantile(engine_m.costs, 0.6)) * 2

    s_free = BatchScheduler(router_free, max_batch=16, max_wait_s=0.0)
    blk_free = s_free.submit_many(np.arange(48), qemb, budget)
    s_free.drain()
    assert set(blk_free.modes.tolist()) == {"jit"}
    assert s_free.stats["spec_jit"] == 3 and s_free.stats["spec_reference"] == 0

    s_met = BatchScheduler(router_m, max_batch=16, max_wait_s=0.0)
    blk_met = s_met.submit_many(np.arange(48), qemb, budget)
    s_met.drain()
    assert set(blk_met.modes.tolist()) == {"reference"}
    assert s_met.stats["spec_reference"] == 3 and s_met.stats["spec_jit"] == 0

    # the data plane never changes the answers
    np.testing.assert_array_equal(blk_free.predictions, blk_met.predictions)
    np.testing.assert_allclose(blk_free.costs, blk_met.costs, rtol=1e-12, atol=0)

    # a budget-sized threshold lets the switch speculate on a metered pool:
    # the worst-case speculative exposure per query can never exceed the
    # planned (in-budget) spend, so budget-per-query is always enough
    s_thresh = BatchScheduler(
        router_m, max_batch=16, max_wait_s=0.0, speculation_threshold=budget
    )
    blk_thresh = s_thresh.submit_many(np.arange(48), qemb, budget)
    s_thresh.drain()
    assert set(blk_thresh.modes.tolist()) == {"jit"}

    # and the plane can be pinned outright
    s_pin = BatchScheduler(router_m, max_batch=16, max_wait_s=0.0,
                           speculation="jit")
    blk_pin = s_pin.submit_many(np.arange(48), qemb, budget)
    s_pin.drain()
    assert set(blk_pin.modes.tolist()) == {"jit"}


def test_speculation_cost_metadata():
    engine_free, router_free, qemb = _make_pool(B=16, metered=False)
    engine_m, router_m, _ = _make_pool(B=16, metered=True)
    budget = float(np.quantile(engine_free.costs, 0.6)) * 2
    assert not engine_free.any_metered and engine_m.any_metered
    p_free = router_free.begin_route(np.arange(16), qemb, budget, mode="auto")
    p_met = router_m.begin_route(np.arange(16), qemb, budget, mode="auto")
    assert p_free.kind == "jit" and p_free.spec_cost == 0.0
    assert p_met.kind == "reference" and p_met.spec_cost > 0.0
    # exposure is the full scheduled metered spend per query
    assert p_met.spec_cost <= budget + 1e-12
    p_free.result(), p_met.result()


def test_reference_wave_stepping_resolves_at_stop_wave():
    """PendingRoute.step(): queries complete in stop-wave order with their
    final predictions, matching the one-shot reference result exactly."""
    engine, router, qemb = _make_pool(B=64)
    _, router2, _ = _make_pool(B=64)
    budget = float(engine.costs.sum())     # everything affordable: deep plans
    res = router2.route_batch_reference(np.arange(64), qemb, budget)

    pending = router.begin_route(np.arange(64), qemb, budget, mode="reference")
    seen = np.full(64, -1, np.int64)
    preds = np.full(64, -1, np.int64)
    wave = 0
    while not pending.exhausted:
        rows, p = pending.step()
        assert np.all(seen[rows] == -1), "a query completed twice"
        seen[rows] = min(wave, pending.T)
        preds[rows] = p
        wave += 1
    assert (seen >= 0).all(), "every query completes through step()"
    np.testing.assert_array_equal(seen, res.stop_waves)
    np.testing.assert_array_equal(preds, res.predictions)
    # finalization after stepping reproduces the one-shot result
    out = pending.result()
    np.testing.assert_array_equal(out.predictions, res.predictions)
    np.testing.assert_allclose(out.costs, res.costs, rtol=1e-12, atol=0)
    np.testing.assert_array_equal(out.invoked, res.invoked)


def test_stats_consistent_under_interleaved_submits():
    engine, router, qemb = _make_pool(B=96)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    sched = BatchScheduler(router, max_batch=24, max_wait_s=0.0)

    futs = [
        sched.submit(Request(payload=j, embedding=qemb[j], budget=budget))
        for j in range(20)
    ]
    sched.pump()
    blk = sched.submit_many(np.arange(20, 70), qemb[20:70], budget)
    sched.pump()
    futs += [
        sched.submit(Request(payload=j, embedding=qemb[j], budget=budget))
        for j in range(70, 96)
    ]
    sched.drain()

    st = sched.stats
    assert st["submitted"] == 96
    assert st["requests"] == 96            # everything admitted
    assert st["completed"] == 96
    assert all(f.done() for f in futs) and blk.done()
    assert st["batches"] >= st["flushes"] >= 96 // 24
    assert st["inflight_peak"] >= 1
    assert st["spec_jit"] + st["spec_reference"] == st["batches"]
    # one mitigator record per routed group
    assert len(sched.mitigator.history) == min(st["batches"],
                                               sched.mitigator.window)
    # per-arm accounting: every invoked wave is one arm-query
    total_waves = sum(f.result().stop_wave for f in futs) + int(
        blk.stop_waves.sum()
    )
    assert int(sched.arm_query_totals.sum()) == total_waves
    # plan-cache counters mirrored and self-consistent
    assert st["plan_hits"] + st["plan_misses"] >= st["batches"]
    assert sched.latency_stats()["count"] == 96
    assert sched.latency_stats()["p99_s"] >= sched.latency_stats()["p50_s"]


def test_empty_block_and_pinned_router_under_auto():
    engine, router, qemb = _make_pool(B=16)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    sched = BatchScheduler(router, max_batch=8, max_wait_s=0.0)
    # a zero-length burst is a no-op, not a poisoned queue
    empty = sched.submit_many(np.zeros((0, 2), np.int64), np.zeros((0, 4)),
                              budget)
    assert empty.done() and empty.n == 0
    assert not sched.ready() and sched.drain() == 0
    blk = sched.submit_many(np.arange(16), qemb, budget)
    sched.drain()
    assert blk.done()

    # a router pinned to the reference plane (jit_waves=False) keeps it
    # under mode="auto" even though no arm carries a metered flag
    from repro.serving import ThriftRouter as TR
    router_pinned = TR(engine, router.estimator, num_classes=4,
                       jit_waves=False)
    pending = router_pinned.begin_route(np.arange(16), qemb, budget,
                                        mode="auto")
    assert pending.kind == "reference"
    pending.result()


def test_slo_tightens_admission_deadline():
    engine, router, qemb = _make_pool(B=8)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    sched = BatchScheduler(router, max_batch=64, max_wait_s=60.0,
                           slo_margin_s=0.0)
    sched.submit(Request(payload=0, embedding=qemb[0], budget=budget))
    assert not sched.ready()               # long max_wait, batch not full
    deadline_no_slo = sched.next_deadline()
    sched.submit(Request(payload=1, embedding=qemb[1], budget=budget,
                         slo_s=0.0))
    assert sched.next_deadline() < deadline_no_slo
    assert sched.ready()                   # SLO already due -> flush now
    assert sched.drain() == 2


def test_queue_composition_prefetch():
    engine, router, qemb = _make_pool(B=32)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    sched = BatchScheduler(router, max_batch=64, max_wait_s=60.0)
    for j in range(32):
        sched.submit(Request(payload=j, embedding=qemb[j], budget=budget))
    assert not sched.ready()
    sched.pump()                           # idle time -> plan prefetch
    st_mid = dict(router.plans.stats())
    assert st_mid["plan_prefetches"] > 0
    misses_before = st_mid["plan_misses"]
    sched.drain()
    assert router.plans.stats()["plan_misses"] == misses_before
    assert sched.stats["completed"] == 32


def test_flush_api_unchanged_and_resolves_futures():
    engine, router, qemb = _make_pool(B=32)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    sched = BatchScheduler(router, max_batch=16, max_wait_s=0.0)
    futs = [
        sched.submit(Request(payload=j, embedding=qemb[j], budget=budget))
        for j in range(32)
    ]
    out = sched.flush()
    assert len(out) == 1
    batch, res = out[0]
    assert len(batch) == 16 and all(isinstance(r, Request) for r in batch)
    assert all(f.done() for f in futs[:16])
    assert not any(f.done() for f in futs[16:])
    np.testing.assert_array_equal(
        [f.result().prediction for f in futs[:16]], res.predictions
    )
    sched.drain()
    assert all(f.done() for f in futs)
