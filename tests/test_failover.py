"""Arm-level fault injection, in-wave failover, and degradation tracking.

The failure plane's contracts, proven by fault matrix:

  * under every injected fault schedule (timeout / error / silent degrade /
    mixed, with failover on or frozen), the jitted wave program and the
    compacting host reference produce bit-identical routes, responses,
    costs and fault evidence — injection is drawn once host-side
    (counter-based hashing keyed on the *original* plan cell), so both
    planes consume the same grid and the jit-vs-reference equivalence pin
    extends to faulted runs;
  * the zero-fault path is bit-identical to a policy-free router — an
    attached-but-inactive FaultPolicy adds nothing, and flipping fault
    schedules between batches causes zero wave-program recompiles (the
    failover gather rides the compiled program as data, never as a static
    shape);
  * a fully-failed plan degrades gracefully: no crash, zero spend, an
    abstain-style prediction from the empty belief, failures counted;
  * failure evidence folds into the online estimator (zero-success
    attempts), the Wilson drift gate replans exactly the clusters that
    observed the failures, and probe traffic readmits a recovered arm.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.distributed.fault import (
    FAULT_DEGRADE,
    FAULT_ERROR,
    FAULT_TIMEOUT,
    ArmFaultSpec,
    FaultPolicy,
    failover_gather,
)
from repro.serving import BatchScheduler, OracleArm, PoolEngine, ThriftRouter


@dataclasses.dataclass
class TabularArm:
    """Deterministic arm: response to query j is the precomputed resp[j]."""

    name: str
    cost: float
    resp: np.ndarray

    def classify_batch(self, queries) -> np.ndarray:
        return self.resp[np.asarray(queries, np.int64)]

    def latency_s(self, batch: int) -> float:
        return 1e-6 * self.cost * batch


def _tabular_pool(K=4, L=8, clusters=5, B=96, seed=3, failover=True):
    wl = OracleWorkload(num_classes=K, num_clusters=clusters, num_arms=L, seed=seed)
    T, emb, _ = wl.response_table(60 * clusters, seed=seed + 1)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(seed + 2)
    qcid, qemb, qlab = wl.sample_queries(B, rng)
    R = np.stack(
        [
            wl.invoke_batch(a, qcid, qlab, np.random.default_rng(seed + 100 + a))
            for a in range(L)
        ]
    )
    engine = PoolEngine(
        [TabularArm(f"t{a}", float(wl.costs[a]), R[a]) for a in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K, failover=failover)
    return est, engine, router, qemb, qlab


def _budget(engine):
    return float(np.quantile(engine.costs, 0.8) * 3)


def _early_arm(router, qemb, budget):
    """The arm most batches invoke at wave 0 — faulting it guarantees the
    injected failures are actually *attempted* (an arm past every row's
    Prop. 4 stop produces no fault evidence, correctly)."""
    res = router.route_batch(np.arange(qemb.shape[0]), qemb, budget)
    first = res.schedule[:, 0]
    return int(np.bincount(first[first >= 0]).argmax())


def _assert_planes_equal(tag, rj, rr):
    for f in ("predictions", "schedule", "responses", "invoked",
              "arm_query_counts", "stop_waves", "clusters"):
        np.testing.assert_array_equal(
            getattr(rj, f), getattr(rr, f), err_msg=f"{tag}:{f}"
        )
    np.testing.assert_allclose(
        rj.costs, rr.costs, rtol=1e-15, atol=0, err_msg=f"{tag}:costs"
    )
    assert rj.waves == rr.waves, (tag, rj.waves, rr.waves)
    if rj.fault_codes is not None or rr.fault_codes is not None:
        for f in ("fault_schedule", "fault_codes", "arm_fault_counts"):
            np.testing.assert_array_equal(
                getattr(rj, f), getattr(rr, f), err_msg=f"{tag}:{f}"
            )


# ---------------------------------------------------------------------------
# Fault matrix: each kind x {failover, frozen} x {jit, reference}
# ---------------------------------------------------------------------------

FAULT_MATRIX = [
    ("timeout", {0: dict(timeout=0.5)}),
    ("error", {0: dict(error=0.7), 1: dict(error=0.3)}),
    ("degrade", {0: dict(degrade=0.6)}),
    ("mixed", {0: dict(timeout=0.3, degrade=0.2), 1: dict(error=0.4),
               2: dict(timeout=0.2, error=0.2)}),
]


@pytest.mark.parametrize("failover", [True, False], ids=["failover", "frozen"])
@pytest.mark.parametrize("kind,rates", FAULT_MATRIX)
def test_jit_matches_reference_under_faults(kind, rates, failover):
    """Bit-equivalence of the two data planes under every fault schedule.
    Rates are keyed by *plan position* (0 = most-invoked wave-0 arm), so
    the faults land on arms the wavefront actually attempts."""
    est, engine, router, qemb, qlab = _tabular_pool(failover=failover)
    budget = _budget(engine)
    order = np.argsort(-np.bincount(
        router.route_batch(np.arange(96), qemb, budget).schedule[:, 0].clip(0),
        minlength=len(engine.arms),
    ))
    policy = FaultPolicy(len(engine.arms), 4, seed=7)
    for pos, kw in rates.items():
        policy.set_arm(int(order[pos]), **kw)
    engine.fault_policy = policy

    rj = router.route_batch(np.arange(96), qemb, budget)
    rr = router.route_batch_reference(np.arange(96), qemb, budget)
    _assert_planes_equal(f"{kind}/{failover}", rj, rr)
    assert rj.fault_codes is not None
    if kind != "degrade":
        # the injected failures really were attempted and attributed
        assert rj.arm_fault_counts.sum() > 0
        hit = np.flatnonzero(rj.arm_fault_counts)
        injected = {int(order[p]) for p in rates}
        assert set(hit.tolist()) <= injected
    if failover:
        # failover never invokes a failed cell: every invoked response is a
        # real class and spend only covers arms that answered
        assert (rj.responses[rj.invoked] >= 0).all()


def test_heterogeneous_budgets_under_faults():
    """The fault grid + failover gather respect per-row budget groups."""
    est, engine, router, qemb, qlab = _tabular_pool()
    rng = np.random.default_rng(11)
    budgets = rng.choice(np.quantile(engine.costs, [0.4, 0.8]) * 2.5, size=96)
    hot = _early_arm(router, qemb, float(budgets.max()))
    policy = FaultPolicy(len(engine.arms), 4, seed=13)
    policy.set_arm(hot, timeout=0.4, degrade=0.1)
    engine.fault_policy = policy
    rj = router.route_batch(np.arange(96), qemb, budgets)
    rr = router.route_batch_reference(np.arange(96), qemb, budgets)
    _assert_planes_equal("hetero", rj, rr)


# ---------------------------------------------------------------------------
# Zero-fault path: bit-identical to the policy-free router
# ---------------------------------------------------------------------------


def test_zero_fault_bit_identical_to_policy_free():
    """An attached FaultPolicy with all-zero rates changes nothing, on
    either plane: same predictions, responses, schedules and exact costs
    as a router that never heard of fault injection."""
    est_a, engine_a, router_a, qemb, _ = _tabular_pool()
    est_b, engine_b, router_b, _, _ = _tabular_pool()
    engine_b.fault_policy = FaultPolicy(len(engine_b.arms), 4, seed=7)
    budget = _budget(engine_a)
    base_j = router_a.route_batch(np.arange(96), qemb, budget)
    base_r = router_a.route_batch_reference(np.arange(96), qemb, budget)
    z_j = router_b.route_batch(np.arange(96), qemb, budget)
    z_r = router_b.route_batch_reference(np.arange(96), qemb, budget)
    for base, z in ((base_j, z_j), (base_r, z_r)):
        np.testing.assert_array_equal(z.predictions, base.predictions)
        np.testing.assert_array_equal(z.schedule, base.schedule)
        np.testing.assert_array_equal(z.responses, base.responses)
        np.testing.assert_array_equal(z.invoked, base.invoked)
        np.testing.assert_allclose(z.costs, base.costs, rtol=0, atol=0)
        assert z.fault_codes is None and z.arm_fault_counts is None


def test_fault_flips_cause_zero_recompiles():
    """Compile-budget guard: the failover gather enters the wave program as
    data (src/valid arrays), never as a static argument — so flipping
    which arms fault, or turning injection off entirely, between batches
    of the same bucket shape is always an XLA cache hit."""
    from repro.analysis import CompileSentinel, compile_cache_size
    from repro.serving import router as router_mod

    est, engine, router, qemb, _ = _tabular_pool()
    budget = _budget(engine)
    hot = _early_arm(router, qemb, budget)
    sentinel = CompileSentinel({"wave": router_mod._wave_scan})
    router.route_batch(np.arange(96), qemb, budget)      # warm the bucket
    assert compile_cache_size(router_mod._wave_scan) >= 1
    sentinel.snapshot()
    policy = FaultPolicy(len(engine.arms), 4, seed=7)
    engine.fault_policy = policy
    schedules = [
        dict(timeout=0.5), dict(error=0.9), dict(degrade=0.7),
        dict(timeout=0.2, error=0.2, degrade=0.2),
    ]
    for kw in schedules:
        policy.clear()
        policy.set_arm(hot, **kw)
        policy.advance()                                  # new fault epoch
        router.route_batch(np.arange(96), qemb, budget)
    engine.fault_policy = None                            # and back off
    router.route_batch(np.arange(96), qemb, budget)
    sentinel.assert_no_new_compiles(
        detail="fault schedule flips within one (B, T) bucket"
    )


# ---------------------------------------------------------------------------
# Total outage: graceful degradation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("failover", [True, False], ids=["failover", "frozen"])
def test_fully_failed_plan_degrades_gracefully(failover):
    """Every arm down: no crash, nothing invoked, nothing charged, an
    abstain/fallback prediction from the empty belief, failures counted."""
    est, engine, router, qemb, qlab = _tabular_pool(failover=failover)
    policy = FaultPolicy(len(engine.arms), 4, seed=7)
    policy.set_arms(range(len(engine.arms)), error=1.0)
    engine.fault_policy = policy
    budget = _budget(engine)
    rj = router.route_batch(np.arange(96), qemb, budget)
    rr = router.route_batch_reference(np.arange(96), qemb, budget)
    _assert_planes_equal("all-dead", rj, rr)
    assert (rj.costs == 0).all()
    assert not rj.invoked.any()
    assert (rj.predictions >= 0).all() and (rj.predictions < 4).all()
    assert rj.arm_fault_counts.sum() > 0
    assert rj.waves == 0

    # the scheduler path survives it too, and the stats see the failures
    sched = BatchScheduler(router, max_batch=32, feedback=True)
    blk = sched.submit_many(np.arange(96), qemb, budget)
    sched.drain()
    assert blk.done()
    assert (blk.costs == 0).all()
    assert sched.stats["degradation_failures"] > 0


# ---------------------------------------------------------------------------
# Degradation -> drift replan -> probe readmission
# ---------------------------------------------------------------------------


def _oracle_pool(K=4, C=4, L=12, hist=120, seed=3, arm_seed=11, est_seed=4):
    wl = OracleWorkload(num_classes=K, num_clusters=C, num_arms=L, seed=seed)
    T, emb, cid_h = wl.response_table(hist * C, seed=est_seed)
    est = SuccessProbEstimator(T, emb, cid_h)
    engine = PoolEngine(
        [OracleArm(f"a{i}", wl, i, seed=arm_seed) for i in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return wl, est, engine, router


def test_failures_drift_replan_only_observing_clusters_then_readmit():
    """A persistently erroring arm is replanned away by the existing Wilson
    drift gate — purely from failure evidence, no ground-truth label ever
    arrives — for exactly the clusters that observed the failures; after
    recovery, probe traffic readmits it."""
    wl, est, engine, router = _oracle_pool()
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    sched = BatchScheduler(router, max_batch=256, max_wait_s=0.0, feedback=True)
    rng = np.random.default_rng(5)

    cid, qemb, lab = wl.sample_queries(256, rng)
    res0 = router.route_batch(np.column_stack([cid, lab]), qemb, budget)
    first = res0.schedule[:, 0]
    hot = int(np.bincount(first[first >= 0]).argmax())
    # clusters whose plan leads with the failing arm = the observers
    observers = sorted(set(res0.clusters[first == hot].tolist()))
    others = [c for c in est.clusters if c not in observers]
    plans_before = {
        c: router.plans.plan(int(c), budget).order.copy() for c in est.clusters
    }
    p_before = {c: float(est.clusters[c].p_hat[hot]) for c in est.clusters}

    policy = FaultPolicy(len(engine.arms), 4, seed=9)
    policy.set_arm(hot, error=0.95)
    engine.fault_policy = policy
    for _ in range(3):
        cid, qemb, lab = wl.sample_queries(256, rng)
        sched.submit_many(np.column_stack([cid, lab]), qemb, budget)
        sched.drain()
        policy.advance()
    sched.apply_feedback()    # fold any evidence still pending

    st = sched.stats
    assert st["degradation_failures"] > 0
    assert st["feedback_drifts"] >= 1
    # only clusters that observed failures went plan-visible...
    drifted = [int(c) for c in est.clusters if est.clusters[c].version > 0]
    assert drifted and set(drifted) <= set(int(c) for c in observers)
    assert all(est.clusters[c].version == 0 for c in others)
    # ...and their fresh plans demote the failing arm off the wavefront
    # head (its collapsed estimate may keep it as a late fallback), while
    # the non-observing clusters' plans stayed hot and unchanged
    for c in drifted:
        assert router.plans.plan(c, budget).order[0] != hot
        assert est.clusters[c].p_hat[hot] < p_before[c] - 0.2
    for c in others:
        np.testing.assert_array_equal(
            router.plans.plan(int(c), budget).order, plans_before[c]
        )

    # --- recovery: arm healthy again, probes feed it labeled successes ----
    engine.fault_policy = None
    sched.feedback.probe_rate = 1.0
    p_collapsed = {c: est.clusters[c].p_hat[hot] for c in drifted}
    for _ in range(6):
        cid, qemb, lab = wl.sample_queries(256, rng)
        blk = sched.submit_many(np.column_stack([cid, lab]), qemb, budget)
        sched.drain()
        sched.record_outcomes(blk.request_ids, lab)
    sched.apply_feedback()
    assert any(
        est.clusters[c].p_hat[hot] > p_collapsed[c] + 0.05 for c in drifted
    ), "probe traffic never re-raised the recovered arm's estimate"


def test_failover_gather_invariants():
    """Host-side gather: compaction is stable, skips exactly the failed
    cells, and is the identity when nothing failed."""
    rng = np.random.default_rng(0)
    # plan schedules are prefix-contiguous per column (arms then -1 padding)
    depth = rng.integers(1, 7, size=9)
    sched_T = np.where(np.arange(6)[:, None] < depth[None, :],
                       rng.integers(0, 5, (6, 9)), -1).astype(np.int64)
    failed = (rng.random((6, 9)) < 0.3) & (sched_T >= 0)
    src, valid, rank, navail = failover_gather(sched_T, failed)
    eff = np.where(valid, sched_T[src, np.arange(9)[None, :]], -1)
    for b in range(9):
        col = sched_T[:, b]
        want = col[(col >= 0) & ~failed[:, b]]
        got = eff[:, b][eff[:, b] >= 0]
        np.testing.assert_array_equal(got, want)   # order preserved
        assert navail[b] == want.size
    none = np.zeros_like(failed)
    src0, valid0, _, _ = failover_gather(sched_T, none)
    np.testing.assert_array_equal(
        np.where(valid0, sched_T[src0, np.arange(9)[None, :]], -1), sched_T
    )


def test_fault_policy_determinism_and_spec():
    """Same (seed, epoch, cell) -> same draw; advance() moves the epoch."""
    p1 = FaultPolicy(4, 3, seed=5)
    p2 = FaultPolicy(4, 3, seed=5)
    for p in (p1, p2):
        p.set_arm(2, timeout=0.3, degrade=0.2)
    sched_T = np.full((4, 16), 2, np.int64)
    np.testing.assert_array_equal(p1.grid_codes(sched_T), p2.grid_codes(sched_T))
    np.testing.assert_array_equal(p1.corrupt_grid(sched_T), p2.corrupt_grid(sched_T))
    before = p1.grid_codes(sched_T)
    p1.advance()
    assert not np.array_equal(p1.grid_codes(sched_T), before)
    assert p1.spec(2) == ArmFaultSpec(timeout=0.3, degrade=0.2)
    with pytest.raises(ValueError):
        ArmFaultSpec(timeout=0.9, error=0.2)   # rates sum > 1


# ---------------------------------------------------------------------------
# Fault plane through the R-replica serving front-end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,rates", FAULT_MATRIX)
def test_replica_set_serves_through_faults(kind, rates):
    """Every fault schedule, served through an R=3 ReplicaSet: the stream
    completes with failover'd (non-abstain) predictions, the failure
    evidence reaches the per-replica degradation trackers, and a follow-up
    feedback fold replans exactly the drift-gated clusters — the same
    pipeline the single-scheduler fault tests pin, now across sharded
    admission and fused dispatch.

    (Bit-identity with the unfused run is deliberately NOT asserted at
    R>1: fault draws hash on the row index within the dispatched batch,
    so fusing changes the draws — see the replica module docstring.)"""
    from repro.serving import ReplicaSet

    est, engine, router, qemb, qlab = _tabular_pool()
    budget = _budget(engine)
    hot = _early_arm(router, qemb, budget)
    B = qemb.shape[0]
    policy = FaultPolicy(len(engine.arms), 4, seed=11)
    order = np.argsort(-np.bincount(
        router.route_batch(np.arange(B), qemb, budget).schedule[:, 0].clip(0),
        minlength=len(engine.arms),
    ))
    for pos, kw in rates.items():
        policy.set_arm(int(order[pos]), **kw)
    engine.fault_policy = policy
    try:
        rset = ReplicaSet(router, replicas=3, max_batch=16, max_wait_s=0.0,
                          feedback=True)
        blk = rset.submit_many(np.arange(B), qemb, budget)
        rset.drain()
        assert blk.done()
        assert (blk.predictions >= 0).all()        # failover kept serving
        st = rset.stats
        assert st["completed"] == B
        if kind != "degrade":                      # degrades aren't failures
            assert st["degradation_failures"] > 0, kind
        assert st["degradation_routes"] > 0
        assert rset.record_outcomes(blk.request_ids, qlab) == B
        report = rset.apply_feedback()
        assert report.labels == B
    finally:
        engine.fault_policy = None
