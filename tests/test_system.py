"""End-to-end behaviour: train a small heterogeneous pool of REAL JAX models
on the token classification task, calibrate success probabilities from a
historical split, then serve queries through the ThriftLLM router — the
full Figure-1 pipeline of the paper on live models.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.estimation import SuccessProbEstimator
from repro.data import make_token_task
from repro.models import LM, ModelConfig
from repro.serving import LMArm, PoolEngine, ThriftRouter
from repro.training import OptimizerConfig, init_train_state, make_train_step

K = 4
SEQ = 32
VOCAB = 64


def _make_arm(name, d_model, layers, steps, data, seed):
    cfg = ModelConfig(
        name=name, family="dense", num_layers=layers, d_model=d_model,
        num_heads=4, num_kv_heads=2, d_ff=2 * d_model, vocab_size=VOCAB,
        dtype="float32", remat=False, tie_embeddings=True,
    )
    model = LM(cfg)
    params, opt = init_train_state(model, jax.random.key(seed))
    step = jax.jit(make_train_step(model, OptimizerConfig(lr=3e-3, warmup_steps=10)))
    toks = data["tokens"]
    n = toks.shape[0]
    bs = 16
    for s in range(steps):
        i = (s * bs) % (n - bs)
        batch = {"tokens": jnp.asarray(toks[i : i + bs])}
        params, opt, m = step(params, opt, batch)
    return LMArm(name, model, params, data["class_token_ids"], tokens_per_query=SEQ)


@pytest.fixture(scope="module")
def trained_pool():
    data = make_token_task(K, SEQ, VOCAB, n=512, seed=0)
    # heterogeneous capacities/training -> heterogeneous accuracy & price
    arms = [
        _make_arm("tiny", 32, 1, 40, data, 1),
        _make_arm("small", 48, 2, 80, data, 2),
        _make_arm("base", 64, 2, 160, data, 3),
    ]
    return data, arms


def test_end_to_end_train_calibrate_route(trained_pool):
    data, arms = trained_pool
    engine = PoolEngine(arms)

    # --- calibrate on a held-out historical split
    hist = make_token_task(K, SEQ, VOCAB, n=256, seed=1)
    T = np.zeros((256, len(arms)))
    for a, arm in enumerate(arms):
        preds = arm.classify_batch(hist["tokens"])
        T[:, a] = preds == hist["labels"]
    acc = T.mean(axis=0)
    # bigger arms should genuinely be better (trained longer/larger)
    assert acc[-1] > acc[0], acc
    assert arms[-1].cost > arms[0].cost

    emb = np.stack([np.bincount(t, minlength=VOCAB) for t in hist["tokens"]]).astype(float)
    est = SuccessProbEstimator(T, emb, np.zeros(256, np.int64))

    router = ThriftRouter(engine, est, num_classes=K)
    test = make_token_task(K, SEQ, VOCAB, n=128, seed=2)
    temb = np.stack([np.bincount(t, minlength=VOCAB) for t in test["tokens"]]).astype(float)

    budget = float(engine.costs.sum())  # generous: full ensemble affordable
    res = router.route_batch(test["tokens"], temb, budget)
    ens_acc = (res.predictions == test["labels"]).mean()
    assert (res.costs <= budget + 1e-15).all()
    # ensemble >= best single arm accuracy - small slack
    assert ens_acc >= max(acc) - 0.08, (ens_acc, acc)

    # tight budget: must still answer, using cheap arms only
    tight = float(np.sort(engine.costs)[0]) * 1.5
    res_t = router.route_batch(test["tokens"], temb, tight)
    assert (res_t.costs <= tight + 1e-15).all()
    acc_t = (res_t.predictions == test["labels"]).mean()
    assert acc_t > 1.0 / K  # far better than chance even at minimum budget


def test_training_reduces_loss():
    data = make_token_task(K, SEQ, VOCAB, n=256, seed=5)
    cfg = ModelConfig(
        name="t", family="dense", num_layers=2, d_model=48, num_heads=4,
        num_kv_heads=2, d_ff=96, vocab_size=VOCAB, dtype="float32",
        remat=False, tie_embeddings=True,
    )
    model = LM(cfg)
    params, opt = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-2, warmup_steps=5, total_steps=200)))
    losses = []
    for s in range(100):
        i = (s * 16) % 240
        batch = {"tokens": jnp.asarray(data["tokens"][i : i + 16])}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    # most body tokens are iid noise (irreducible ~log V), so assert an
    # absolute drop of the learnable component rather than a ratio
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.25
