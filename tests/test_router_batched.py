"""Batched wavefront router (jitted + compacting) vs per-query references.

Three implementations must agree exactly on deterministic pools:
``route_batch`` (the on-device jitted scan), ``route_batch_reference`` (the
compacting host wavefront) and a loop calling ``adaptive_invoke`` once per
query — identical predictions, per-query costs and arms-used, across
heterogeneous (K, budget, cluster) mixes. Determinism comes from tabular
arms: each arm's response to query j is precomputed, so invocation order,
batching and speculative response gathering cannot change what any arm
answers.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.belief import tie_break_argmax
from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.core.selection import adaptive_invoke
from repro.core.types import SelectionResult
from repro.data import OracleWorkload
from repro.serving import BatchScheduler, PoolEngine, Request, ThriftRouter


@dataclasses.dataclass
class TabularArm:
    """Deterministic arm: response to query j is the precomputed resp[j]."""

    name: str
    cost: float
    resp: np.ndarray

    def classify_batch(self, queries) -> np.ndarray:
        return self.resp[np.asarray(queries, np.int64)]

    def latency_s(self, batch: int) -> float:
        return 1e-6 * self.cost * batch


def _make_pool(K, L, clusters, B, seed):
    wl = OracleWorkload(num_classes=K, num_clusters=clusters, num_arms=L, seed=seed)
    T, emb, _ = wl.response_table(60 * clusters, seed=seed + 1)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(seed + 2)
    qcid, qemb, qlab = wl.sample_queries(B, rng)
    R = np.stack(
        [
            wl.invoke_batch(a, qcid, qlab, np.random.default_rng(seed + 100 + a))
            for a in range(L)
        ]
    )
    engine = PoolEngine(
        [TabularArm(f"t{a}", float(wl.costs[a]), R[a]) for a in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return wl, est, engine, router, qemb, R


def _reference(router, est, R, qemb, budgets, K):
    """Per-query adaptive_invoke loop — the semantics the batch must match."""
    B = qemb.shape[0]
    cids = est.lookup_batch(qemb)
    preds, costs, planned, arms = [], [], [], []
    for j in range(B):
        p = est.clusters[int(cids[j])].p_hat
        sel = router.selector.select(p, K, float(budgets[j]))
        inv = adaptive_invoke(
            list(sel.chosen), p, K, lambda a: int(R[a, j]),
            costs=router.engine.costs,
        )
        preds.append(inv.prediction)
        costs.append(inv.cost)
        planned.append(inv.planned_cost)
        arms.append([int(a) for a in inv.used])
    return np.asarray(preds), np.asarray(costs), np.asarray(planned), arms


MIXES = [
    # (K, L, clusters, B, seed, budget quantiles used per query)
    (4, 8, 5, 96, 3, [0.5]),
    (2, 6, 3, 64, 7, [0.3, 0.8]),
    (5, 12, 6, 128, 11, [0.2, 0.55, 0.9]),
]


@pytest.mark.parametrize("K,L,clusters,B,seed,quantiles", MIXES)
def test_batched_matches_sequential_reference(K, L, clusters, B, seed, quantiles):
    wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
    rng = np.random.default_rng(seed + 5)
    levels = np.quantile(engine.costs, quantiles) * 2.5
    budgets = rng.choice(levels, size=B)  # heterogeneous budgets in one batch

    res = router.route_batch(np.arange(B), qemb, budgets)
    preds, costs, planned, arms = _reference(router, est, R, qemb, budgets, K)

    np.testing.assert_array_equal(res.predictions, preds)
    np.testing.assert_allclose(res.costs, costs, rtol=1e-12, atol=0)
    np.testing.assert_allclose(res.planned_costs, planned, rtol=1e-12, atol=0)
    assert res.arms_used == arms
    # arm accounting is consistent with the per-query trace
    total = np.zeros(L, np.int64)
    for a_list in arms:
        total[a_list] += 1
    np.testing.assert_array_equal(res.arm_query_counts, total)


@pytest.mark.parametrize("K,L,clusters,B,seed,quantiles", MIXES)
def test_jitted_matches_compacting_reference(K, L, clusters, B, seed, quantiles):
    """route_batch (jitted scan) == route_batch_reference (compacting loop)
    on every output, including the invoked mask and arm accounting."""
    wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
    rng = np.random.default_rng(seed + 5)
    levels = np.quantile(engine.costs, quantiles) * 2.5
    budgets = rng.choice(levels, size=B)
    res = router.route_batch(np.arange(B), qemb, budgets)
    ref = router.route_batch_reference(np.arange(B), qemb, budgets)
    np.testing.assert_array_equal(res.predictions, ref.predictions)
    np.testing.assert_allclose(res.costs, ref.costs, rtol=1e-12, atol=0)
    np.testing.assert_allclose(res.planned_costs, ref.planned_costs, rtol=1e-12, atol=0)
    np.testing.assert_array_equal(res.invoked, ref.invoked)
    np.testing.assert_array_equal(res.arm_query_counts, ref.arm_query_counts)
    assert res.arms_used == ref.arms_used
    assert res.waves == ref.waves


@pytest.mark.parametrize("K,L,clusters,B,seed,quantiles", MIXES[:1])
def test_reference_route_batch_agrees(K, L, clusters, B, seed, quantiles):
    """All three paths agree: jitted == compacting == per-query sequential."""
    wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    res = router.route_batch(np.arange(B), qemb, budget)
    ref = router.route_batch_reference(np.arange(B), qemb, budget)
    seq = router.route_batch_sequential(np.arange(B), qemb, budget)
    for other in (ref, seq):
        np.testing.assert_array_equal(res.predictions, other.predictions)
        np.testing.assert_allclose(res.costs, other.costs, rtol=1e-12, atol=0)
        assert res.arms_used == other.arms_used


def test_jit_waves_false_dispatches_to_reference():
    K, L, clusters, B, seed = 4, 8, 5, 48, 3
    wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
    router_ref = ThriftRouter(engine, est, num_classes=K, jit_waves=False)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    res = router.route_batch(np.arange(B), qemb, budget)
    res_ref = router_ref.route_batch(np.arange(B), qemb, budget)
    np.testing.assert_array_equal(res.predictions, res_ref.predictions)
    np.testing.assert_allclose(res.costs, res_ref.costs, rtol=1e-12, atol=0)
    assert res.arms_used == res_ref.arms_used


def test_donate_buffers_off_is_bit_identical():
    """PR 10: the serving default donates the staged wave tables
    (`_wave_scan`); `donate_buffers=False` routes through the nodonate
    twin. Both must produce bitwise the same routes — donation is a
    storage contract, never a numerics knob."""
    K, L, clusters, B, seed = 4, 8, 5, 64, 3
    wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
    assert router.donate_buffers            # serving default
    router_nd = ThriftRouter(
        engine, est, num_classes=K, donate_buffers=False
    )
    rng = np.random.default_rng(seed + 5)
    budgets = rng.choice(np.quantile(engine.costs, [0.3, 0.8]) * 2.5, size=B)
    res = router.route_batch(np.arange(B), qemb, budgets)
    res_nd = router_nd.route_batch(np.arange(B), qemb, budgets)
    np.testing.assert_array_equal(res.predictions, res_nd.predictions)
    np.testing.assert_allclose(res.costs, res_nd.costs, rtol=0, atol=0)
    np.testing.assert_allclose(
        res.planned_costs, res_nd.planned_costs, rtol=0, atol=0
    )
    assert res.arms_used == res_nd.arms_used


def test_kernel_backend_matches_on_jitted_and_reference_paths():
    """use_kernel=True: the Pallas kernel dispatched from inside the jitted
    scan agrees with the kernel-backed compacting loop and the numpy path."""
    K, L, clusters, B, seed = 5, 12, 6, 96, 11
    wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
    router_k = ThriftRouter(engine, est, num_classes=K, use_kernel=True)
    rng = np.random.default_rng(seed + 5)
    budgets = rng.choice(np.quantile(engine.costs, [0.3, 0.8]) * 2.5, size=B)
    res_k = router_k.route_batch(np.arange(B), qemb, budgets)
    ref_k = router_k.route_batch_reference(np.arange(B), qemb, budgets)
    res = router.route_batch(np.arange(B), qemb, budgets)
    np.testing.assert_array_equal(res_k.predictions, ref_k.predictions)
    np.testing.assert_allclose(res_k.costs, ref_k.costs, rtol=1e-12, atol=0)
    assert res_k.arms_used == ref_k.arms_used
    np.testing.assert_array_equal(res_k.predictions, res.predictions)
    assert res_k.arms_used == res.arms_used


def test_kernel_backend_matches_numpy_backend():
    K, L, clusters, B, seed = 4, 8, 5, 64, 3
    wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
    router_k = ThriftRouter(engine, est, num_classes=K, use_kernel=True)
    budget = float(np.quantile(engine.costs, 0.6)) * 2
    res = router.route_batch(np.arange(B), qemb, budget)
    res_k = router_k.route_batch(np.arange(B), qemb, budget)
    np.testing.assert_array_equal(res.predictions, res_k.predictions)
    np.testing.assert_allclose(res.costs, res_k.costs, rtol=1e-12, atol=0)
    assert res.arms_used == res_k.arms_used


class TestCompileBudget:
    """CompileSentinel: the wave program's XLA cache is keyed only by
    bucket shapes, so steady-state traffic never recompiles."""

    def test_route_batch_content_change_does_not_recompile(self):
        from repro.analysis import CompileSentinel, compile_cache_size
        from repro.serving import router as router_mod

        K, L, clusters, B, seed = 4, 8, 5, 96, 3
        wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
        levels = np.quantile(engine.costs, [0.3, 0.8]) * 2.5
        rng = np.random.default_rng(seed + 5)
        sentinel = CompileSentinel({"wave": router_mod._wave_scan})
        router.route_batch(np.arange(B), qemb, rng.choice(levels, size=B))
        # the program is in cache (earlier tests may have warmed this
        # bucket already, so assert the absolute population, not the delta)
        assert compile_cache_size(router_mod._wave_scan) >= 1
        sentinel.snapshot()
        # fresh queries and budget assignments, identical bucket shapes:
        # zero new XLA programs
        for s in (101, 102, 103):
            rng2 = np.random.default_rng(s)
            _, qemb2, _ = wl.sample_queries(B, rng2)
            router.route_batch(
                np.arange(B), qemb2, rng2.choice(levels, size=B)
            )
        sentinel.assert_no_new_compiles(
            detail="route_batch content change within one (B, T) bucket"
        )

    def test_route_batch_bucket_sharing_across_batch_sizes(self):
        from repro.analysis import CompileSentinel
        from repro.serving import router as router_mod

        K, L, clusters, B, seed = 4, 8, 5, 96, 3
        wl, est, engine, router, qemb, R = _make_pool(K, L, clusters, B, seed)
        budget = float(np.quantile(engine.costs, 0.6)) * 2
        sentinel = CompileSentinel({"wave": router_mod._wave_scan})
        # 40 and 48 quantise to the same wave bucket: one compile serves both
        router.route_batch(np.arange(40), qemb[:40], budget)
        after_first = sentinel.compiles("wave")
        router.route_batch(np.arange(48), qemb[:48], budget)
        assert sentinel.compiles("wave") == after_first, (
            "B=40 and B=48 share a bucket; the second size must be a "
            "cache hit"
        )
        sentinel.assert_within(
            {"wave": 2}, detail="declared wave-bucket budget for one pool"
        )


def _symmetric_router(p_sym=0.8, N=200):
    """Two equal-cost, equal-p arms that always vote class 0 and class 1:
    every routed query ends in an exact belief tie."""
    emb = np.zeros((N, 4))
    table = np.zeros((N, 2))
    table[: int(N * p_sym)] = 1.0  # p_hat exactly p_sym for both arms
    est = SuccessProbEstimator(table, emb, np.zeros(N, np.int64))
    B = 64
    engine = PoolEngine(
        [
            TabularArm("zero", 1.0, np.zeros(B, np.int64)),
            TabularArm("one", 1.0, np.ones(B, np.int64)),
        ]
    )
    router = ThriftRouter(engine, est, num_classes=2)
    budget = 2.0
    # pin the selection to both arms so the wavefront really invokes both
    # (p_sym > 2/3 makes the empty-class belief positive, defeating early stop)
    cid = list(est.clusters)[0]
    p = est.clusters[cid].p_hat
    key = (np.round(np.asarray(p, np.float64), 12).tobytes(), 2, budget)
    router.selector._cache[key] = SelectionResult(
        chosen=np.asarray([0, 1], np.int64), xi_est=p_sym, cost=2.0, budget=budget
    )
    return router, np.zeros((B, 4)), budget, B


def test_tie_break_regression_symmetric_pool():
    """Seed bug: bare np.argmax biased every tied query to class 0."""
    router, qemb, budget, B = _symmetric_router()
    rng = np.random.default_rng(0)
    res = router.route_batch(np.arange(B), qemb, budget, rng=rng)
    assert all(len(a) == 2 for a in res.arms_used)  # both arms really invoked
    frac0 = float(np.mean(res.predictions == 0))
    assert 0.25 < frac0 < 0.75  # ~Binomial(64, 1/2); not systematically 0
    # deterministic mode stays reproducible: first-max tie break
    res_det = router.route_batch(np.arange(B), qemb, budget)
    assert (res_det.predictions == 0).all()


def test_tie_break_helper_scalar_and_batch():
    beliefs = np.array([[1.0, 1.0, 0.5], [0.2, 0.9, 0.9]])
    pred, ties = tie_break_argmax(beliefs)
    np.testing.assert_array_equal(pred, [0, 1])
    np.testing.assert_array_equal(ties, [2, 2])
    rng = np.random.default_rng(1)
    draws = [int(tie_break_argmax(beliefs[0], rng)[0]) for _ in range(300)]
    assert set(draws) == {0, 1}
    assert 0.4 < np.mean(draws) < 0.6


def test_scheduler_group_accounting_and_used_arm_latency():
    wl = OracleWorkload(num_classes=4, num_clusters=4, num_arms=8, seed=3)
    T, emb, _ = wl.response_table(400)
    assign, _ = kmeans(emb, 4, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    from repro.serving import OracleArm

    engine = PoolEngine([OracleArm(f"a{i}", wl, i, seed=11) for i in range(8)])
    router = ThriftRouter(engine, est, num_classes=4)
    sched = BatchScheduler(router, max_batch=16, max_wait_s=0.0)
    rng = np.random.default_rng(5)
    cid, qemb, lab = wl.sample_queries(16, rng)
    lo = float(np.quantile(engine.costs, 0.3)) * 2
    hi = float(np.quantile(engine.costs, 0.8)) * 2
    for i in range(16):
        sched.submit(
            Request(
                payload=(cid[i], lab[i]),
                embedding=qemb[i],
                budget=lo if i % 2 == 0 else hi,
            )
        )
    out = sched.flush()
    assert len(out) == 1
    batch, res = out[0]
    assert len(batch) == 16
    assert sched.stats["batches"] == 2       # two budget groups routed
    assert sched.stats["flushes"] == 1
    lat = sched.mitigator.history[-1]
    unused = res.arm_query_counts == 0
    assert (lat[unused] == 0.0).all()        # idle arms record no latency
    assert (lat[~unused] > 0.0).all()
    # per-query budgets enforced per group
    budgets = np.asarray([r.budget for r in batch])
    assert (res.costs <= budgets + 1e-12).all()
