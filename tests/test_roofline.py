"""Roofline accounting: HLO collective parser + analytic FLOP counter
validated against XLA cost_analysis on small *unrolled* configs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (
    analytic_flops,
    hlo_collective_bytes,
    model_flops,
    parse_hlo,
    roofline_terms,
    xla_cost_analysis,
    _shape_bytes,
)
from repro.models import LM, ModelConfig, ShapeConfig


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[]") == 1


def test_while_trip_count_scaling():
    """Collectives inside a lax.scan must be multiplied by the trip count."""

    def f10(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, None, length=10)
        return h.sum()

    def f20(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, None, length=20)
        return h.sum()

    from jax.sharding import NamedSharding, PartitionSpec as P

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 host device")
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    xs = NamedSharding(mesh, P(None, "model"))
    ws = NamedSharding(mesh, P("model", None))
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t10 = jax.jit(f10, in_shardings=(xs, ws)).lower(x, w).compile().as_text()
    t20 = jax.jit(f20, in_shardings=(xs, ws)).lower(x, w).compile().as_text()
    c10 = hlo_collective_bytes(t10)
    c20 = hlo_collective_bytes(t20)
    assert c10["unscoped_while"] == 0
    assert c20["all-reduce"] == pytest.approx(2 * c10["all-reduce"], rel=0.1)


def _tiny_cfg(**kw):
    base = dict(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32", remat=False,
        tie_embeddings=True,
    )
    base.update(kw)
    return ModelConfig(**base)


def test_analytic_flops_matches_xla_on_unrolled_model():
    """Validate the analytic counter against XLA's own FLOP count for a
    config small enough to inspect (forward pass, no scan undercounting:
    cost_analysis counts each scan body once, so compare per-layer)."""
    cfg = _tiny_cfg(num_layers=1)
    model = LM(cfg)
    shape = ShapeConfig("t", seq_len=128, global_batch=4, kind="prefill")

    def fwd(params, tokens):
        return model.forward(params, tokens)

    params = jax.eval_shape(model.init, jax.random.key(0))
    tok = jax.ShapeDtypeStruct((4, 128), jnp.int32)
    comp = jax.jit(fwd).lower(params, tok).compile()
    xla_fl = xla_cost_analysis(comp)["flops"]
    ours = analytic_flops(cfg, shape)["fwd"]
    # XLA counts only matmul/conv flops by default; ours adds elementwise.
    assert ours == pytest.approx(xla_fl, rel=0.35), (ours, xla_fl)


def test_model_flops_train_is_6nd():
    cfg = _tiny_cfg()
    shape = ShapeConfig("t", seq_len=64, global_batch=2, kind="train")
    assert model_flops(cfg, shape) == 6.0 * cfg.active_param_count() * 128


def test_roofline_terms_bottleneck():
    hw = {"peak_flops": 100.0, "hbm_bw": 10.0, "ici_bw": 1.0}
    t = roofline_terms(flops=1000.0, hbm_bytes=10.0, collective_bytes=0.1, chips=1, hw=hw)
    assert t["bottleneck"] == "compute_s"
    assert t["compute_s"] == pytest.approx(10.0)
    t2 = roofline_terms(flops=1.0, hbm_bytes=1000.0, collective_bytes=0.0, chips=1, hw=hw)
    assert t2["bottleneck"] == "memory_s"
