"""Per-architecture smoke tests (reduced configs) + training substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import LM, SHAPES, shape_applicable
from repro.training import CompressionConfig, OptimizerConfig, init_train_state, make_train_step

ARCHS = list_archs()


def _batch_for(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    lf = cfg.frontend_len if cfg.frontend != "none" else 0
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - lf)), jnp.int32)}
    if lf:
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, lf, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    logits = model.forward(
        params, batch["tokens"], batch.get("frontend_embeds")
    )
    assert logits.shape[0] == batch["tokens"].shape[0]
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = LM(cfg)
    params, opt = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-3, warmup_steps=1)))
    batch = _batch_for(cfg)
    params2, opt2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_matches_full_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.num_experts:  # capacity drops break exact equality at low factor
        cfg = type(cfg)(**{**cfg.__dict__, "expert_capacity_factor": 8.0})
    model = LM(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch_for(cfg, S=20)
    tokens = batch["tokens"]
    fe = batch.get("frontend_embeds")
    logits = model.forward(params, tokens, fe)
    _, cache = jax.jit(model.prefill)(params, tokens[:, :-1], fe)
    dl, cache2 = jax.jit(model.decode_step)(params, cache, tokens[:, -1:])
    err = float(jnp.max(jnp.abs(dl - logits[:, -1])))
    assert err < 2e-4, (arch, err)
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


def test_all_full_configs_have_positive_params():
    for arch in ARCHS:
        cfg = get_config(arch)
        n = cfg.param_count()
        assert n > 1e8, arch
        assert cfg.active_param_count() <= n


def test_shape_applicability_matrix():
    """long_500k only for sub-quadratic archs; 34 runnable LM cells + 6 skips."""
    runnable = sum(
        shape_applicable(get_config(a), s) for a in ARCHS for s in SHAPES.values()
    )
    assert runnable == 33, runnable  # 40 cells - 7 full-attention long_500k skips


def test_microbatch_grad_equivalence():
    """Grad accumulation over microbatches == single-batch gradients."""
    cfg = get_smoke_config("smollm-135m")
    cfg1 = type(cfg)(**{**cfg.__dict__, "num_microbatches": 1})
    cfg2 = type(cfg)(**{**cfg.__dict__, "num_microbatches": 2})
    m1, m2 = LM(cfg1), LM(cfg2)
    params, opt = init_train_state(m1, jax.random.key(0))
    batch = _batch_for(cfg, B=4)
    s1 = jax.jit(make_train_step(m1, OptimizerConfig(lr=1e-3)))
    s2 = jax.jit(make_train_step(m2, OptimizerConfig(lr=1e-3)))
    p1, _, _ = s1(params, opt, batch)
    p2, _, _ = s2(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_gradient_compression_error_feedback():
    cfg = get_smoke_config("smollm-135m")
    model = LM(cfg)
    comp = CompressionConfig(codec="int8", error_feedback=True)
    params, opt = init_train_state(model, jax.random.key(0), comp)
    assert "residuals" in opt
    step = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-3), comp))
    batch = _batch_for(cfg)
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    res_norm = float(m["compression_err_norm"])
    assert res_norm >= 0


def test_loss_chunking_equivalence():
    cfg = get_smoke_config("smollm-135m")
    cfg_c = type(cfg)(**{**cfg.__dict__, "loss_chunk": 8})
    m0, mc = LM(cfg), LM(cfg_c)
    params = m0.init(jax.random.key(0))
    batch = _batch_for(cfg, S=20)
    l0, _ = m0.loss(params, batch)
    lc, _ = mc.loss(params, batch)
    assert float(l0) == pytest.approx(float(lc), rel=1e-5)
