"""R-replica serving plane (repro/serving/replica.py).

The contracts pinned here, in order:

* **R=1 bit-identity.** ``ReplicaSet(router, replicas=1)`` on a stream with
  feedback (probes on), a multi-tenant cost ledger and mid-stream label
  folds produces byte-for-byte the BatchScheduler outputs: predictions,
  costs, stop waves, modes, request ids, arm totals, and every stats
  counter the baseline exposes.
* **Batch-composition invariance at R>1.** On a fault-free deterministic
  pool, fusing several workers' same-budget groups into one wave program
  (the single-device dispatch mode) cannot change any per-request output —
  fused R=4 and pump-driven heterogeneous R=2 streams bit-match a single
  baseline scheduler per request.
* **Shard-merged feedback.** Labels recorded through the replica plane and
  folded via export_shard -> merge_counts -> one central apply leave the
  estimator in exactly the single-log state (p_hat, arm counts, versions,
  drift set).
* **Fault plane at R>1.** Under an active FaultPolicy the set still
  completes, the ledger invariant ``spent + reserved <= limit`` holds per
  tenant, and the failure evidence reaches the degradation counters.
* **Compile budgets.** After ``prewarm_compile`` a replica stream causes
  zero new wave-program compiles (CompileSentinel), per replica and fused.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import (
    BatchScheduler,
    CostLedger,
    FaultPolicy,
    FeedbackLog,
    PoolEngine,
    ReplicaSet,
    Request,
    ThriftRouter,
)


@dataclasses.dataclass
class TabularArm:
    """Deterministic arm: response to query j is the precomputed resp[j]."""

    name: str
    cost: float
    resp: np.ndarray
    metered: bool = False

    def classify_batch(self, queries) -> np.ndarray:
        return self.resp[np.asarray(queries, np.int64)]

    def latency_s(self, batch: int) -> float:
        return 1e-6 * self.cost * batch


def _make_pool(K=4, L=8, clusters=5, B=96, seed=3):
    """A deterministic tabular pool; rebuilding with the same seed gives a
    bit-identical twin (the baseline side of every equivalence test)."""
    wl = OracleWorkload(num_classes=K, num_clusters=clusters, num_arms=L,
                       seed=seed)
    T, emb, _ = wl.response_table(60 * clusters, seed=seed + 1)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(seed + 2)
    qcid, qemb, qlab = wl.sample_queries(B, rng)
    R = np.stack(
        [
            wl.invoke_batch(a, qcid, qlab, np.random.default_rng(seed + 100 + a))
            for a in range(L)
        ]
    )
    engine = PoolEngine(
        [TabularArm(f"t{a}", float(wl.costs[a]), R[a]) for a in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return engine, router, qemb, qlab


def _budget(engine, q=0.8, mult=3.0):
    return float(np.quantile(engine.costs, q) * mult)


def _assert_block_equal(a, b):
    np.testing.assert_array_equal(a.predictions, b.predictions)
    np.testing.assert_array_equal(a.costs, b.costs)
    np.testing.assert_array_equal(a.stop_waves, b.stop_waves)
    np.testing.assert_array_equal(a.modes, b.modes)
    np.testing.assert_array_equal(a.request_ids, b.request_ids)
    np.testing.assert_array_equal(a.clusters, b.clusters)
    np.testing.assert_array_equal(a.planned_costs, b.planned_costs)


# ---------------------------------------------------------------------------
# R=1 equivalence: the whole contract, including control-plane counters
# ---------------------------------------------------------------------------


def test_r1_bit_identical_to_batch_scheduler():
    """ReplicaSet(replicas=1) IS a BatchScheduler: same outputs, same
    feedback folds (probe rng stream included), same ledger settlement,
    same stats counters on a 3-block multi-tenant stream with mid-stream
    label folds."""
    engine_a, router_a, qemb, qlab = _make_pool()
    engine_b, router_b, _, _ = _make_pool()
    budget = _budget(engine_a)
    B = qemb.shape[0]
    tenants = np.asarray(["acme", "zen", "acme"], object)

    def led():
        ledger = CostLedger(num_arms=len(engine_a.arms))
        ledger.set_limit("acme", budget * B)       # roomy: admits everything
        ledger.set_limit("zen", budget * B)
        return ledger

    rset = ReplicaSet(
        router_a, replicas=1, max_batch=16, max_wait_s=0.0,
        feedback=FeedbackLog(router_a.estimator, probe_rate=0.2, probe_seed=5),
        ledger=led(),
    )
    base = BatchScheduler(
        router_b, max_batch=16, max_wait_s=0.0,
        feedback=FeedbackLog(router_b.estimator, probe_rate=0.2, probe_seed=5),
        ledger=led(),
    )
    assert rset.fuse_waves is False                # never fuses at R=1

    cuts = [(0, 32), (32, 64), (64, B)]
    for sched in (rset, base):
        for k, (s, e) in enumerate(cuts):
            blk = sched.submit_many(
                np.arange(s, e), qemb[s:e], budget, tenant=tenants[k]
            )
            sched.drain()
            sched.record_outcomes(blk.request_ids, qlab[s:e])
            if k < len(cuts) - 1:
                continue
            sched.apply_feedback()                 # fold the tail too

    # rebuild both streams' blocks through one more pass for comparison
    rset_blocks, base_blocks = [], []
    for sched, out in ((rset, rset_blocks), (base, base_blocks)):
        for s, e in cuts:
            out.append(sched.submit_many(np.arange(s, e), qemb[s:e], budget))
        sched.drain()
    for a, b in zip(rset_blocks, base_blocks):
        _assert_block_equal(a, b)

    np.testing.assert_array_equal(rset.arm_query_totals, base.arm_query_totals)
    rstats = rset.stats
    for k, v in base.stats.items():                # rset adds replica_* keys
        assert rstats[k] == v, f"stats[{k}]: replica {rstats[k]} != base {v}"
    assert rstats["replicas"] == 1
    assert rstats["replica_fused"] == 0 and rstats["replica_spills"] == 0
    lat = rset.latency_stats()
    assert lat["count"] == base.latency_stats()["count"]


def test_r1_submit_single_requests_match():
    engine_a, router_a, qemb, _ = _make_pool(B=48)
    engine_b, router_b, _, _ = _make_pool(B=48)
    budget = _budget(engine_a)
    rset = ReplicaSet(router_a, replicas=1, max_batch=16, max_wait_s=0.0)
    base = BatchScheduler(router_b, max_batch=16, max_wait_s=0.0)
    fa = [rset.submit(Request(payload=j, embedding=qemb[j], budget=budget))
          for j in range(48)]
    fb = [base.submit(Request(payload=j, embedding=qemb[j], budget=budget))
          for j in range(48)]
    rset.drain()
    base.drain()
    for x, y in zip(fa, fb):
        rx, ry = x.result(), y.result()
        assert (rx.prediction, rx.cost, rx.stop_wave, rx.mode) == \
               (ry.prediction, ry.cost, ry.stop_wave, ry.mode)


# ---------------------------------------------------------------------------
# R>1: fused / sharded dispatch is batch-composition invariant per request
# ---------------------------------------------------------------------------


def test_r4_fused_matches_baseline_per_request():
    """On a fault-free deterministic pool, per-query routing does not
    depend on which rows share a wave program: the fused R=4 outputs equal
    a single baseline scheduler's, row for row."""
    engine_a, router_a, qemb, _ = _make_pool()
    engine_b, router_b, _, _ = _make_pool()
    budget = _budget(engine_a)
    B = qemb.shape[0]

    rset = ReplicaSet(router_a, replicas=4, max_batch=16, max_wait_s=0.0)
    assert rset.fuse_waves is True or len(__import__("jax").devices()) > 1
    blk = rset.submit_many(np.arange(B), qemb, budget)
    rset.drain()

    base = BatchScheduler(router_b, max_batch=B, max_wait_s=0.0)
    ref = base.submit_many(np.arange(B), qemb, budget)
    base.drain()

    np.testing.assert_array_equal(blk.predictions, ref.predictions)
    np.testing.assert_array_equal(blk.costs, ref.costs)
    np.testing.assert_array_equal(blk.stop_waves, ref.stop_waves)
    np.testing.assert_array_equal(rset.arm_query_totals, base.arm_query_totals)
    st = rset.stats
    assert st["completed"] == B
    if rset.fuse_waves:
        assert st["replica_fused"] >= 1           # fusion actually engaged
        assert st["replica_fused_rows"] <= B


def test_r2_hetero_budgets_pump_driven_matches():
    """Heterogeneous budgets, driven by pump() like a live front door:
    every request still gets its composition-invariant result, across
    budget-group splits, affinity shards and fusions."""
    engine_a, router_a, qemb, _ = _make_pool()
    engine_b, router_b, _, _ = _make_pool()
    B = qemb.shape[0]
    rng = np.random.default_rng(11)
    levels = np.quantile(engine_a.costs, [0.4, 0.8]) * 2.5
    budgets = rng.choice(levels, size=B)

    rset = ReplicaSet(router_a, replicas=2, max_batch=8, max_wait_s=0.0)
    blocks = []
    for s in range(0, B, 24):
        blocks.append(rset.submit_many(
            np.arange(s, min(s + 24, B)), qemb[s:s + 24], budgets[s:s + 24]
        ))
        rset.pump()
    rset.drain()
    assert all(b.done() for b in blocks)

    base = BatchScheduler(router_b, max_batch=B, max_wait_s=0.0)
    ref = base.submit_many(np.arange(B), qemb, budgets)
    base.drain()
    got_p = np.concatenate([b.predictions for b in blocks])
    got_c = np.concatenate([b.costs for b in blocks])
    np.testing.assert_array_equal(got_p, ref.predictions)
    np.testing.assert_array_equal(got_c, ref.costs)


def test_affinity_is_sticky_and_spill_caps_skew():
    """The same embedding always lands on the same replica; a block whose
    clusters all hash to one replica spills its tail to the least loaded."""
    engine, router, qemb, _ = _make_pool()
    budget = _budget(engine)
    rset = ReplicaSet(router, replicas=4, max_batch=16, max_wait_s=0.0)
    a1 = rset._assign(qemb, qemb.shape[0])
    a2 = rset._assign(qemb, qemb.shape[0])
    np.testing.assert_array_equal(a1, a2)          # stateless affinity
    # all rows from ONE cluster: affinity alone would pile them on one
    # replica; the home keeps its FIFO prefix up to the cap and the tail
    # spills to the least-loaded replica
    one = np.repeat(qemb[:1], 64, axis=0)
    home = int(rset._assign(one[:1], 1)[0])
    before = rset.spills
    assign = rset._assign(one, 64)
    cap = int(np.ceil(rset.spill_factor * 64 / 4))
    counts = np.bincount(assign, minlength=4)
    assert counts[home] == cap                     # prefix stays home
    assert rset.spills - before == 64 - cap        # tail spilled elsewhere
    assert (counts > 0).sum() >= 2
    blk = rset.submit_many(np.arange(64) % qemb.shape[0], one, budget)
    rset.drain()
    assert blk.done() and (blk.predictions >= 0).all()


def test_spill_multi_overflow_no_double_count_never_self_spill():
    """Regression: when SEVERAL replicas overflow in one block, each sheds
    exactly its own tail once — the spill counter equals the true excess
    (it used to double-count rows that landed on another over-cap home and
    were then re-spilled), every over-cap home ends exactly at cap, and no
    spilled row lands back on its own home."""
    engine, router, qemb, _ = _make_pool()
    rset = ReplicaSet(router, replicas=4, max_batch=16, max_wait_s=0.0,
                      spill_factor=1.0)
    # two embeddings with DISTINCT affinity homes, 32 rows each: both
    # homes overflow the cap = ceil(1.0 * 64 / 4) = 16 simultaneously
    homes = {int(rset._assign(qemb[i:i + 1], 1)[0]): i
             for i in range(qemb.shape[0])}
    (h1, i1), (h2, i2) = list(homes.items())[:2]
    assert h1 != h2
    emb = np.concatenate([np.repeat(qemb[i1:i1 + 1], 32, axis=0),
                          np.repeat(qemb[i2:i2 + 1], 32, axis=0)])
    before = rset.spills
    assign = rset._assign(emb, 64)
    cap = int(np.ceil(rset.spill_factor * 64 / 4))
    counts = np.bincount(assign, minlength=4)
    assert counts[h1] == cap and counts[h2] == cap   # prefixes stay home
    assert rset.spills - before == 64 - 2 * cap      # counted once each
    # the shed tails went to the two idle replicas, not each other's home
    tails = np.concatenate([assign[:32][assign[:32] != h1],
                            assign[32:][assign[32:] != h2]])
    assert not np.isin(tails, [h1, h2]).any()
    assert counts.sum() == 64


# ---------------------------------------------------------------------------
# Shard-merged feedback: replica-plane folds == single-log folds
# ---------------------------------------------------------------------------


def test_shard_merge_reproduces_single_log_estimator_state():
    """Labels stream through an R=3 replica plane (three local shard logs,
    merged at ONE central apply) vs the same labels through a single
    BatchScheduler log: the estimator ends bit-identical — p_hat, arm
    counts, per-cluster versions, global version."""
    engine_a, router_a, qemb, qlab = _make_pool()
    engine_b, router_b, _, _ = _make_pool()
    budget = _budget(engine_a)
    B = qemb.shape[0]

    rset = ReplicaSet(router_a, replicas=3, max_batch=16, max_wait_s=0.0,
                      feedback=True)
    blk = rset.submit_many(np.arange(B), qemb, budget)
    rset.drain()
    assert rset.record_outcomes(blk.request_ids, qlab) == B
    rep_r = rset.apply_feedback()

    base = BatchScheduler(router_b, max_batch=16, max_wait_s=0.0,
                          feedback=True)
    ref = base.submit_many(np.arange(B), qemb, budget)
    base.drain()
    base.record_outcomes(ref.request_ids, qlab)
    rep_b = base.apply_feedback()

    assert rep_r.labels == rep_b.labels == B
    assert sorted(rep_r.clusters) == sorted(rep_b.clusters)
    assert sorted(rep_r.drifted) == sorted(rep_b.drifted)
    est_r, est_b = router_a.estimator, router_b.estimator
    assert est_r.version == est_b.version
    assert est_r.plan_version == est_b.plan_version
    assert set(est_r.clusters) == set(est_b.clusters)
    for cid, st in est_r.clusters.items():
        st2 = est_b.clusters[cid]
        np.testing.assert_array_equal(st.p_hat, st2.p_hat)
        np.testing.assert_array_equal(st.arm_counts, st2.arm_counts)
        assert st.version == st2.version
    fr, fb = rset.stats, base.stats
    for k in ("feedback_labels", "feedback_applies", "feedback_drifts",
              "feedback_unmatched"):
        assert fr[k] == fb[k], k


def test_stray_labels_land_on_central_log():
    engine, router, qemb, qlab = _make_pool(B=32)
    rset = ReplicaSet(router, replicas=2, max_batch=16, max_wait_s=0.0,
                      feedback=True)
    blk = rset.submit_many(np.arange(32), qemb, _budget(engine))
    rset.drain()
    matched = rset.record_outcomes(
        np.concatenate([blk.request_ids, [10 ** 9]]),
        np.concatenate([qlab[:32], [0]]),
    )
    assert matched == 32
    assert rset.stats["feedback_unmatched"] == 1


# ---------------------------------------------------------------------------
# Fault plane + ledger threading at R>1
# ---------------------------------------------------------------------------


def test_replica_faults_complete_with_ledger_invariant():
    """Fused dispatch changes fault-draw row indices (documented caveat),
    so R>1 under faults pins behavioral invariants, not bit-identity: the
    stream completes, failure evidence reaches the degradation counters,
    and every tenant holds ``spent + reserved <= limit``."""
    engine, router, qemb, qlab = _make_pool()
    budget = _budget(engine)
    B = qemb.shape[0]
    ledger = CostLedger(num_arms=len(engine.arms))
    ledger.set_limit("acme", budget * B)
    policy = FaultPolicy(len(engine.arms), 4, seed=7)
    hot = int(np.argmin(engine.costs))
    policy.set_arm(hot, timeout=0.4, error=0.3)
    engine.fault_policy = policy
    try:
        rset = ReplicaSet(router, replicas=3, max_batch=16, max_wait_s=0.0,
                          feedback=True, ledger=ledger)
        blk = rset.submit_many(np.arange(B), qemb, budget, tenant="acme")
        rset.drain()
        assert blk.done() and (blk.predictions >= 0).all()
        rset.record_outcomes(blk.request_ids, qlab)
        rset.apply_feedback()
        st = rset.stats
        assert st["degradation_failures"] > 0      # evidence was threaded
        assert st["degradation_routes"] > 0
        ent = ledger.tenant("acme")
        assert ent["spent"] + ent["reserved"] <= ent["limit"] + 1e-9
        assert ent["reserved"] == 0.0              # fully settled at drain
        assert np.isclose(ent["spent"], blk.costs.sum())
    finally:
        engine.fault_policy = None


def test_replica_tenant_budget_rejections_match_baseline():
    """A tenant that runs out of budget mid-stream is rejected identically
    through the replica plane: prediction -1, cost 0, mode 'rejected',
    and the ledger never over-commits."""
    engine_a, router_a, qemb, _ = _make_pool()
    engine_b, router_b, _, _ = _make_pool()
    budget = _budget(engine_a)
    B = qemb.shape[0]
    cap = budget * (B // 4)                        # fits ~a quarter

    def run(sched_cls, router):
        ledger = CostLedger(num_arms=len(engine_a.arms))
        ledger.set_limit("acme", cap)
        if sched_cls is ReplicaSet:
            s = ReplicaSet(router, replicas=1, max_batch=16, max_wait_s=0.0,
                           ledger=ledger)
        else:
            s = BatchScheduler(router, max_batch=16, max_wait_s=0.0,
                               ledger=ledger)
        blk = s.submit_many(np.arange(B), qemb, budget, tenant="acme")
        s.drain()
        return blk, ledger

    blk_r, led_r = run(ReplicaSet, router_a)
    blk_b, led_b = run(BatchScheduler, router_b)
    _assert_block_equal(blk_r, blk_b)
    rej = blk_r.modes == "rejected"
    assert rej.any()
    assert (blk_r.predictions[rej] == -1).all()
    assert (blk_r.costs[rej] == 0).all()
    assert led_r.tenant("acme")["spent"] == led_b.tenant("acme")["spent"]
    assert led_r.tenant("acme")["spent"] <= cap


# ---------------------------------------------------------------------------
# Compile budgets: zero timed recompiles per replica
# ---------------------------------------------------------------------------


def test_replica_stream_zero_recompiles_after_prewarm():
    """prewarm_compile covers both the per-worker admission bucket and the
    fused concatenation bucket; a full R=4 stream (fused dispatches
    included) then never compiles a new wave program."""
    from repro.analysis import CompileSentinel
    from repro.serving import router as router_mod

    engine, router, qemb, _ = _make_pool()
    budget = _budget(engine)
    rset = ReplicaSet(router, replicas=4, max_batch=16, max_wait_s=0.0)
    rset.prewarm(budgets=[budget])
    rset.prewarm_compile()
    sentinel = CompileSentinel({"wave": router_mod._wave_scan})
    sentinel.snapshot()
    for _ in range(3):
        blk = rset.submit_many(np.arange(qemb.shape[0]), qemb, budget)
        rset.drain()
        assert blk.done()
    sentinel.assert_no_new_compiles(
        detail="R=4 replica stream after prewarm_compile"
    )
