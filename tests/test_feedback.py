"""Online estimation feedback: equivalence, drift gating, lazy versioned
invalidation, and the drifted-traffic recovery acceptance bar.

Four contracts:
  * a scheduler with feedback *enabled* but zero labels recorded is
    bit-identical to PR 3 behavior (and continuous == one-shot still
    holds), with no plan-cache hit-rate regression;
  * feedback that merely confirms current estimates folds into the
    estimator without invalidating a single plan (drift gating);
  * plan-cache keys carry estimator versions, so a stale plan can never
    serve — even when ``refresh()`` is never called (lazy invalidation) —
    and a drifted-arm scenario re-selects plans only for drifted clusters;
  * on synthetic drifted traffic, the feedback-enabled front-end recovers
    >= 90% of the oracle-replan accuracy while the frozen-plan baseline
    does not (the ISSUE 4 acceptance criterion, mirrored by the bench's
    ``feedback`` section).
"""
import dataclasses

import numpy as np

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import (
    BatchScheduler,
    FeedbackLog,
    OracleArm,
    PoolEngine,
    Request,
    ThriftRouter,
)


@dataclasses.dataclass
class TabularArm:
    """Deterministic arm: response to query j is the precomputed resp[j]."""

    name: str
    cost: float
    resp: np.ndarray

    def classify_batch(self, queries) -> np.ndarray:
        return self.resp[np.asarray(queries, np.int64)]

    def latency_s(self, batch: int) -> float:
        return 1e-6 * self.cost * batch


def _tabular_pool(K=4, L=8, clusters=5, B=96, seed=3):
    """Deterministic pool (bit-identical equivalence testing)."""
    wl = OracleWorkload(num_classes=K, num_clusters=clusters, num_arms=L, seed=seed)
    T, emb, _ = wl.response_table(60 * clusters, seed=seed + 1)
    assign, _ = kmeans(emb, clusters, seed=0)
    est = SuccessProbEstimator(T, emb, assign)
    rng = np.random.default_rng(seed + 2)
    qcid, qemb, qlab = wl.sample_queries(B, rng)
    R = np.stack(
        [
            wl.invoke_batch(a, qcid, qlab, np.random.default_rng(seed + 100 + a))
            for a in range(L)
        ]
    )
    engine = PoolEngine(
        [TabularArm(f"t{a}", float(wl.costs[a]), R[a]) for a in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return est, engine, router, qemb, qlab


def _oracle_pool(K=4, C=4, L=12, hist=120, seed=3, arm_seed=11, est_seed=4):
    """Oracle pool over *true* cluster ids — the drift scenario's substrate
    (truth is mutable via ``OracleWorkload.drift_arms``)."""
    wl = OracleWorkload(num_classes=K, num_clusters=C, num_arms=L, seed=seed)
    T, emb, cid_h = wl.response_table(hist * C, seed=est_seed)
    est = SuccessProbEstimator(T, emb, cid_h)
    engine = PoolEngine(
        [OracleArm(f"a{i}", wl, i, seed=arm_seed) for i in range(L)]
    )
    router = ThriftRouter(engine, est, num_classes=K)
    return wl, est, engine, router


# ---------------------------------------------------------------------------
# Zero-feedback equivalence
# ---------------------------------------------------------------------------


def test_zero_labels_is_bit_identical_and_no_hit_rate_regression():
    """Feedback enabled + zero labels == feedback disabled, exactly:
    same predictions/costs/stop waves on an interleaved-budget stream,
    same plan-cache hit/miss counters, estimator never versioned."""
    est_a, engine, router_a, qemb, _ = _tabular_pool(B=96)
    est_b, _, router_b, _, _ = _tabular_pool(B=96)
    rng = np.random.default_rng(11)
    levels = np.quantile(engine.costs, [0.4, 0.8]) * 2.5
    budgets = rng.choice(levels, size=96)

    sched_fb = BatchScheduler(router_a, max_batch=32, max_wait_s=0.0,
                              feedback=True)
    sched_off = BatchScheduler(router_b, max_batch=32, max_wait_s=0.0)
    blk_fb = sched_fb.submit_many(np.arange(96), qemb, budgets)
    blk_off = sched_off.submit_many(np.arange(96), qemb, budgets)
    sched_fb.drain()
    sched_off.drain()

    np.testing.assert_array_equal(blk_fb.predictions, blk_off.predictions)
    np.testing.assert_allclose(blk_fb.costs, blk_off.costs, rtol=1e-15, atol=0)
    np.testing.assert_array_equal(blk_fb.stop_waves, blk_off.stop_waves)
    # plan-cache hit rate must not regress with feedback enabled
    for key in ("plan_hits", "plan_misses", "plan_invalidations",
                "plan_stale_dropped"):
        assert sched_fb.stats[key] == sched_off.stats[key], key
    # nothing ever touched the estimator
    assert est_a.version == 0 and est_a.plan_version == 0
    assert sched_fb.stats["feedback_labels"] == 0
    assert sched_fb.stats["feedback_watching"] == 96  # outcomes registered
    assert sched_fb.apply_feedback() is None          # no-op with no labels


def test_continuous_with_feedback_matches_oneshot_stream():
    """PR 3's continuous == one-shot equivalence survives the feedback
    plumbing (request ids, outcome registration at retirement)."""
    est, engine, router, qemb, _ = _tabular_pool(B=64)
    _, _, router2, _, _ = _tabular_pool(B=64)
    budget = float(np.quantile(engine.costs, 0.6)) * 2

    sched = BatchScheduler(router, max_batch=16, max_wait_s=0.0, feedback=True)
    futs = [
        sched.submit(Request(payload=j, embedding=qemb[j], budget=budget))
        for j in range(64)
    ]
    sched.drain()
    preds = np.zeros(64, np.int64)
    costs = np.zeros(64, np.float64)
    for s in range(0, 64, 16):
        rows = np.arange(s, s + 16)
        res = router2.route_batch(rows, qemb[rows], budget)
        preds[rows] = res.predictions
        costs[rows] = res.costs
    np.testing.assert_array_equal([f.result().prediction for f in futs], preds)
    np.testing.assert_allclose(
        [f.result().cost for f in futs], costs, rtol=1e-15, atol=0
    )
    # futures expose the feedback key
    assert [f.request_id for f in futs] == list(range(64))
    assert all(f.result().request_id == f.request_id for f in futs)


# ---------------------------------------------------------------------------
# Drift gating + versioned lazy invalidation
# ---------------------------------------------------------------------------


def test_confirming_feedback_keeps_plans_hot():
    """Labels consistent with current estimates fold in (version bumps,
    counts grow) without invalidating any plan or batch table."""
    wl, est, engine, router = _oracle_pool()
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    sched = BatchScheduler(router, max_batch=128, max_wait_s=0.0, feedback=True)
    rng = np.random.default_rng(7)

    cid, qemb, lab = wl.sample_queries(256, rng)
    blk = sched.submit_many(np.column_stack([cid, lab]), qemb, budget)
    sched.drain()
    misses0 = sched.stats["plan_misses"]
    sched.record_outcomes(blk.request_ids, lab)       # truth unchanged

    cid, qemb, lab = wl.sample_queries(256, rng)
    blk = sched.submit_many(np.column_stack([cid, lab]), qemb, budget)
    sched.drain()
    st = sched.stats
    assert st["feedback_applies"] == 1 and st["feedback_drifts"] == 0
    assert est.version > 0                     # feedback really folded
    assert est.plan_version == 0               # ...but stayed plan-invisible
    assert all(c.version == 0 for c in est.clusters.values())
    assert st["plan_misses"] == misses0        # every plan kept hitting
    assert st["plan_stale_dropped"] == 0


def test_stale_version_keys_never_serve_without_refresh():
    """Lazy invalidation: a plan-visible estimator change makes old keys
    unreachable immediately — plan() and batch_tables() rebuild even if
    refresh() is never called — and refresh() prunes the corpses."""
    _, est, engine, router = _oracle_pool()
    plans = router.plans
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    cid = int(est.cluster_order[0])

    p0 = plans.plan(cid, budget)
    t0 = plans.batch_tables(budget)
    assert plans.plan(cid, budget) is p0               # warm
    assert plans.batch_tables(budget) is t0
    size0 = len(plans._cache)

    est.update(cid, np.ones((40, len(engine.arms))))   # plan-visible change
    # NO refresh() call — the version in the key does the invalidation
    p1 = plans.plan(cid, budget)
    t1 = plans.batch_tables(budget)
    assert p1 is not p0 and t1 is not t0
    assert not np.array_equal(p1.weights, p0.weights) or not np.array_equal(
        p1.order, p0.order
    )
    assert len(plans._cache) == size0 + 1              # corpse still cached
    assert plans.refresh() is True                     # detected + pruned
    assert len(plans._cache) == size0
    assert plans.stats()["plan_stale_dropped"] == 1
    assert plans.plan(cid, budget) is p1               # fresh entry survives


def test_drift_replans_only_drifted_clusters():
    """A drifted arm re-selects plans for the drifted cluster alone; the
    other clusters' plans and versions stay put."""
    wl, est, engine, router = _oracle_pool()
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    sched = BatchScheduler(router, max_batch=256, max_wait_s=0.0, feedback=True)
    rng = np.random.default_rng(5)

    target = 0
    plan_arms = router.plans.plan(target, budget).order
    wl.drift_arms(plan_arms, 0.30, clusters=[target])

    drifted = False
    for _ in range(4):
        cid, qemb, lab = wl.sample_queries(256, rng)
        blk = sched.submit_many(np.column_stack([cid, lab]), qemb, budget)
        sched.drain()
        sched.record_outcomes(blk.request_ids, lab)
        if sched.stats["feedback_drifts"]:
            drifted = True
    sched.apply_feedback()
    assert drifted or sched.stats["feedback_drifts"] > 0
    # only the drifted cluster's estimate went plan-visible
    assert est.clusters[target].version > 0
    others = [c for c in est.clusters if c != target]
    assert all(est.clusters[c].version == 0 for c in others)
    # and only its plan was re-selected: the arm mix moved away from the
    # broken ensemble while other clusters kept their cached plans
    new_plan = router.plans.plan(target, budget)
    assert not np.array_equal(np.sort(new_plan.order), np.sort(plan_arms))
    assert sched.stats["plan_stale_dropped"] >= 1


# ---------------------------------------------------------------------------
# Acceptance: drifted-traffic recovery
# ---------------------------------------------------------------------------


def test_online_feedback_recovers_oracle_accuracy_frozen_does_not():
    """ISSUE 4 acceptance: an arm's true accuracy shifts mid-stream; the
    feedback-enabled front-end recovers >= 90% of the oracle-replan
    accuracy on the drifted clusters' tail traffic, the frozen-plan
    baseline does not. (Same scenario as the bench's ``feedback``
    section, sized for CI.)"""
    wl, est, engine, router = _oracle_pool()
    K, L = 4, len(engine.arms)
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    sched = BatchScheduler(router, max_batch=256, max_wait_s=0.0, feedback=True)

    # mid-stream shift: the served plans' arms degrade to barely-above-
    # random (0.30 > 1/K keeps selection inside the paper's p > 1/K regime)
    targets = [0, 1]
    for t in targets:
        wl.drift_arms(router.plans.plan(t, budget).order, 0.30, clusters=[t])

    # oracle replan: re-estimated from post-drift truth
    T2, emb2, cid2 = wl.response_table(120 * est.cluster_order.size, seed=14)
    oracle = ThriftRouter(
        PoolEngine([OracleArm(f"o{i}", wl, i, seed=12) for i in range(L)]),
        SuccessProbEstimator(T2, emb2, cid2),
        num_classes=K,
    )
    # frozen baseline: an identical pre-drift pool (same seeds -> same stale
    # estimates) whose truth drifts the same way, but no feedback ever folds
    wl_f, _, _, frozen = _oracle_pool(arm_seed=13)
    wl_f.p_true[:] = wl.p_true

    rng = np.random.default_rng(5)
    accs, oaccs, faccs = [], [], []
    for _ in range(14):
        cid, qemb, lab = wl.sample_queries(256, rng)
        m = np.isin(cid, targets)
        q = np.column_stack([cid, lab])
        blk = sched.submit_many(q, qemb, budget)
        sched.drain()
        ores = oracle.route_batch(q, qemb, budget)
        fres = frozen.route_batch(q, qemb, budget)
        accs.append(float((blk.predictions[m] == lab[m]).mean()))
        oaccs.append(float((ores.predictions[m] == lab[m]).mean()))
        faccs.append(float((fres.predictions[m] == lab[m]).mean()))
        sched.record_outcomes(blk.request_ids, lab)   # online loop closes

    online, oracle_acc, frozen_acc = (
        float(np.mean(a[7:])) for a in (accs, oaccs, faccs)
    )
    assert online >= 0.9 * oracle_acc, (online, oracle_acc, accs)
    assert frozen_acc < 0.9 * oracle_acc, (frozen_acc, oracle_acc, faccs)
    # the loop really drove the recovery
    st = sched.stats
    assert st["feedback_drifts"] >= 1 and st["plan_stale_dropped"] >= 1


# ---------------------------------------------------------------------------
# FeedbackLog unit behavior
# ---------------------------------------------------------------------------


def test_feedback_log_unmatched_eviction_and_shared_use():
    _, est, engine, router = _oracle_pool()
    log = FeedbackLog(est, max_watch=4)
    sched = BatchScheduler(router, max_batch=8, max_wait_s=0.0, feedback=log)
    assert sched.feedback is log                       # instance shareable
    assert log.record(999, 0) is False                 # unknown id
    assert log.stats()["feedback_unmatched"] == 1

    rng = np.random.default_rng(1)
    wl = engine.arms[0].workload
    cid, qemb, lab = wl.sample_queries(8, rng)
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    blk = sched.submit_many(np.column_stack([cid, lab]), qemb, budget)
    sched.drain()
    # retention cap: only the newest 4 outcomes are still watched
    assert log.watching == 4 and log.stats()["feedback_evicted"] == 4
    assert log.record(int(blk.request_ids[0]), int(lab[0])) is False  # evicted
    assert log.record(int(blk.request_ids[-1]), int(lab[-1])) is True
    assert log.record(int(blk.request_ids[-1]), int(lab[-1])) is False  # dup
    assert log.pending == 1
    report = log.apply()
    assert report.labels == 1 and report.version == est.version
    assert log.pending == 0


def test_shared_log_ids_unique_and_labeled_ids_age_out():
    """Two schedulers sharing one FeedbackLog draw collision-free request
    ids from it, and a healthily-labeled server's bookkeeping stays
    bounded (labeled ids age out of the retention window; blocks free as
    their last row is consumed)."""
    _, est, engine, router = _oracle_pool()
    _, _, _, router2 = _oracle_pool(arm_seed=17)
    log = FeedbackLog(est, max_watch=64)
    s1 = BatchScheduler(router, max_batch=8, max_wait_s=0.0, feedback=log)
    s2 = BatchScheduler(router2, max_batch=8, max_wait_s=0.0, feedback=log)
    wl = engine.arms[0].workload
    rng = np.random.default_rng(2)
    budget = float(np.quantile(engine.costs, 0.5)) * 2

    cid, qemb, lab = wl.sample_queries(8, rng)
    q = np.column_stack([cid, lab])
    b1 = s1.submit_many(q, qemb, budget)
    s1.drain()
    b2 = s2.submit_many(q, qemb, budget)
    s2.drain()
    assert not set(b1.request_ids.tolist()) & set(b2.request_ids.tolist())
    # labels resolve against the right scheduler's outcomes, no cross-talk
    assert s1.record_outcomes(b1.request_ids, lab) == 8
    assert s2.record_outcomes(b2.request_ids, lab) == 8
    assert log.stats()["feedback_unmatched"] == 0

    # stream many fully-labeled chunks: retention deque stays within the
    # window and consumed blocks are freed, so nothing grows unboundedly
    for _ in range(20):
        cid, qemb, lab = wl.sample_queries(8, rng)
        blk = s1.submit_many(np.column_stack([cid, lab]), qemb, budget)
        s1.drain()
        s1.record_outcomes(blk.request_ids, lab)
    assert len(log._watch_order) <= 64
    assert log.watching == 0 and not log._blocks


# ---------------------------------------------------------------------------
# Exploration probes (ISSUE 5: recovered arms re-enter estimates)
# ---------------------------------------------------------------------------


def test_probe_rate_zero_changes_nothing():
    """probe_rate=0 (default): no rng consumed, no probe columns — the
    zero-label path stays bit-identical to a probe-free FeedbackLog."""
    wl, est, engine, router = _oracle_pool()
    wl2, est2, engine2, router2 = _oracle_pool()
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    s_a = BatchScheduler(router, max_batch=64, max_wait_s=0.0,
                         feedback=FeedbackLog(est))
    s_b = BatchScheduler(router2, max_batch=64, max_wait_s=0.0,
                         feedback=FeedbackLog(est2, probe_rate=0.0))
    rng = np.random.default_rng(8)
    cid, qemb, lab = wl.sample_queries(64, rng)
    q = np.column_stack([cid, lab])
    a = s_a.submit_many(q, qemb, budget); s_a.drain()
    b = s_b.submit_many(q, qemb, budget); s_b.drain()
    np.testing.assert_array_equal(a.predictions, b.predictions)
    assert s_b.stats["feedback_probes"] == 0


def test_probes_feed_unplanned_arm_estimates():
    """A probed (currently-unplanned) arm accumulates labeled observations,
    so its estimate moves again — the recovered-arm loop the ROADMAP left
    open. The probe never perturbs routing outputs' shape or validity."""
    wl, est, engine, router = _oracle_pool()
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    log = FeedbackLog(est, probe_rate=1.0, probe_seed=5)
    sched = BatchScheduler(router, max_batch=64, max_wait_s=0.0, feedback=log)
    rng = np.random.default_rng(9)

    cid, qemb, lab = wl.sample_queries(64, rng)
    blk = sched.submit_many(np.column_stack([cid, lab]), qemb, budget)
    sched.drain()
    assert log.probes == 64                       # every request probed

    # the probed arm is outside the served plan for its cluster
    planned = {
        (int(c), int(a))
        for c in np.unique(cid)
        for a in router.plans.plan(int(c), budget).order
    }
    counts_before = {
        int(c): est.clusters[int(c)].arm_counts.copy() for c in np.unique(cid)
    }
    sched.record_outcomes(blk.request_ids, lab)
    report = sched.apply_feedback()
    assert report is not None and report.labels == 64
    moved_unplanned = 0
    for c in np.unique(cid):
        delta = est.clusters[int(c)].arm_counts - counts_before[int(c)]
        for a in np.flatnonzero(delta > 0):
            if (int(c), int(a)) not in planned:
                moved_unplanned += 1
    assert moved_unplanned > 0                    # unplanned arms observed


def test_drift_replans_are_batched_at_admission():
    """A fold that drifts clusters triggers ONE batched replan at the
    admission boundary (plan_batch_replans counter), and the rebuilt plans
    serve the next batch as cache hits."""
    wl, est, engine, router = _oracle_pool()
    budget = float(np.quantile(engine.costs, 0.5)) * 2
    sched = BatchScheduler(router, max_batch=256, max_wait_s=0.0,
                           feedback=True)
    rng = np.random.default_rng(5)

    targets = [0, 1]
    for t in targets:
        wl.drift_arms(router.plans.plan(t, budget).order, 0.30, clusters=[t])
    for _ in range(4):
        cid, qemb, lab = wl.sample_queries(256, rng)
        blk = sched.submit_many(np.column_stack([cid, lab]), qemb, budget)
        sched.drain()
        sched.record_outcomes(blk.request_ids, lab)
    sched.apply_feedback()
    st = sched.stats
    assert st["feedback_drifts"] >= 1
    assert st["plan_batch_replans"] >= 1          # replans went batched
    assert st["plan_batch_replanned"] >= st["feedback_drifts"] >= 1
    # the eager rebuild means the drifted clusters' next plans are hits
    misses = router.plans.stats()["plan_misses"]
    for t in targets:
        router.plans.plan(t, budget)
    assert router.plans.stats()["plan_misses"] == misses
