"""Suppression-machinery fixture (never imported; parsed only).

Three identical f64-reduction violations with different suppression
states: reasoned (silenced), reason-less (bad-suppression), and bare
(survives).
"""
import jax
import jax.numpy as jnp


@jax.jit
def suppressed_ok(w, x):
    return jnp.sum(w * x)  # thriftlint: ignore[f64-reduction] fixture: pretend exactness is documented here


@jax.jit
def reasonless(w, x):
    return jnp.sum(w * x)  # thriftlint: ignore[f64-reduction]


@jax.jit
def unsuppressed(w, x):
    return jnp.sum(w * x)
