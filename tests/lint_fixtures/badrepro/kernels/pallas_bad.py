"""Seeded pallas-contract violations (never imported; parsed only)."""
import jax
from jax.experimental import pallas as pl


def _bad_store_kernel(x_ref, o_ref):
    t = pl.program_id(0)
    o_ref[t] = x_ref[0] * 2.0  # FIRES: pallas-contract


def bad_store(x):
    return pl.pallas_call(  # FIRES: pallas-contract
        _bad_store_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(x.shape[0],),
    )(x)


def _mismatch_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def mismatched_grid(x, interpret):
    return pl.pallas_call(
        _mismatch_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],  # FIRES: pallas-contract
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        interpret=True,  # FIRES: pallas-contract
    )(x)


def _clean_kernel(x_ref, o_ref):
    t = pl.program_id(0)
    o_ref[0, pl.dslice(t, 1), :] = x_ref[0, pl.dslice(t, 1), :]


def clean(x, interpret):
    return pl.pallas_call(
        _clean_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(x.shape[1],),
        interpret=interpret,
    )(x)
