"""Seeded f64-reduction violations (never imported; parsed only)."""
import jax
import jax.numpy as jnp


@jax.jit
def marginal_gain(w, x):
    g = jnp.einsum("ij,j->i", w, x)  # FIRES: f64-reduction
    return jnp.sum(g)  # FIRES: f64-reduction


@jax.jit
def hashed_accumulate(x):
    total = 0.0
    for arm in {3, 1, 2}:  # FIRES: f64-reduction
        total += x[arm]
    return total


@jax.jit
def explicit_ok(w, x):
    # explicit accumulator dtype: the contract-compliant spelling
    return jnp.sum(w * x, dtype=jnp.float64)


@jax.jit
def exact_ok(a, b):
    # integer-exact indicator count: the other compliant spelling
    return jnp.sum((a == b).astype(jnp.int32))
