"""Seeded prng-discipline violations (never imported; parsed only)."""
import jax


@jax.jit
def double_sample(key):
    a = jax.random.uniform(key, (4,))
    b = jax.random.normal(key, (4,))  # FIRES: prng-discipline
    return a + b


@jax.jit
def sample_and_split(key):
    u = jax.random.uniform(key, (2,))  # FIRES: prng-discipline
    k1, k2 = jax.random.split(key)
    return u, jax.random.uniform(k1), jax.random.uniform(k2)


@jax.jit
def clean_fold(key, n):
    # the repo's CRN idiom: derive-many, consume-each-derived-once
    return jax.random.uniform(jax.random.fold_in(key, n))
