"""Seeded recompile-risk violations (never imported; parsed only)."""
import functools

import jax

_SCALE = 1.0


def set_scale(s):
    global _SCALE
    _SCALE = s


@jax.jit
def scaled(x):  # FIRES: recompile-risk
    return x * _SCALE


def per_call(xs):
    out = []
    for x in xs:
        f = jax.jit(lambda v: v * 2.0)  # FIRES: recompile-risk
        out.append(f(x))
    return out


@functools.partial(jax.jit, static_argnames=("dims",))
def windowed(x, dims):
    return x.reshape(dims)


def caller(x):
    return windowed(x, dims=[2, 2])  # FIRES: recompile-risk


def churny(x):
    return windowed(x, dims=(len(x), 1))  # FIRES: recompile-risk


def _stage_on(v):
    return jax.device_put(v, None)  # FIRES: recompile-risk


@jax.jit
def pinned(x):
    return _stage_on(x) * 2.0
