"""Seeded jit-purity violations.

Never imported — parsed by the thriftlint walker only.  Lines carrying a
violation end with a ``FIRES: <rule>`` marker; the test derives the
expected finding locations from those markers.
"""
import random
import time

import jax
import numpy as np

_TRACE_COUNT = 0


@jax.jit
def stamped(x):
    t = time.time()  # FIRES: jit-purity
    r = random.random()  # FIRES: jit-purity
    n = np.random.rand()  # FIRES: jit-purity
    return x + t + r + n


def accum_body(carry, x):
    global _TRACE_COUNT  # FIRES: jit-purity
    _TRACE_COUNT += 1
    return carry + x, x


def run_scan(xs):
    return jax.lax.scan(accum_body, 0.0, xs)
