"""Seeded donation-contract violations (never imported; parsed only)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

_STAGED = np.zeros((8, 8))
_SCRATCH = {"resp": np.zeros((8, 8))}


def _wave_core(sched, resp, w):
    return resp * w


wave = functools.partial(jax.jit, donate_argnums=(1, 2))(_wave_core)


@functools.partial(jax.jit, donate_argnums=(0,))
def fused_step(state, delta):
    return state + delta


def reread_after_donation(sched, resp, w):
    out = wave(sched, resp, w)  # FIRES: donation-contract
    return out + resp.sum()


def donates_module_buffer(sched, w):
    return wave(sched, _STAGED, w)  # FIRES: donation-contract


def donates_scratch_entry(sched, w):
    return wave(sched, _SCRATCH["resp"], w)  # FIRES: donation-contract


class Engine:
    def __init__(self):
        self._table = jnp.zeros((4, 4))

    def step(self, sched, w):
        return wave(sched, self._table, w)  # FIRES: donation-contract


def caller_keeps_state(state, delta):
    new = fused_step(state, delta)  # FIRES: donation-contract
    return new - state


def safe_throwaway_locals(sched):
    resp = jnp.ones((8, 8))
    w = jnp.ones((8, 8))
    return wave(sched, resp, w)


def safe_reassigned_before_read(sched, resp, w):
    out = wave(sched, resp, w)
    resp = jnp.zeros((8, 8))
    return out + resp
