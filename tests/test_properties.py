"""Hypothesis property tests on the system's core invariants.

Runs on the real ``hypothesis`` engine when installed; otherwise on the
in-repo ``_hypolite`` fallback (same API subset, deterministic draws), so
the properties ALWAYS run — scripts/ci.sh fails the build if these tests
skip, closing the old importorskip hole that silently masked them.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: see requirements-test.txt
    from _hypolite import given, settings, strategies as st

from repro.core import (
    aggregate_log_beliefs,
    empty_log_belief,
    gamma,
    log_weight,
    predict_from_beliefs,
    xi_exact,
)

# NOTE: paper Lemma 1 (monotonicity of xi) implicitly assumes arms are
# better than random: for p < 1/K the belief weight p(K-1)/(1-p) < 1 (log
# weight negative) and adding such an arm can DECREASE xi under the paper's
# aggregator with its empty-class heuristic. Property testing found the
# counterexample (see test_lemma1_fails_for_worse_than_random_arms); all
# monotonicity properties below therefore sample better-than-random arms.
probs = st.lists(st.floats(0.05, 0.98), min_size=1, max_size=5)
klass = st.integers(2, 6)


def _better_than_random(ps, K, margin=0.02):
    return min(ps) > 1.0 / K + margin


@settings(max_examples=60, deadline=None)
@given(probs, klass)
def test_gamma_upper_bounds_xi(ps, K):
    """Lemma 3 — holds for better-than-random arms. (Its Category-II proof
    step assumes 'all arms wrong => prediction wrong', which anti-evidence
    arms violate: see test_lemma3_fails_for_worse_than_random_arms.)"""
    if not _better_than_random(ps, K):
        return
    p = np.asarray(ps)
    assert gamma(p) >= xi_exact(p, K) - 1e-9


def test_lemma3_fails_for_worse_than_random_arms():
    """Documented deviation (found by hypothesis): with K=2 and two p=0.05
    arms, the ML aggregator flips their anti-evidence votes and achieves
    xi=0.95 while gamma=0.0975 — the surrogate is NOT an upper bound below
    the 1/K threshold, so Theorem 3's guarantee needs p_min > 1/K."""
    p = np.array([0.05, 0.05])
    assert xi_exact(p, 2) > 0.9
    assert gamma(p) < 0.1


@settings(max_examples=60, deadline=None)
@given(probs, klass)
def test_xi_bounded_and_at_least_best_single(ps, K):
    """xi in [0,1]; for better-than-random arms the ML ensemble never loses
    to its best single arm."""
    p = np.asarray(ps)
    x = xi_exact(p, K)
    assert -1e-9 <= x <= 1 + 1e-9
    if _better_than_random(ps, K):
        assert x >= max(p) - 1e-9


@settings(max_examples=40, deadline=None)
@given(probs, klass, st.floats(0.0, 0.05))
def test_xi_monotone_in_probs(ps, K, bump):
    if not _better_than_random(ps, K):
        return
    p = np.asarray(ps)
    hi = np.clip(p + bump, 0.0, 0.99)
    assert xi_exact(hi, K) >= xi_exact(p, K) - 1e-9


@settings(max_examples=40, deadline=None)
@given(probs, klass)
def test_xi_monotone_in_set(ps, K):
    if not _better_than_random(ps, K):
        return
    p = np.asarray(ps)
    if p.size < 2:
        return
    assert xi_exact(p, K, p_all=p) >= xi_exact(p[:-1], K, p_all=p) - 1e-9


def test_lemma1_fails_for_worse_than_random_arms():
    """Documented deviation from paper Lemma 1 (found by hypothesis):
    adding a worse-than-random arm can strictly decrease xi."""
    p_all = np.array([0.0625, 0.0625, 0.125])
    K = 3
    smaller = xi_exact(p_all[:2], K, p_all=p_all)
    larger = xi_exact(p_all, K, p_all=p_all)
    assert larger < smaller  # monotonicity violated below the 1/K threshold


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.integers(0, 4), min_size=1, max_size=8),
    st.lists(st.floats(0.2, 0.95), min_size=8, max_size=8),
)
def test_belief_aggregation_majority_of_identical_weights(resp, ps):
    """With equal weights, ML aggregation must agree with majority voting."""
    K = 5
    m = len(resp)
    p = np.full(m, 0.7)
    w = log_weight(p, K)
    beliefs = aggregate_log_beliefs(np.asarray(resp), w, K, empty_log_belief(p))
    pred, _ = predict_from_beliefs(beliefs)
    votes = np.bincount(resp, minlength=K)
    assert votes[pred] == votes.max()


@settings(max_examples=50, deadline=None)
@given(probs, klass)
def test_gamma_submodularity_random_chains(ps, K):
    rng = np.random.default_rng(0)
    p = np.asarray(ps)
    if p.size < 3:
        return
    l = p.size - 1
    s1 = p[:1]
    s2 = p[:-1]
    g1 = gamma(np.append(s1, p[l])) - gamma(s1)
    g2 = gamma(np.append(s2, p[l])) - gamma(s2)
    assert g1 >= g2 - 1e-12


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 30), klass)
def test_empty_belief_below_any_arm_weight(m, K):
    """The empty-class heuristic never outranks a voted class with p>1/2."""
    p = np.full(m, 0.6)
    assert empty_log_belief(p) < log_weight(p, K).min()
