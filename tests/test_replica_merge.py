"""Property suite: the shard-merged feedback contract (satellite of the
R-replica serving plane).

``merge_counts`` must be a true commutative monoid on feedback shards —
counts are monotone sums of unit increments (integer-valued floats, exact
far below 2**53), so shard addition is associative, commutative and
bit-for-bit reproducible in any grouping. On top of that the *partition
invariance* property: ANY partition of a label stream across R replica
shard logs, merged and folded through ONE central apply, leaves the
estimator in exactly the single-log state (p_hat, arm counts, versions,
drift set). That pair of properties is what lets the replica plane fold
feedback locally and reconcile centrally without any cross-replica
ordering protocol.

Scope note: the contract is merge-then-ONE-fold. Folding the same counts
in several ``apply`` calls at different boundaries is deliberately NOT
bit-equal (interval refreshes compose nonlinearly) — the control plane
always merges all pending shards before its single central fold.

Runs on the real ``hypothesis`` engine when installed, else on the
in-repo ``_hypolite`` fallback — scripts/ci.sh fails if these skip.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: see requirements-test.txt
    from _hypolite import given, settings, strategies as st

from repro.core.clustering import kmeans
from repro.core.estimation import SuccessProbEstimator
from repro.data import OracleWorkload
from repro.serving import FeedbackLog, FeedbackShard, merge_counts

L = 6            # arms
K = 4            # classes
CLUSTERS = 4
T = 3            # waves per observed request


def _estimator() -> SuccessProbEstimator:
    """A fresh estimator twin: deterministic construction, so every call
    returns a bit-identical starting state (the two sides of each
    equivalence property get one each)."""
    wl = OracleWorkload(num_classes=K, num_clusters=CLUSTERS, num_arms=L,
                       seed=9)
    tbl, emb, _ = wl.response_table(40 * CLUSTERS, seed=10)
    assign, _ = kmeans(emb, CLUSTERS, seed=0)
    return SuccessProbEstimator(tbl, emb, assign)


def _shard(spec) -> FeedbackShard:
    """Materialize one shard from a drawn spec: list of (cid, nq, seed)
    entries — per-cluster integer-valued success/attempt buffers with
    succ <= att, the exact pending-buffer shape a replica exports."""
    counts = {}
    labels = 0
    for cid, nq, seed in spec:
        rng = np.random.default_rng(seed)
        att = rng.integers(0, 8, L).astype(np.float64)
        succ = np.floor(att * rng.random(L))
        buf = counts.get(cid)
        if buf is None:
            counts[cid] = [succ, att, int(nq)]
        else:
            buf[0] += succ
            buf[1] += att
            buf[2] += int(nq)
        labels += int(nq)
    return FeedbackShard(counts, labels)


def _shard_equal(a: FeedbackShard, b: FeedbackShard) -> None:
    assert a.labels == b.labels
    assert set(a.counts) == set(b.counts)
    for cid in a.counts:
        sa, aa, na = a.counts[cid]
        sb, ab, nb = b.counts[cid]
        np.testing.assert_array_equal(sa, sb)
        np.testing.assert_array_equal(aa, ab)
        assert na == nb


_ENTRY = st.tuples(
    st.integers(min_value=0, max_value=CLUSTERS - 1),   # cluster id
    st.integers(min_value=0, max_value=5),              # labeled queries
    st.integers(min_value=0, max_value=10_000),         # buffer seed
)
_SPEC = st.lists(_ENTRY, min_size=0, max_size=6)


# ---------------------------------------------------------------------------
# merge_counts is a commutative monoid, bit-for-bit
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(_SPEC, _SPEC, _SPEC)
def test_merge_counts_associative(sa, sb, sc):
    a, b, c = _shard(sa), _shard(sb), _shard(sc)
    left = merge_counts(merge_counts(a, b), c)
    right = merge_counts(a, merge_counts(b, c))
    flat = merge_counts(a, b, c)
    _shard_equal(left, right)
    _shard_equal(left, flat)


@settings(max_examples=60, deadline=None)
@given(_SPEC, _SPEC)
def test_merge_counts_commutative(sa, sb):
    a, b = _shard(sa), _shard(sb)
    _shard_equal(merge_counts(a, b), merge_counts(b, a))


@settings(max_examples=30, deadline=None)
@given(_SPEC)
def test_merge_counts_identity_and_purity(spec):
    """The empty shard is the identity, and merging never aliases or
    mutates its inputs (replicas hand their shards over by reference)."""
    a = _shard(spec)
    before = a.copy()
    merged = merge_counts(a, FeedbackShard({}, 0))
    _shard_equal(merged, a)
    for cid in merged.counts:
        merged.counts[cid][0] += 1.0     # mutate the result...
        merged.counts[cid][1] += 1.0
    _shard_equal(a, before)              # ...inputs unharmed


# ---------------------------------------------------------------------------
# Partition invariance: R shard logs == one log, after ONE central fold
# ---------------------------------------------------------------------------


def _observations(n: int, seed: int):
    """A synthetic retired-group stream: n requests with valid cluster
    ids, (B, T) schedules/responses/invoked masks, and labels."""
    rng = np.random.default_rng(seed)
    est = _estimator()
    cids = est.cluster_order[rng.integers(0, len(est.cluster_order), n)]
    schedule = rng.integers(0, L, (n, T))
    invoked = rng.random((n, T)) < 0.7
    invoked[:, 0] = True                 # wave 0 always runs
    responses = np.where(invoked, rng.integers(0, K, (n, T)), -1)
    labels = rng.integers(0, K, n)
    return cids.astype(np.int64), schedule, responses, invoked, labels


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=24),             # stream length
    st.integers(min_value=1, max_value=4),              # replica count R
    st.integers(min_value=0, max_value=10_000),         # stream seed
    st.integers(min_value=0, max_value=10_000),         # partition seed
)
def test_partition_invariance_vs_single_log(n, R, stream_seed, part_seed):
    """Scatter one observation stream across R shard logs by an arbitrary
    row partition, merge the exported shards, fold ONCE through a central
    log: the estimator state and the fold report match the single-log
    baseline exactly."""
    cids, schedule, responses, invoked, labels = _observations(n, stream_seed)
    ids = np.arange(n, dtype=np.int64)
    assign = np.random.default_rng(part_seed).integers(0, R, n)

    # single-log baseline
    est_one = _estimator()
    log_one = FeedbackLog(est_one)
    log_one.observe(ids, cids, schedule, responses, invoked)
    assert log_one.record_many(ids, labels) == n
    rep_one = log_one.apply()

    # R shard logs -> merge -> one central fold
    est_r = _estimator()
    central = FeedbackLog(est_r)
    shards = []
    for r in range(R):
        rows = np.flatnonzero(assign == r)
        shard_log = FeedbackLog(est_r)
        if rows.size:
            shard_log.observe(ids[rows], cids[rows], schedule[rows],
                              responses[rows], invoked[rows])
            assert shard_log.record_many(ids[rows], labels[rows]) == rows.size
        if shard_log.has_pending:
            shards.append(shard_log.export_shard())
    central.absorb_shard(merge_counts(*shards))
    rep_r = central.apply()

    assert rep_r.labels == rep_one.labels == n
    assert sorted(rep_r.clusters) == sorted(rep_one.clusters)
    assert sorted(rep_r.drifted) == sorted(rep_one.drifted)
    assert est_r.version == est_one.version
    assert est_r.plan_version == est_one.plan_version
    for cid, stats in est_one.clusters.items():
        other = est_r.clusters[cid]
        np.testing.assert_array_equal(stats.p_hat, other.p_hat)
        np.testing.assert_array_equal(stats.arm_counts, other.arm_counts)
        np.testing.assert_array_equal(stats.lo, other.lo)
        np.testing.assert_array_equal(stats.hi, other.hi)
        assert stats.version == other.version


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=2, max_value=16),
    st.integers(min_value=0, max_value=10_000),
)
def test_shard_fold_order_free(n, seed):
    """Merging the SAME shards in any order folds to the same state: the
    merged shard handed to apply() is order-free, so replicas never need
    to coordinate export order."""
    cids, schedule, responses, invoked, labels = _observations(n, seed)
    ids = np.arange(n, dtype=np.int64)
    halves = [np.arange(0, n, 2), np.arange(1, n, 2)]

    states = []
    for order in ((0, 1), (1, 0)):
        est = _estimator()
        central = FeedbackLog(est)
        shards = []
        for rows in halves:
            lg = FeedbackLog(est)
            lg.observe(ids[rows], cids[rows], schedule[rows],
                       responses[rows], invoked[rows])
            lg.record_many(ids[rows], labels[rows])
            shards.append(lg.export_shard())
        central.absorb_shard(merge_counts(shards[order[0]], shards[order[1]]))
        central.apply()
        states.append(est)
    a, b = states
    assert a.version == b.version
    for cid, stats in a.clusters.items():
        np.testing.assert_array_equal(stats.p_hat, b.clusters[cid].p_hat)
        np.testing.assert_array_equal(
            stats.arm_counts, b.clusters[cid].arm_counts
        )
